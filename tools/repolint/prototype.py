#!/usr/bin/env python3
"""Reference prototype of tools/repolint (see src/main.rs).

The Rust binary is the enforced implementation; this script mirrors its
algorithm 1:1 so the rules can be exercised on the live tree without a
Rust toolchain (the repo's standing no-local-toolchain caveat). Keep the
two in sync when changing a rule.
"""
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

UNSAFE_ALLOWLIST = {
    "rust/src/util/disjoint.rs",
    "rust/src/sched/executor.rs",
    "rust/src/sched/graph.rs",
    "rust/src/sched/session.rs",
}

RANK_FIELDS = {
    "progress": "GRAPH_PROGRESS",
    "jobs": "GRAPH_JOBS",
    "pending": "SCOPE_PENDING",
    "lease": "ELASTIC_LEASE",
    "queue": "RUN_QUEUE",
    "body": "JOB_BODY",
    "panic": "JOB_PANIC",
    "stats": "JOB_STATS",
    "done": "JOB_DONE",
    "on_done": "JOB_ON_DONE",
}

DISPATCH_PATH_FNS = {
    "rust/src/sched/executor.rs": [
        "worker_main", "pick_job", "run_job_stint", "flush_stats",
        "complete_items", "finalize", "make_report", "publish_completion",
        "abort_job", "drain_source", "cancel_job", "enqueue_raw",
    ],
    "rust/src/sched/graph.rs": [
        "dispatch", "node_done", "record_done", "cancel_dependents",
    ],
}

COMMENT_WINDOW = 14

SIM_ALLOWED = {"sched", "config", "topology", "util", "sim", "obs"}

SERVE_ALLOWED = {"sched", "sim", "config", "topology", "util", "serve", "obs"}

OBS_ALLOWED = {"util", "topology", "config", "obs"}

# The obs *analysis* modules (critical-path attribution, trace diffing,
# bench reports) consume replay outcomes, so they may additionally read
# `sim` public types -- but never `sched` internals.
OBS_ANALYSIS_FILES = ("rust/src/obs/analyze.rs", "rust/src/obs/report.rs")
OBS_ANALYSIS_ALLOWED = {"util", "topology", "config", "obs", "sim"}

SERVE_CONSUMERS = ("rust/src/serve/", "rust/src/bench/")

# The elastic lease overlay is consulted from the dispatch hot path, so
# it stays a near-leaf; and its module path is API only for sched/, the
# DES mirror and the serving loop (everything else goes through the
# crate::sched re-exports).
ELASTIC_ALLOWED = {"sched", "util", "topology", "config"}
ELASTIC_CONSUMERS = ("rust/src/sched/", "rust/src/sim/", "rust/src/serve/")


def strip(src):
    """Return (code_lines, comment_lines): comments and string/char
    literal bodies blanked from code; comment text collected."""
    code, comment = [], []
    in_block = 0
    raw_hashes = None
    in_str = False
    for line in src.split("\n"):
        b = list(line)
        cl, cm = [], []
        i = 0
        n = len(b)
        while i < n:
            c = b[i]
            if in_block > 0:
                if c == "*" and i + 1 < n and b[i + 1] == "/":
                    in_block -= 1
                    cl += [" ", " "]
                    i += 2
                elif c == "/" and i + 1 < n and b[i + 1] == "*":
                    in_block += 1
                    cl += [" ", " "]
                    i += 2
                else:
                    cm.append(c)
                    cl.append(" ")
                    i += 1
                continue
            if raw_hashes is not None:
                if c == '"' and b[i + 1:i + 1 + raw_hashes] == ["#"] * raw_hashes:
                    cl += ['"'] + [" "] * raw_hashes
                    i += 1 + raw_hashes
                    raw_hashes = None
                else:
                    cl.append(" ")
                    i += 1
                continue
            if in_str:
                if c == "\\" and i + 1 < n:
                    cl += [" ", " "]
                    i += 2
                elif c == '"':
                    in_str = False
                    cl.append('"')
                    i += 1
                else:
                    cl.append(" ")
                    i += 1
                continue
            if c == "/" and i + 1 < n and b[i + 1] == "/":
                cm += b[i:]
                break
            if c == "/" and i + 1 < n and b[i + 1] == "*":
                in_block = 1
                cl += [" ", " "]
                i += 2
                continue
            if c == '"':
                in_str = True
                cl.append('"')
                i += 1
                continue
            if c == "r" and i + 1 < n and b[i + 1] in ('"', "#") \
                    and (i == 0 or not (b[i - 1].isalnum() or b[i - 1] == "_")):
                j = i + 1
                h = 0
                while j < n and b[j] == "#":
                    h += 1
                    j += 1
                if j < n and b[j] == '"':
                    raw_hashes = h
                    cl += [" "] * (j + 1 - i)
                    i = j + 1
                    continue
            if c == "'":
                if i + 1 < n and b[i + 1] == "\\":
                    j = i + 2
                    if j < n:
                        j += 1  # the escaped char
                        while j < n and b[j] != "'":
                            j += 1
                    cl += ["'"] + [" "] * (j - i - 1) + ["'"]
                    i = j + 1
                    continue
                if i + 2 < n and b[i + 2] == "'" and b[i + 1] != "'":
                    cl += ["'", " ", "'"]
                    i += 3
                    continue
                cl.append("'")
                i += 1
                continue
            cl.append(c)
            i += 1
        code.append("".join(cl))
        comment.append("".join(cm))
    return code, comment


def parse_ranks(path):
    with open(path) as f:
        code, _ = strip(f.read())
    ranks = {}
    pat = re.compile(r"pub const (\w+): LockRank = LockRank::new\((\d+),")
    for line in code:
        m = pat.search(line)
        if m:
            ranks[m.group(1)] = int(m.group(2))
    return ranks


def comment_block_above(comment, lnum, needle):
    lo = max(0, lnum - COMMENT_WINDOW)
    return any(needle in comment[j] for j in range(lo, lnum))


def test_regions(code):
    """Line spans (start, end) of #[cfg(test)] items, by brace matching."""
    spans = []
    i = 0
    while i < len(code):
        if code[i].strip().startswith("#[cfg(test)"):
            depth = 0
            started = False
            j = i
            while j < len(code):
                for ch in code[j]:
                    if ch == "{":
                        depth += 1
                        started = True
                    elif ch == "}":
                        depth -= 1
                if started and depth <= 0:
                    break
                j += 1
            spans.append((i, j))
            i = j + 1
        else:
            i += 1
    return spans


def in_spans(spans, lnum):
    return any(a <= lnum <= b for a, b in spans)


IDENT = re.compile(r"[A-Za-z0-9_]")


def recv_ident(code_line, lock_pos):
    """Last identifier of the receiver chain before `.lock()`, with one
    trailing index stripped (`job.stats[lw].lock()` -> `stats`)."""
    i = lock_pos - 1
    if i >= 0 and code_line[i] == "]":
        depth = 1
        i -= 1
        while i >= 0 and depth > 0:
            if code_line[i] == "]":
                depth += 1
            elif code_line[i] == "[":
                depth -= 1
            i -= 1
    end = i + 1
    while i >= 0 and IDENT.match(code_line[i]):
        i -= 1
    return code_line[i + 1:end]


GUARD_LET = re.compile(r"^\s*let\s+(?:mut\s+)?(\w+)\s*=.*\.lock\(\)\.unwrap\(\);\s*$")
DROP_CALL = re.compile(r"\bdrop\(\s*(\w+)\s*\)")
FN_DEF = re.compile(r"\bfn\s+(\w+)")


def fn_span(code, name):
    """Body span of `fn name` (line of the def to its closing brace)."""
    pat = re.compile(r"\bfn\s+" + re.escape(name) + r"\b")
    for i, line in enumerate(code):
        if pat.search(line):
            depth = 0
            started = False
            j = i
            while j < len(code):
                for ch in code[j]:
                    if ch == "{":
                        depth += 1
                        started = True
                    elif ch == "}":
                        depth -= 1
                if started and depth <= 0:
                    return (i, j)
                j += 1
    return None


def lint_file(rel, src, ranks, findings):
    code, comment = strip(src)
    tspans = test_regions(code)

    is_sched_core = rel in UNSAFE_ALLOWLIST

    # --- unsafe / transmute comments + allowlist ---
    for i, line in enumerate(code):
        if re.search(r"\bunsafe\b", line):
            if rel not in UNSAFE_ALLOWLIST:
                findings.append((rel, i + 1, "unsafe-allowlist",
                                 "`unsafe` outside the audited allowlist"))
            elif not (comment_block_above(comment, i, "SAFETY:")
                      or comment_block_above(comment, i, "SOUNDNESS:")):
                findings.append((rel, i + 1, "unsafe-comment",
                                 "`unsafe` without a SAFETY:/SOUNDNESS: comment"))
        if re.search(r"\btransmute\b", line):
            if rel not in UNSAFE_ALLOWLIST:
                findings.append((rel, i + 1, "transmute-allowlist",
                                 "`transmute` outside the audited allowlist"))
            elif not comment_block_above(comment, i, "SOUNDNESS:"):
                findings.append((rel, i + 1, "transmute-comment",
                                 "`transmute` without a SOUNDNESS: comment"))

    # --- lock-rank ordering (code view, whole tree) ---
    depth = 0
    held = []  # (rank, name, depth)
    for i, line in enumerate(code):
        if FN_DEF.search(line) and depth <= 1:
            held = []
        m = DROP_CALL.search(line)
        if m:
            held = [h for h in held if h[1] != m.group(1)]
        for lm in re.finditer(r"\.lock\(\)", line):
            ident = recv_ident(line, lm.start())
            const = RANK_FIELDS.get(ident)
            if const is None:
                continue
            rank = ranks[const]
            for (hrank, hname, _) in held:
                if rank <= hrank:
                    findings.append((rel, i + 1, "lock-rank",
                                     f"acquiring {const}({rank}) via `{ident}` while "
                                     f"holding `{hname}` rank {hrank} inverts the "
                                     "declared order"))
            g = GUARD_LET.match(line)
            if g:
                held.append((rank, g.group(1), depth))
        opens = line.count("{")
        closes = line.count("}")
        depth += opens - closes
        held = [h for h in held if h[2] <= depth]

    # --- condvar wait predicate loops ---
    if rel != "rust/src/util/ordered.rs":
        stack = []  # (keyword, ) parallel to brace depth
        for i, line in enumerate(code):
            t = line.strip()
            m = re.search(r"\.wait\(\s*[^)\s]", line)
            if m:
                ok = False
                for kw in reversed(stack):
                    if kw == "fn":
                        break
                    if kw in ("while", "loop"):
                        ok = True
                        break
                if not ok:
                    findings.append((rel, i + 1, "condvar-predicate",
                                     "`Condvar::wait` outside a predicate loop"))
            first = True
            for ch in line:
                if ch == "{":
                    if first:
                        kw = "block"
                        if re.search(r"\bfn\b", t):
                            kw = "fn"
                        elif re.search(r"\bwhile\b", t):
                            kw = "while"
                        elif re.search(r"\bloop\b", t):
                            kw = "loop"
                        stack.append(kw)
                        first = False
                    else:
                        stack.append("block")
                elif ch == "}":
                    if stack:
                        stack.pop()

    # --- layering ---
    if rel.startswith("rust/src/util/"):
        for i, line in enumerate(code):
            for m in re.finditer(r"crate::(\w+)", line):
                if m.group(1) != "util":
                    findings.append((rel, i + 1, "layering-util",
                                     f"util must not import crate::{m.group(1)}"))
    if rel.startswith("rust/src/sched/"):
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::(bench|apps)\b", line):
                findings.append((rel, i + 1, "layering-sched",
                                 f"sched must not import crate::{m.group(1)}"))
    if rel.startswith("rust/src/sim/"):
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::(\w+)", line):
                if m.group(1) not in SIM_ALLOWED:
                    findings.append((rel, i + 1, "layering-sim",
                                     f"sim may only use {sorted(SIM_ALLOWED)}, "
                                     f"found crate::{m.group(1)}"))

    if rel.startswith("rust/src/serve/"):
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::(\w+)", line):
                if m.group(1) not in SERVE_ALLOWED:
                    findings.append((rel, i + 1, "layering-serve",
                                     f"serve may only use {sorted(SERVE_ALLOWED)}, "
                                     f"found crate::{m.group(1)}"))
    serve_consumer = (rel.startswith(SERVE_CONSUMERS)
                      or rel == "rust/src/main.rs")
    if rel.startswith("rust/src/") and not serve_consumer:
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::serve\b", line):
                findings.append((rel, i + 1, "layering-serve-consumers",
                                 "only bench/ and main.rs may import crate::serve"))

    if rel.startswith("rust/src/obs/"):
        analysis = rel in OBS_ANALYSIS_FILES
        allowed = OBS_ANALYSIS_ALLOWED if analysis else OBS_ALLOWED
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::(\w+)", line):
                if m.group(1) not in allowed:
                    if analysis:
                        msg = (f"obs analysis modules may only use "
                               f"{sorted(OBS_ANALYSIS_ALLOWED)} (sim public "
                               f"types, never sched internals), "
                               f"found crate::{m.group(1)}")
                    else:
                        msg = (f"obs may only use {sorted(OBS_ALLOWED)}, "
                               f"found crate::{m.group(1)}")
                    findings.append((rel, i + 1, "layering-obs", msg))

    # --- elastic overlay layering ---
    if rel == "rust/src/sched/elastic.rs":
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            for m in re.finditer(r"crate::(\w+)", line):
                if m.group(1) not in ELASTIC_ALLOWED:
                    findings.append((rel, i + 1, "layering-elastic",
                                     f"sched/elastic.rs may only use "
                                     f"{sorted(ELASTIC_ALLOWED)}, "
                                     f"found crate::{m.group(1)}"))
    if rel.startswith("rust/src/") and not rel.startswith(ELASTIC_CONSUMERS):
        for i, line in enumerate(code):
            if in_spans(tspans, i):
                continue
            if "sched::elastic" in line:
                findings.append((rel, i + 1, "layering-elastic",
                                 "only sched/, sim/ and serve/ may name "
                                 "sched::elastic directly (use the "
                                 "crate::sched re-exports)"))

    # --- no unwrap/expect in the worker dispatch path ---
    for fname in DISPATCH_PATH_FNS.get(rel, []):
        span = fn_span(code, fname)
        if span is None:
            findings.append((rel, 1, "dispatch-unwrap",
                             f"dispatch-path fn `{fname}` not found (update repolint)"))
            continue
        for i in range(span[0], span[1] + 1):
            line = code[i]
            for m in re.finditer(r"\.unwrap\(\)", line):
                before = line[:m.start()].rstrip()
                if before.endswith(".lock()") or re.search(r"\.wait\([^()]*\)$", before):
                    continue
                findings.append((rel, i + 1, "dispatch-unwrap",
                                 f"`.unwrap()` in dispatch-path fn `{fname}` "
                                 "outside the poisoned-lock idiom"))
            if re.search(r"\.expect\(", line):
                findings.append((rel, i + 1, "dispatch-unwrap",
                                 f"`.expect(...)` in dispatch-path fn `{fname}`"))

        # --- obs recording on the dispatch path is lock-free ---
        # A trace/metrics call must never acquire a lock: the statement
        # containing a record call (hit line extended forward to the
        # terminating `;`) may not contain `.lock(`. Holding a lock
        # *around* a record is fine -- the obs API itself acquires
        # nothing.
        i = span[0]
        while i <= span[1]:
            line = code[i]
            if not ("obs::" in line or "trace::record" in line
                    or "record_trace" in line):
                i += 1
                continue
            j = i
            while j < span[1] and not code[j].rstrip().endswith(";"):
                j += 1
            if any(".lock(" in code[k] for k in range(i, j + 1)):
                findings.append((rel, i + 1, "obs-lockfree",
                                 f"obs record in dispatch-path fn `{fname}` "
                                 "shares a statement with `.lock(` -- trace "
                                 "and metrics calls must stay lock-free"))
            i = j + 1


def main():
    ranks = parse_ranks(os.path.join(ROOT, "rust/src/sched/ranks.rs"))
    missing = [c for c in RANK_FIELDS.values() if c not in ranks]
    if missing:
        print(f"repolint: rank consts missing from ranks.rs: {missing}")
        return 1
    findings = []
    roots = ["rust/src", "rust/tests", "rust/benches", "examples",
             "tools/repolint/src"]
    for top in roots:
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, top)):
            dirnames[:] = [d for d in dirnames if d not in ("vendor", "target")]
            for f in sorted(filenames):
                if not f.endswith(".rs"):
                    continue
                p = os.path.join(dirpath, f)
                rel = os.path.relpath(p, ROOT)
                with open(p) as fh:
                    lint_file(rel, fh.read(), ranks, findings)
    for (rel, line, rule, msg) in findings:
        print(f"{rel}:{line}: [{rule}] {msg}")
    print(f"repolint(prototype): {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
