//! Cross-module integration: the full scheduler matrix (11 schemes × 4
//! layouts × 4 victims) drives both evaluated apps correctly, and the
//! DES reproduces the paper's qualitative orderings at small scale.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use daphne_sched::apps::{cc, linreg};
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, scale_up, GraphSpec};
use daphne_sched::sched::{Executor, JobSpec, QueueLayout, Scheme, VictimStrategy};
use daphne_sched::sim::{self, CostModel, Workload};
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn host2() -> Topology {
    Topology::symmetric("t", 2, 1, 1.5, 1.0)
}

/// The three queue layouts of Fig. 4 (the centralized one in both its
/// locked and atomic variants).
const ALL_LAYOUTS: [QueueLayout; 4] = [
    QueueLayout::Centralized { atomic: false },
    QueueLayout::Centralized { atomic: true },
    QueueLayout::PerGroup,
    QueueLayout::PerCore,
];

fn hit_counters(n: usize) -> Vec<AtomicUsize> {
    (0..n).map(|_| AtomicUsize::new(0)).collect()
}

fn assert_exactly_once(hits: &[AtomicUsize], ctx: &str) {
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 1, "{ctx}: item {i} ran != once");
    }
}

/// Partitioning invariant under pool reuse: ≥3 consecutive jobs on one
/// persistent executor, every item of every job handed out exactly
/// once, for all queue layouts.
#[test]
fn pool_reuse_preserves_partitioning_across_consecutive_jobs() {
    for layout in ALL_LAYOUTS {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Fac2)
            .with_layout(layout)
            .with_victim(VictimStrategy::SeqPri);
        let exec = Executor::new(
            Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
            Arc::new(cfg),
        );
        for (job, total) in [4_001usize, 9_999, 1, 6_500].iter().enumerate() {
            let hits = hit_counters(*total);
            let report = exec.run(JobSpec::new(*total), |_w, r| {
                for i in r.iter() {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert_eq!(report.total_items(), *total, "{layout:?} job {job}");
            assert_exactly_once(&hits, &format!("{layout:?} job {job}"));
        }
        assert_eq!(exec.jobs_completed(), 4);
    }
}

/// Partitioning invariant under multiplexing: two jobs submitted
/// concurrently to the same executor both complete with full item
/// coverage, for all queue layouts.
#[test]
fn two_concurrent_jobs_cover_all_items_on_one_pool() {
    for layout in ALL_LAYOUTS {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Gss)
            .with_layout(layout)
            .with_victim(VictimStrategy::Rnd);
        let exec = Executor::new(
            Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
            Arc::new(cfg),
        );
        let a = hit_counters(8_000);
        let b = hit_counters(5_432);
        exec.scope(|s| {
            let ha = s.submit(JobSpec::new(a.len()).named("job-a"), |_w, r| {
                for i in r.iter() {
                    a[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            let hb = s.submit(JobSpec::new(b.len()).named("job-b"), |_w, r| {
                for i in r.iter() {
                    b[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert_eq!(ha.wait().total_items(), a.len(), "{layout:?}");
            assert_eq!(hb.wait().total_items(), b.len(), "{layout:?}");
        });
        assert_exactly_once(&a, &format!("{layout:?} concurrent job a"));
        assert_exactly_once(&b, &format!("{layout:?} concurrent job b"));
    }
}

/// Two full app pipelines submitted concurrently from separate threads
/// onto one shared engine produce the same results as isolated runs.
#[test]
fn concurrent_app_pipelines_on_shared_engine_match_isolated_runs() {
    let g = amazon_like(&GraphSpec::small(400, 2)).symmetrize();
    let expected =
        cc::run_native(&g, &host2(), &SchedConfig::default(), 100).labels;
    let vee = Vee::new(
        Topology::symmetric("t4", 1, 4, 1.0, 1.0),
        SchedConfig::default().with_scheme(Scheme::Mfsc),
    );
    let results: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| s.spawn(|| cc::run_with(&vee, &g, 100).labels))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for labels in results {
        assert_eq!(labels, expected);
    }
}

#[test]
fn full_config_matrix_runs_cc_correctly() {
    let g = amazon_like(&GraphSpec::small(400, 2)).symmetrize();
    let expected =
        cc::run_native(&g, &host2(), &SchedConfig::default(), 100).labels;
    let layouts = [
        QueueLayout::Centralized { atomic: false },
        QueueLayout::Centralized { atomic: true },
        QueueLayout::PerGroup,
        QueueLayout::PerCore,
    ];
    for scheme in Scheme::ALL {
        for layout in layouts {
            for victim in VictimStrategy::ALL {
                let cfg = SchedConfig {
                    scheme,
                    layout,
                    victim,
                    seed: 99,
                    stages: None,
                    pls_swr: 0.5,
                };
                let got = cc::run_native(&g, &host2(), &cfg, 100);
                assert_eq!(
                    got.labels, expected,
                    "{scheme:?}/{layout:?}/{victim:?}"
                );
                // stealing layouts only steal when legal
                if !layout.steals() {
                    assert_eq!(got.reports[0].total_steals(), 0);
                }
            }
        }
    }
}

#[test]
fn scaled_graph_has_k_times_components() {
    let g = amazon_like(&GraphSpec::small(150, 8)).symmetrize();
    let scaled = scale_up(&g, 4);
    let r = cc::run_native(&scaled, &host2(), &SchedConfig::default(), 100);
    assert_eq!(r.components, 4, "4 disjoint copies = 4 components");
}

#[test]
fn des_reproduces_fig7_ordering_smallscale() {
    // Sparse CC workload on modelled Broadwell under the figure
    // environment (DAPHNE-like dispatch costs + OS interference): MFSC
    // must beat STATIC (the paper's headline Fig. 7a result). Averaged
    // over iterations like the figure harness.
    let g = amazon_like(&GraphSpec::small(200_000, 1)).symmetrize();
    let topo = Topology::broadwell20();
    let costs = CostModel::daphne_like();
    let base = SchedConfig::default().with_seed(1);
    let (t_static, _) = cc::simulate_run(
        &g,
        &topo,
        &base.clone().with_scheme(Scheme::Static),
        &costs,
        10,
        10.3e-9,
        1.1e-9,
    );
    let (t_mfsc, _) = cc::simulate_run(
        &g,
        &topo,
        &base.clone().with_scheme(Scheme::Mfsc),
        &costs,
        10,
        10.3e-9,
        1.1e-9,
    );
    assert!(
        t_mfsc < t_static,
        "MFSC {t_mfsc} must beat STATIC {t_static} on sparse CC"
    );
}

#[test]
fn des_reproduces_fig10_ordering_smallscale() {
    // Dense LR workload: STATIC must beat the fine-grained dynamic
    // schemes (Fig. 10) because scheduling overhead is pure loss.
    let topo = Topology::broadwell20();
    let costs = CostModel::recorded();
    let w = linreg::workload(200_000, 3e-8);
    let time = |scheme: Scheme| {
        sim::simulate(
            &topo,
            &SchedConfig::default().with_scheme(scheme),
            &w,
            &costs,
        )
        .makespan()
    };
    let t_static = time(Scheme::Static);
    for scheme in [Scheme::Mfsc, Scheme::Tfss, Scheme::Pls, Scheme::Pss] {
        let t = time(scheme);
        assert!(
            t >= t_static * 0.98,
            "{scheme:?} ({t}) must not beat STATIC ({t_static}) on dense LR"
        );
    }
}

#[test]
fn des_ss_explodes_on_central_queue() {
    // §4: SS execution time "explodes" under central-queue contention —
    // the reason it is omitted from Figs. 7-10.
    let topo = Topology::cascadelake56();
    let costs = CostModel::recorded();
    let w = Workload::uniform("u", 500_000, 1e-8);
    let t_ss = sim::simulate(
        &topo,
        &SchedConfig::default().with_scheme(Scheme::Ss),
        &w,
        &costs,
    )
    .makespan();
    let t_gss = sim::simulate(
        &topo,
        &SchedConfig::default().with_scheme(Scheme::Gss),
        &w,
        &costs,
    )
    .makespan();
    assert!(
        t_ss > 10.0 * t_gss,
        "SS ({t_ss}) must explode vs GSS ({t_gss})"
    );
}

#[test]
fn linreg_beta_invariant_across_machines() {
    let (x, y) = linreg::generate(&linreg::LinregSpec {
        rows: 1200,
        cols: 9,
        lambda: 1e-3,
        seed: 5,
    });
    let a = linreg::run_native(&x, &y, 1e-3, &host2(), &SchedConfig::default())
        .unwrap()
        .beta;
    let b = linreg::run_native(
        &x,
        &y,
        1e-3,
        &Topology::symmetric("t4", 1, 4, 1.0, 1.0),
        &SchedConfig::default().with_scheme(Scheme::Fac2),
    )
    .unwrap()
    .beta;
    for (i, (p, q)) in a.iter().zip(&b).enumerate() {
        assert!((p - q).abs() < 1e-3, "beta[{i}]: {p} vs {q}");
    }
}
