//! Victim selection for work-stealing (paper §2): SEQ, SEQPRI, RND,
//! RNDPRI.
//!
//! - **SEQ**: round-robin search starting from the thief's position in
//!   the system topology \[Perarnau & Sato, IPDPS'14\].
//! - **SEQPRI**: like SEQ but victims in the thief's own NUMA domain are
//!   searched first (preserves locality, minimises inter-socket traffic).
//! - **RND**: uniformly random victim order.
//! - **RNDPRI**: random order within the thief's NUMA domain first, then
//!   random order over the rest.

use crate::util::Rng;

/// The four victim-selection strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VictimStrategy {
    Seq,
    SeqPri,
    Rnd,
    RndPri,
}

impl VictimStrategy {
    pub const ALL: [VictimStrategy; 4] = [
        VictimStrategy::Seq,
        VictimStrategy::SeqPri,
        VictimStrategy::Rnd,
        VictimStrategy::RndPri,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            VictimStrategy::Seq => "SEQ",
            VictimStrategy::SeqPri => "SEQPRI",
            VictimStrategy::Rnd => "RND",
            VictimStrategy::RndPri => "RNDPRI",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "SEQ" => Some(VictimStrategy::Seq),
            "SEQPRI" => Some(VictimStrategy::SeqPri),
            "RND" | "RAND" | "RANDOM" => Some(VictimStrategy::Rnd),
            "RNDPRI" | "RANDPRI" => Some(VictimStrategy::RndPri),
            _ => None,
        }
    }
}

/// Per-thief victim picker. Owns the thief's round-robin cursor (SEQ*)
/// and RNG stream (RND*), so selection is deterministic per seed.
#[derive(Debug)]
pub struct VictimSelector {
    strategy: VictimStrategy,
    /// The thief's own queue (never a candidate).
    own_queue: usize,
    /// NUMA domain of every queue.
    queue_socket: Vec<usize>,
    /// The thief's NUMA domain.
    my_socket: usize,
    /// Persistent round-robin cursor (SEQ/SEQPRI).
    cursor: usize,
    rng: Rng,
}

impl VictimSelector {
    pub fn new(
        strategy: VictimStrategy,
        own_queue: usize,
        my_socket: usize,
        queue_socket: Vec<usize>,
        seed: u64,
    ) -> Self {
        let cursor = (own_queue + 1) % queue_socket.len().max(1);
        VictimSelector {
            strategy,
            own_queue,
            queue_socket,
            my_socket,
            cursor,
            rng: Rng::new(seed),
        }
    }

    fn n_queues(&self) -> usize {
        self.queue_socket.len()
    }

    /// Candidate victim queues for one steal round, in preference order.
    /// Every other queue appears exactly once, so a full round visits the
    /// whole system (termination guarantee for the steal loop).
    pub fn round(&mut self) -> Vec<usize> {
        let n = self.n_queues();
        if n <= 1 {
            return Vec::new();
        }
        match self.strategy {
            VictimStrategy::Seq => {
                let start = self.cursor;
                let order: Vec<usize> = (0..n)
                    .map(|i| (start + i) % n)
                    .filter(|&q| q != self.own_queue)
                    .collect();
                self.cursor = (self.cursor + 1) % n;
                order
            }
            VictimStrategy::SeqPri => {
                let start = self.cursor;
                let rotated: Vec<usize> = (0..n)
                    .map(|i| (start + i) % n)
                    .filter(|&q| q != self.own_queue)
                    .collect();
                let (mut local, remote): (Vec<usize>, Vec<usize>) = rotated
                    .into_iter()
                    .partition(|&q| self.queue_socket[q] == self.my_socket);
                self.cursor = (self.cursor + 1) % n;
                local.extend(remote);
                local
            }
            VictimStrategy::Rnd => {
                let mut order: Vec<usize> =
                    (0..n).filter(|&q| q != self.own_queue).collect();
                self.rng.shuffle(&mut order);
                order
            }
            VictimStrategy::RndPri => {
                let (mut local, mut remote): (Vec<usize>, Vec<usize>) = (0..n)
                    .filter(|&q| q != self.own_queue)
                    .partition(|&q| self.queue_socket[q] == self.my_socket);
                self.rng.shuffle(&mut local);
                self.rng.shuffle(&mut remote);
                local.extend(remote);
                local
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn two_socket_queues(per_socket: usize) -> Vec<usize> {
        (0..2 * per_socket).map(|q| q / per_socket).collect()
    }

    #[test]
    fn seq_rotates_round_robin() {
        let mut v = VictimSelector::new(
            VictimStrategy::Seq,
            0,
            0,
            two_socket_queues(2), // queues 0,1 on s0; 2,3 on s1
            1,
        );
        let r1 = v.round();
        assert_eq!(r1, vec![1, 2, 3]);
        let r2 = v.round();
        assert_eq!(r2, vec![2, 3, 1]); // cursor advanced
    }

    #[test]
    fn seqpri_prefers_same_socket() {
        let mut v = VictimSelector::new(
            VictimStrategy::SeqPri,
            0,
            0,
            two_socket_queues(4), // 0-3 on s0, 4-7 on s1
            1,
        );
        let r = v.round();
        // first candidates all on socket 0
        assert!(r[..3].iter().all(|&q| q < 4), "{r:?}");
        assert!(r[3..].iter().all(|&q| q >= 4), "{r:?}");
    }

    #[test]
    fn rndpri_partitions_by_socket() {
        let mut v = VictimSelector::new(
            VictimStrategy::RndPri,
            5, // on socket 1
            1,
            two_socket_queues(4),
            7,
        );
        let r = v.round();
        assert_eq!(r.len(), 7);
        assert!(r[..3].iter().all(|&q| q >= 4), "{r:?}");
        assert!(r[3..].iter().all(|&q| q < 4), "{r:?}");
    }

    #[test]
    fn rnd_is_seeded() {
        let mk = || {
            VictimSelector::new(
                VictimStrategy::Rnd,
                0,
                0,
                two_socket_queues(8),
                99,
            )
        };
        let (mut a, mut b) = (mk(), mk());
        assert_eq!(a.round(), b.round());
        assert_eq!(a.round(), b.round());
    }

    #[test]
    fn single_queue_has_no_victims() {
        for s in VictimStrategy::ALL {
            let mut v = VictimSelector::new(s, 0, 0, vec![0], 1);
            assert!(v.round().is_empty(), "{s:?}");
        }
    }

    #[test]
    fn prop_round_visits_every_other_queue_once() {
        prop::check("victim round is a permutation", 100, |rng| {
            let strategy = *rng.choose(&VictimStrategy::ALL);
            let per_socket = rng.range(1, 8) as usize;
            let sockets = rng.range(1, 4) as usize;
            let n = per_socket * sockets;
            let queue_socket: Vec<usize> =
                (0..n).map(|q| q / per_socket).collect();
            let own = rng.index(n);
            let mut v = VictimSelector::new(
                strategy,
                own,
                queue_socket[own],
                queue_socket,
                rng.next_u64(),
            );
            let mut r = v.round();
            prop::ensure(!r.contains(&own), format!("{strategy:?}: steals self"))?;
            r.sort_unstable();
            let expect: Vec<usize> = (0..n).filter(|&q| q != own).collect();
            prop::ensure(r == expect, format!("{strategy:?}: not a permutation"))
        });
    }

    #[test]
    fn parse_roundtrip() {
        for s in VictimStrategy::ALL {
            assert_eq!(VictimStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(VictimStrategy::parse("bogus"), None);
    }
}
