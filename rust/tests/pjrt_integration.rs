//! Integration: AOT JAX/Pallas artifacts executed from rust via PJRT,
//! validated against the native rust kernels. Requires `make artifacts`
//! (tests skip with a notice if artifacts are absent).

use daphne_sched::apps::{cc, linreg};
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::matrix::DenseMatrix;
use daphne_sched::runtime::{DeviceService, Runtime};
use daphne_sched::sched::{QueueLayout, Scheme};
use daphne_sched::topology::Topology;
use daphne_sched::util::Rng;

fn artifacts_ready() -> bool {
    let ok = Runtime::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
    }
    ok
}

fn topo() -> Topology {
    Topology::symmetric("t", 1, 2, 1.0, 1.0)
}

#[test]
fn device_service_runs_cc_propagate_tile() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (rows, cols) = service.manifest.cc_block;
    // G = single edge row0 -> col3; ids = index+1
    let mut g = vec![0f32; rows * cols];
    g[3] = 1.0;
    let c: Vec<f32> = (0..cols).map(|i| (i + 1) as f32).collect();
    let c_row: Vec<f32> = (0..rows).map(|i| (i + 1) as f32).collect();
    let out = client
        .run_f32("cc_propagate", vec![g, c.clone(), c_row.clone()])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), rows);
    // row 0: max(own id 1, neighbour id 4) = 4; all others keep own id
    assert_eq!(out[0][0], 4.0);
    for (i, &v) in out[0].iter().enumerate().skip(1) {
        assert_eq!(v, (i + 1) as f32, "row {i}");
    }
}

#[test]
fn device_service_concurrent_clients() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (rows, cols) = service.manifest.lr_block;
    let mut rng = Rng::new(11);
    let x = DenseMatrix::rand(rows, cols, 0.0, 1.0, rng.next_u64());
    std::thread::scope(|s| {
        for _ in 0..4 {
            let client = client.clone();
            let x = x.data.clone();
            s.spawn(move || {
                let out = client.run_f32("lr_colstats", vec![x]).unwrap();
                assert_eq!(out.len(), 2);
                assert_eq!(out[0].len(), cols);
            });
        }
    });
}

#[test]
fn pjrt_cc_matches_native_labels() {
    if !artifacts_ready() {
        return;
    }
    let g = amazon_like(&SnapGraph::small(300, 21)).symmetrize();
    let (service, client) = DeviceService::start_default().unwrap();
    let sched = SchedConfig::default().with_scheme(Scheme::Gss);
    let native = cc::run_native(&g, &topo(), &sched, 100);
    let pjrt = cc::run_pjrt(
        &g,
        &client,
        &service.manifest,
        &topo(),
        &sched,
        100,
    )
    .unwrap();
    assert_eq!(native.labels, pjrt.labels);
    assert_eq!(native.iterations, pjrt.iterations);
    assert_eq!(native.components, pjrt.components);
}

#[test]
fn pjrt_linreg_matches_native_beta() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (_, d) = service.manifest.lr_block;
    let n = 1024;
    let mut rng = Rng::new(5);
    let x = DenseMatrix::rand(n, d, 0.0, 1.0, rng.next_u64());
    let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let sched = SchedConfig::default()
        .with_scheme(Scheme::Fac2)
        .with_layout(QueueLayout::PerCore);
    let native = linreg::run_native(&x, &y, 1e-3, &topo(), &sched).unwrap();
    let pjrt = linreg::run_pjrt(
        &x,
        &y,
        1e-3,
        &client,
        &service.manifest,
        &topo(),
        &sched,
    )
    .unwrap();
    assert_eq!(native.beta.len(), pjrt.beta.len());
    for (i, (a, b)) in native.beta.iter().zip(&pjrt.beta).enumerate() {
        assert!(
            (a - b).abs() < 5e-2 * a.abs().max(1.0),
            "beta[{i}]: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn pjrt_linreg_handles_padding_tail() {
    // n not a multiple of the block: the closed-form padding correction
    // must keep A/b exact.
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (block_rows, d) = service.manifest.lr_block;
    let n = block_rows + 37;
    let mut rng = Rng::new(9);
    let x = DenseMatrix::rand(n, d, 0.0, 1.0, rng.next_u64());
    let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let sched = SchedConfig::default();
    let native = linreg::run_native(&x, &y, 1e-3, &topo(), &sched).unwrap();
    let pjrt = linreg::run_pjrt(
        &x,
        &y,
        1e-3,
        &client,
        &service.manifest,
        &topo(),
        &sched,
    )
    .unwrap();
    for (i, (a, b)) in native.beta.iter().zip(&pjrt.beta).enumerate() {
        assert!(
            (a - b).abs() < 5e-2 * a.abs().max(1.0),
            "beta[{i}]: native {a} vs pjrt {b}"
        );
    }
}
