//! The heterogeneous diamond pipeline (à la Trident): a mixed-modality
//! IDA job whose two middle stages want *different* device classes.
//!
//! ```text
//!            ┌─ dense  (regular tensor work: accelerator-friendly) ─┐
//!   prep ────┤                                                      ├── join
//!            └─ sparse (irregular, branchy: CPU-friendly) ──────────┘
//! ```
//!
//! Trident's argument — echoed by the data-aware irregular-workload
//! line of work in PAPERS.md — is that a heterogeneous pipeline's
//! placement is a first-class scheduling decision: the dense branch is
//! regular enough to saturate an accelerator while the sparse branch's
//! skewed per-item costs want the CPU pool's width and work-stealing.
//! This module provides that pipeline in cost-described
//! ([`GraphShape`]) form for virtual-time replay on the modelled
//! heterogeneous machines
//! ([`Topology::hetero20`](crate::topology::Topology::hetero20) /
//! [`Topology::hetero56`](crate::topology::Topology::hetero56)), under
//! three placement policies:
//!
//! - [`diamond_shape`] — every node `Placement::Any`, i.e. the all-CPU
//!   baseline (the accelerator pool idles);
//! - [`pinned_diamond`] — the hand-placed assignment: `dense` on the
//!   accelerator class, `sparse` pinned to the CPU pool;
//! - autotuned — feed [`diamond_shape`] to
//!   [`tune_graph`](crate::sched::autotune::tune_graph) with
//!   [`SearchSpace::for_machine`](crate::sched::autotune::SearchSpace)
//!   so placement is the fourth tuned dimension.
//!
//! `figure hetero` compares the three on both modelled machines; the
//! `tune graph=hetero` CLI surface runs the autotuned variant.

use crate::sim::{GraphShape, NodeModel, Workload};
use crate::topology::DeviceClass;

/// Per-item virtual costs of the shape, scaled by the CPU pool width
/// `w` so the branches keep every worker busy on any modelled machine.
///
/// Branch totals are deliberately comparable (`dense ≈ 0.9 × sparse`):
/// on the modelled machines the accelerator pool's throughput is below
/// the CPU pool's (e.g. 8 devices × 4× < 56 cores on `hetero56`), so
/// offloading the dense branch pays off precisely because it *frees the
/// CPU pool for the sparse branch*, not because the accelerator is
/// faster outright — the regime Trident's adaptive split targets.
fn nodes(w: usize) -> [NodeModel; 4] {
    // sparse: heavy-tailed per-item costs (hub rows first), the CC-like
    // irregular profile where work-stealing earns its keep
    let sparse_costs: Vec<f64> = (0..w * 32)
        .map(|i| if i < w * 4 { 4e-4 } else { 1e-4 })
        .collect();
    [
        NodeModel::uniform("prep", w * 64, 2e-6),
        NodeModel::uniform("dense", w * 8, 5e-4).after("prep"),
        NodeModel::new("sparse", Workload::from_costs("sparse", &sparse_costs))
            .after("prep"),
        NodeModel::uniform("join", w * 16, 2e-6)
            .after("dense")
            .after("sparse"),
    ]
}

/// The heterogeneous diamond with no placement constraints: every node
/// `Placement::Any`, so on a heterogeneous machine the whole pipeline
/// runs on the CPU pool — the baseline placement-aware dispatch is
/// measured against. `cpu_cores` is the machine's CPU pool width.
pub fn diamond_shape(cpu_cores: usize) -> GraphShape {
    let [prep, dense, sparse, join] = nodes(cpu_cores);
    GraphShape::new("hetero-diamond")
        .node(prep)
        .node(dense)
        .node(sparse)
        .node(join)
}

/// The hand-pinned assignment: the dense branch on `accel`'s pool, the
/// sparse branch pinned to the CPU pool (prep/join stay `Any`). Replay
/// rejects it with `GraphError::NoSuchPool` on machines without an
/// `accel` pool — pass a class the topology provides.
pub fn pinned_diamond(cpu_cores: usize, accel: DeviceClass) -> GraphShape {
    let [prep, dense, sparse, join] = nodes(cpu_cores);
    GraphShape::new("hetero-diamond-pinned")
        .node(prep)
        .node(dense.on(accel))
        .node(sparse.on(DeviceClass::Cpu))
        .node(join)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphMode, SchedConfig};
    use crate::sim::{replay, CostModel};
    use crate::topology::{DeviceClass, Topology};

    #[test]
    fn shapes_validate_and_mirror_each_other() {
        let any = diamond_shape(56);
        let pinned = pinned_diamond(56, DeviceClass::Gpu);
        assert!(any.validate().is_ok());
        assert!(pinned.validate().is_ok());
        assert_eq!(
            any.node_names().collect::<Vec<_>>(),
            vec!["prep", "dense", "sparse", "join"]
        );
        // same nodes, same costs — only the placements differ
        assert!((any.total_cost() - pinned.total_cost()).abs() < 1e-12);
        // branch totals comparable (dense slightly lighter)
        let cost = |s: &GraphShape, n: &str| {
            s.nodes()
                .iter()
                .find(|m| m.name == n)
                .unwrap()
                .workload
                .total_cost()
        };
        let ratio = cost(&any, "dense") / cost(&any, "sparse");
        assert!((0.7..1.1).contains(&ratio), "dense/sparse = {ratio}");
    }

    #[test]
    fn pinned_beats_all_cpu_on_the_modelled_hetero_machines() {
        let sched = SchedConfig::default();
        let costs = CostModel::recorded();
        for topo in [Topology::hetero20(), Topology::hetero56()] {
            let w = topo.class_cores(DeviceClass::Cpu);
            let any =
                replay(&diamond_shape(w), &topo, &sched, &costs, GraphMode::Dag)
                    .unwrap();
            let pinned = replay(
                &pinned_diamond(w, DeviceClass::Gpu),
                &topo,
                &sched,
                &costs,
                GraphMode::Dag,
            )
            .unwrap();
            assert_eq!(
                pinned.node("dense").unwrap().device,
                DeviceClass::Gpu
            );
            assert_eq!(any.node("dense").unwrap().device, DeviceClass::Cpu);
            assert!(
                pinned.makespan() < any.makespan(),
                "{}: pinned {} vs all-cpu {}",
                topo.name,
                pinned.makespan(),
                any.makespan()
            );
            // the branches genuinely overlap across pools
            let d = pinned.node("dense").unwrap();
            let s = pinned.node("sparse").unwrap();
            assert!(d.start < s.finish && s.start < d.finish);
        }
    }

    #[test]
    fn pinning_on_a_cpu_only_machine_is_rejected() {
        let err = replay(
            &pinned_diamond(20, DeviceClass::Gpu),
            &Topology::broadwell20(),
            &SchedConfig::default(),
            &CostModel::recorded(),
            GraphMode::Dag,
        )
        .unwrap_err();
        assert!(matches!(
            err,
            crate::sched::GraphError::NoSuchPool { .. }
        ));
    }
}
