//! Multi-tenant session submission: many pipelines, one resident pool.
//!
//! The evaluation harness of the paper runs one IDA pipeline at a time,
//! but a scheduler serving many users multiplexes *competing* pipelines
//! over the same workers — the regime where Canary argues for fusing
//! work into a single scheduler view instead of thread-per-client
//! submission, and where Trident shows policy-aware interleaving of
//! heterogeneous pipelines wins. This module is that surface:
//!
//! - [`Session`] ([`Executor::session`]) — a submission context on the
//!   resident pool. [`Session::submit_graph`] attaches
//!   [`SubmitOpts`] (priority, weight, tag) to a whole task graph;
//!   [`Session::submit_all`] / [`Session::run_all`] **fuse** a batch of
//!   pipelines into one merged scheduling horizon: every graph is
//!   validated before anything dispatches, then all of their root nodes
//!   enter the run queue together, so the cross-job pick policy — not
//!   submission interleaving — decides execution order.
//! - [`TenancyPolicy`] — the pluggable cross-job pick policy the
//!   executor's workers apply at task-acquisition time (and, because
//!   dependents enter the same policy-ordered run queue the moment
//!   their in-edges complete, at dependent-enqueue time too):
//!   - `Fifo` — oldest submission first; a worker drains one job's
//!     source before moving on (the pre-session behaviour).
//!   - `Fair` — weighted fair sharing over *tags*: workers serve the
//!     tag with the least executed-items-per-weight among the live
//!     jobs of their pool, re-evaluated every few tasks, so
//!     concurrent tenants make proportional progress.
//!   - `Priority` — strict levels (higher first) with aging: a job
//!     gains one effective level per [`AGING_QUANTUM_SECS`] it has
//!     waited *since it was last served* (service resets the clock,
//!     so an actively-served job never out-ages a late high-priority
//!     arrival), bounding starvation of low-priority tenants.
//! - First-class cancellation —
//!   [`JobHandle::cancel`](super::JobHandle::cancel) /
//!   [`GraphHandle::cancel`](super::GraphHandle::cancel) reuse the
//!   panic-abort drain path to drop a tenant's undispatched work and
//!   free the pool for the tenants queued behind it (running task
//!   bodies finish; they are never interrupted mid-call).
//!
//! The DES mirrors the whole policy surface in virtual time
//! ([`crate::sim::graph::replay_tenants`]), which is what `figure
//! tenancy` and [`crate::sched::autotune::tune_tenancy`] predict with.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::executor::{publish_pool_widths, Executor, JobHandle, JobSpec};
use super::graph::{
    dispatch, wait_terminal, GraphError, GraphHandle, GraphReport, GraphSpec,
};
use crate::obs::trace::{self, TraceKind, NO_JOB, OBS_CONTROL_WORKER};

/// Aging quantum for [`TenancyPolicy::Priority`]: a job gains one
/// effective priority level per this many seconds (wall-clock on the
/// executor, virtual seconds in the DES) spent waiting *since it was
/// last served*, bounding starvation. Serving a job resets its aging
/// clock, so aging can never freeze the relative order of two live
/// jobs — a late high-priority arrival always outranks a tenant the
/// pool is actively serving.
pub const AGING_QUANTUM_SECS: f64 = 1.0;

/// Cross-job pick policy: which live job a worker serves next when
/// several tenants' task sources are queued on its pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TenancyPolicy {
    /// Oldest submission first (default; the pre-session behaviour).
    #[default]
    Fifo,
    /// Weighted fair sharing over tags: serve the tag with the least
    /// executed items per unit weight among the pool's live jobs.
    Fair,
    /// Strict priority levels (higher first), with one level of aging
    /// per [`AGING_QUANTUM_SECS`] waited.
    Priority,
}

impl TenancyPolicy {
    pub const ALL: [TenancyPolicy; 3] = [
        TenancyPolicy::Fifo,
        TenancyPolicy::Fair,
        TenancyPolicy::Priority,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TenancyPolicy::Fifo => "fifo",
            TenancyPolicy::Fair => "fair",
            TenancyPolicy::Priority => "priority",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(TenancyPolicy::Fifo),
            "fair" | "wrr" => Some(TenancyPolicy::Fair),
            "priority" | "prio" => Some(TenancyPolicy::Priority),
            _ => None,
        }
    }
}

/// Admission policy: what [`Session::try_submit_graph`] does when the
/// submitting tag's live-job backlog is already deep. `Open` is today's
/// accept-everything behaviour; `Bounded` and `Shed` make a saturated
/// service degrade predictably (bounded queueing delay, counted
/// rejections) instead of queueing unboundedly — the serving loop
/// ([`crate::serve`]) and its DES mirror
/// ([`crate::sim::serve::replay_open_loop`]) apply the *same* rule, so
/// `figure serve` predicts real shed rates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Accept every submission (the pre-serve default).
    Open,
    /// Reject when the backlog already holds `max_backlog` entries.
    Bounded { max_backlog: usize },
    /// Reject when the estimated queueing delay (backlog × the
    /// submitter's per-entry cost estimate) exceeds `deadline` seconds.
    Shed { deadline: f64 },
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::Open
    }
}

impl AdmissionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Open => "open",
            AdmissionPolicy::Bounded { .. } => "bounded",
            AdmissionPolicy::Shed { .. } => "shed",
        }
    }

    /// Parse a policy name, taking the bound / deadline from the caller
    /// (they arrive as separate config keys: `max_backlog=`,
    /// `deadline_ms=`).
    pub fn parse(s: &str, max_backlog: usize, deadline: f64) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "open" => Some(AdmissionPolicy::Open),
            "bounded" => Some(AdmissionPolicy::Bounded { max_backlog }),
            "shed" => Some(AdmissionPolicy::Shed { deadline }),
            _ => None,
        }
    }

    /// The admission rule itself, shared verbatim by the real serving
    /// loop and the DES: given the submitting tag's current backlog
    /// depth and the estimated wait behind it (`backlog ×
    /// est-cost-per-entry`, in the caller's clock), may this submission
    /// enter?
    pub fn admits(&self, backlog: usize, est_wait: f64) -> bool {
        match self {
            AdmissionPolicy::Open => true,
            AdmissionPolicy::Bounded { max_backlog } => backlog < *max_backlog,
            AdmissionPolicy::Shed { deadline } => est_wait <= *deadline,
        }
    }
}

/// Per-submission tenancy options: how the cross-job pick policy
/// weighs this tenant's work against the other live tenants.
#[derive(Debug, Clone)]
pub struct SubmitOpts {
    /// Priority level for [`TenancyPolicy::Priority`] (higher runs
    /// first; default 0).
    pub priority: i64,
    /// Share weight for [`TenancyPolicy::Fair`] (default 1; a tag with
    /// weight 2 is served twice the items per scheduling decision).
    pub weight: u64,
    /// Tenant tag: [`TenancyPolicy::Fair`] shares the pool *between
    /// tags*, so every graph submitted under one tag counts against
    /// one fair share. Empty (default) = the anonymous tenant.
    pub tag: String,
    /// Admission policy applied by [`Session::try_submit_graph`]
    /// against this tag's live-job backlog (default [`Open`]
    /// (AdmissionPolicy::Open); plain `submit_graph` ignores it).
    pub admission: AdmissionPolicy,
    /// Estimated service seconds per backlog entry, used by
    /// [`AdmissionPolicy::Shed`] to turn backlog depth into an
    /// estimated wait (default 0.0 = Shed never rejects).
    pub est_cost: f64,
    /// Moldable width range `(min, max)` in workers: `Some` declares
    /// that this tenant tolerates its pool being resized while it runs
    /// — and, crucially, that its jobs may execute on *borrowed*
    /// workers lent from another pool ([`Session::lend`] / the elastic
    /// scaling controller). `None` (default) pins the work to its
    /// pool's own workers; a pinned arrival snaps outstanding leases
    /// back (see [`crate::sched::elastic`]).
    pub moldable: Option<(usize, usize)>,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        SubmitOpts {
            priority: 0,
            weight: 1,
            tag: String::new(),
            admission: AdmissionPolicy::Open,
            est_cost: 0.0,
            moldable: None,
        }
    }
}

impl SubmitOpts {
    pub fn new() -> Self {
        SubmitOpts::default()
    }

    pub fn priority(mut self, priority: i64) -> Self {
        self.priority = priority;
        self
    }

    pub fn weight(mut self, weight: u64) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn tag(mut self, tag: &str) -> Self {
        self.tag = tag.to_string();
        self
    }

    pub fn admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    pub fn est_cost(mut self, est_cost: f64) -> Self {
        self.est_cost = est_cost.max(0.0);
        self
    }

    /// Declare the tenant moldable over `min..=max` workers (`min` is
    /// clamped to ≥ 1 and `max` to ≥ `min`): its jobs may run on
    /// borrowed workers and tolerate pool resizes mid-flight.
    pub fn moldable(mut self, min: usize, max: usize) -> Self {
        let min = min.max(1);
        self.moldable = Some((min, max.max(min)));
        self
    }
}

/// Outcome of an admission-checked submission
/// ([`Session::try_submit_graph`]).
#[must_use = "a rejected submission must be counted or retried"]
pub enum Admitted {
    /// The graph was admitted and dispatched.
    Accepted(GraphHandle<'static>),
    /// The graph was rejected (shed) without dispatching anything;
    /// `backlog` is the live-job depth that triggered the decision.
    Rejected { backlog: usize },
}

impl Admitted {
    pub fn is_accepted(&self) -> bool {
        matches!(self, Admitted::Accepted(_))
    }

    /// The handle, if admitted.
    pub fn handle(self) -> Option<GraphHandle<'static>> {
        match self {
            Admitted::Accepted(h) => Some(h),
            Admitted::Rejected { .. } => None,
        }
    }
}

/// Resolved tenancy attached to every job in the run queue (each graph
/// node's job clones its graph's). `arrived` anchors priority aging.
#[derive(Debug, Clone)]
pub(super) struct Tenancy {
    pub(super) priority: i64,
    pub(super) weight: u64,
    pub(super) tag: Arc<str>,
    /// FNV-1a of `tag` (0 = anonymous), carried so trace records on the
    /// dispatch path never touch the string. Interned for the exporter
    /// only while tracing is enabled.
    pub(super) tag_hash: u64,
    pub(super) arrived: Instant,
    /// Whether this tenant's jobs may run on borrowed (foreign-home)
    /// workers — see [`SubmitOpts::moldable`] and
    /// [`crate::sched::elastic`].
    pub(super) moldable: bool,
}

impl Tenancy {
    pub(super) fn from_opts(opts: &SubmitOpts) -> Self {
        let tag_hash = if opts.tag.is_empty() {
            0
        } else if trace::enabled() {
            trace::intern_tag(&opts.tag)
        } else {
            trace::fnv1a(&opts.tag)
        };
        Tenancy {
            priority: opts.priority,
            weight: opts.weight.max(1),
            tag: Arc::from(opts.tag.as_str()),
            tag_hash,
            arrived: Instant::now(),
            moldable: opts.moldable.is_some(),
        }
    }

    /// Priority after aging: one level per quantum of `waited_secs`
    /// (time since the job was last served — see
    /// [`AGING_QUANTUM_SECS`]).
    pub(super) fn effective_priority(&self, waited_secs: f64) -> i64 {
        self.priority
            .saturating_add((waited_secs.max(0.0) / AGING_QUANTUM_SECS) as i64)
    }
}

impl Default for Tenancy {
    fn default() -> Self {
        Tenancy::from_opts(&SubmitOpts::default())
    }
}

/// A multi-tenant submission context on one executor's resident pool.
/// Created by [`Executor::session`]; cheap (borrows the executor), so
/// apps create one per client or one per batch as they like — all
/// sessions of an executor share its run queue and pick policy.
pub struct Session<'e> {
    exec: &'e Executor,
}

impl<'e> Session<'e> {
    pub(super) fn new(exec: &'e Executor) -> Self {
        Session { exec }
    }

    pub fn executor(&self) -> &'e Executor {
        self.exec
    }

    /// Submit one owned-body job under tenancy options.
    pub fn submit<F>(
        &self,
        spec: JobSpec,
        opts: SubmitOpts,
        body: F,
    ) -> JobHandle<'static>
    where
        F: Fn(usize, super::TaskRange) + Send + Sync + 'static,
    {
        self.exec.submit_tenant(spec, Tenancy::from_opts(&opts), body)
    }

    /// Validate and launch one task graph under tenancy options; the
    /// graph keeps running if the handle is dropped.
    pub fn submit_graph(
        &self,
        spec: GraphSpec<'static>,
        opts: SubmitOpts,
    ) -> Result<GraphHandle<'static>, GraphError> {
        let tenancy = Tenancy::from_opts(&opts);
        let (run, roots) = self.exec.prepare_graph(spec, tenancy)?;
        dispatch(&run, &roots);
        Ok(GraphHandle::from_run(run))
    }

    /// Admission-checked submission: consult `opts.admission` against
    /// the tag's current live-job backlog *before* dispatching. A
    /// rejected graph dispatches nothing (its spec is dropped here) and
    /// the decision is returned for the caller to count — the serving
    /// loop's shed-vs-served accounting. Validation errors still
    /// surface as `Err` regardless of the admission decision.
    pub fn try_submit_graph(
        &self,
        spec: GraphSpec<'static>,
        opts: SubmitOpts,
    ) -> Result<Admitted, GraphError> {
        let backlog = self.exec.tag_backlog(&opts.tag);
        let est_wait = backlog as f64 * opts.est_cost;
        crate::obs::metrics()
            .backlog_high_water
            .fetch_max(backlog as u64, Ordering::Relaxed);
        if !opts.admission.admits(backlog, est_wait) {
            // still validate, so a malformed graph is an error — not a
            // silently-counted shed
            let tenancy = Tenancy::from_opts(&opts);
            let tag_hash = tenancy.tag_hash;
            let name_hash = trace::enabled().then(|| trace::intern_tag(&spec.name));
            let (run, _roots) = self.exec.prepare_graph(spec, tenancy)?;
            drop(run);
            // the shed counter is authoritative here (not trace-gated);
            // the trace event only exists while tracing is on
            crate::obs::metrics().shed.fetch_add(1, Ordering::Relaxed);
            if let Some(name_hash) = name_hash {
                trace::record(
                    TraceKind::Shed,
                    OBS_CONTROL_WORKER,
                    NO_JOB,
                    name_hash,
                    tag_hash,
                );
            }
            return Ok(Admitted::Rejected { backlog });
        }
        crate::obs::metrics().admitted.fetch_add(1, Ordering::Relaxed);
        if trace::enabled() {
            let name_hash = trace::intern_tag(&spec.name);
            let tag_hash = if opts.tag.is_empty() {
                0
            } else {
                trace::intern_tag(&opts.tag)
            };
            trace::record(
                TraceKind::Admit,
                OBS_CONTROL_WORKER,
                NO_JOB,
                name_hash,
                tag_hash,
            );
        }
        self.submit_graph(spec, opts).map(Admitted::Accepted)
    }

    /// Fused submission: validate *every* graph, then dispatch all of
    /// their root nodes into one merged scheduling horizon. If any
    /// graph is invalid, nothing dispatches and the whole batch is
    /// rejected — so concurrent tenants never observe a half-submitted
    /// batch. Execution order across the batch is the executor's
    /// [`TenancyPolicy`], not submission order.
    pub fn submit_all(
        &self,
        specs: Vec<(GraphSpec<'static>, SubmitOpts)>,
    ) -> Result<Vec<GraphHandle<'static>>, GraphError> {
        let mut prepared = Vec::with_capacity(specs.len());
        for (spec, opts) in specs {
            prepared
                .push(self.exec.prepare_graph(spec, Tenancy::from_opts(&opts))?);
        }
        Ok(prepared
            .into_iter()
            .map(|(run, roots)| {
                dispatch(&run, &roots);
                GraphHandle::from_run(run)
            })
            .collect())
    }

    /// Borrowed-body fused submission: like [`Session::submit_all`] but
    /// the node bodies may borrow the caller's stack data; blocks until
    /// *every* graph in the batch is terminal and returns the reports
    /// in batch order. The first node panic (across the whole batch) is
    /// resumed on this thread after every graph has settled.
    pub fn run_all<'env>(
        &self,
        specs: Vec<(GraphSpec<'env>, SubmitOpts)>,
    ) -> Result<Vec<GraphReport>, GraphError> {
        // SOUNDNESS: lifetime-only transmute of the node bodies ('env
        // erased to 'static; layout unchanged), with the same argument
        // as `Executor::run_graph`: this function blocks (below) until
        // every submitted graph is terminal, and by then every body is
        // gone — dispatched bodies are dropped by job finalization
        // before the node's completion publishes, cancelled bodies
        // under the progress lock at cancellation, both before the
        // graph-level `remaining` counter reaches zero. On the `Err`
        // path nothing was dispatched and the specs (with their
        // bodies) are dropped here, inside 'env.
        let specs: Vec<(GraphSpec<'static>, SubmitOpts)> =
            unsafe { std::mem::transmute(specs) };
        let mut prepared = Vec::with_capacity(specs.len());
        for (spec, opts) in specs {
            prepared
                .push(self.exec.prepare_graph(spec, Tenancy::from_opts(&opts))?);
        }
        let runs: Vec<_> = prepared
            .into_iter()
            .map(|(run, roots)| {
                dispatch(&run, &roots);
                run
            })
            .collect();
        let mut reports = Vec::with_capacity(runs.len());
        let mut first_panic = None;
        for run in &runs {
            let (report, panic) = wait_terminal(run);
            reports.push(report);
            if first_panic.is_none() {
                first_panic = panic;
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        Ok(reports)
    }

    // -----------------------------------------------------------------
    // elastic pool control (see [`crate::sched::elastic`])
    // -----------------------------------------------------------------

    /// Resize `pool` to `width` resident workers (clamped to
    /// `1..=members`): surplus workers park until widened again, so the
    /// pool's jobs keep running on fewer cores without losing tasks.
    /// Returns the resulting resident width. Publishes the new widths
    /// (gauges + [`TraceKind::Resize`] events) and wakes the pool.
    pub fn resize_pool(&self, pool: usize, width: usize) -> usize {
        let before = self.exec.elastic().epoch();
        let got = self.exec.elastic().set_width(pool, width);
        if self.exec.elastic().epoch() != before {
            publish_pool_widths(self.exec.shared());
        }
        got
    }

    /// Lend up to `n` idle workers from pool `from` to pool `to`, where
    /// they serve **moldable** jobs only. Refused (returns 0) while the
    /// donor has live non-moldable work of its own — and any later
    /// non-moldable arrival on the donor snaps the lease back
    /// automatically. Returns how many workers moved.
    pub fn lend(&self, from: usize, to: usize, n: usize) -> usize {
        if self.exec.pool_backlog(from) > 0 {
            return 0;
        }
        let moved = self.exec.elastic().lend(from, to, n);
        if moved > 0 {
            publish_pool_widths(self.exec.shared());
        }
        moved
    }

    /// Return every worker lent out of `pool` to its home (the manual
    /// form of the automatic pinned-arrival snap-back). Returns how
    /// many came home.
    pub fn reclaim(&self, pool: usize) -> usize {
        let returned = self.exec.elastic().reclaim(pool);
        if returned > 0 {
            publish_pool_widths(self.exec.shared());
        }
        returned
    }
}

impl Executor {
    /// A multi-tenant submission context on this executor's pool.
    pub fn session(&self) -> Session<'_> {
        Session::new(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedConfig;
    use crate::sched::graph::{NodeSpec, NodeStatus};
    use crate::topology::Topology;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn exec() -> Executor {
        Executor::new(
            Arc::new(Topology::symmetric("t", 2, 2, 1.5, 1.0)),
            Arc::new(SchedConfig::default()),
        )
    }

    #[test]
    fn policy_names_round_trip() {
        for p in TenancyPolicy::ALL {
            assert_eq!(TenancyPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(TenancyPolicy::parse("bogus"), None);
        assert_eq!(TenancyPolicy::default(), TenancyPolicy::Fifo);
    }

    #[test]
    fn submit_opts_builder_clamps_weight() {
        let opts = SubmitOpts::new().priority(3).weight(0).tag("t");
        assert_eq!(opts.priority, 3);
        assert_eq!(opts.weight, 1, "weight 0 would starve the tag");
        assert_eq!(opts.tag, "t");
        let t = Tenancy::from_opts(&SubmitOpts::default());
        assert_eq!(t.priority, 0);
        assert_eq!(t.weight, 1);
        assert_eq!(&*t.tag, "");
        assert!(!t.moldable, "default tenancy is pinned");
        let m = SubmitOpts::new().moldable(0, 0);
        assert_eq!(m.moldable, Some((1, 1)), "moldable range is clamped");
        assert!(Tenancy::from_opts(&m).moldable);
    }

    #[test]
    fn admission_policy_rules() {
        let open = AdmissionPolicy::Open;
        assert!(open.admits(usize::MAX, f64::INFINITY));
        let bounded = AdmissionPolicy::Bounded { max_backlog: 2 };
        assert!(bounded.admits(0, 0.0));
        assert!(bounded.admits(1, 0.0));
        assert!(!bounded.admits(2, 0.0));
        let shed = AdmissionPolicy::Shed { deadline: 0.5 };
        assert!(shed.admits(100, 0.5));
        assert!(!shed.admits(100, 0.500001));
        // names parse back with the bound carried from separate keys
        assert_eq!(
            AdmissionPolicy::parse("bounded", 2, 0.0),
            Some(bounded)
        );
        assert_eq!(AdmissionPolicy::parse("shed", 0, 0.5), Some(shed));
        assert_eq!(AdmissionPolicy::parse("open", 9, 9.0), Some(open));
        assert_eq!(AdmissionPolicy::parse("bogus", 0, 0.0), None);
        assert_eq!(AdmissionPolicy::default(), open);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-gate body holds workers")]
    fn bounded_admission_rejects_past_backlog_and_recovers() {
        let e = exec();
        let session = e.session();
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let opts = || {
            SubmitOpts::new()
                .tag("svc")
                .admission(AdmissionPolicy::Bounded { max_backlog: 1 })
        };
        let spec = |gate: &Arc<std::sync::atomic::AtomicBool>| {
            let g = Arc::clone(gate);
            GraphSpec::new("req").node(NodeSpec::new("n", 1), move |_w, _r| {
                while !g.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            })
        };
        let first = session.try_submit_graph(spec(&gate), opts()).unwrap();
        let Admitted::Accepted(h) = first else {
            panic!("empty backlog must admit")
        };
        // the gated job is live, so the tag backlog is 1 = max_backlog
        let second = session.try_submit_graph(spec(&gate), opts()).unwrap();
        match second {
            Admitted::Rejected { backlog } => assert_eq!(backlog, 1),
            Admitted::Accepted(_) => panic!("saturated tag must reject"),
        }
        // a foreign tag is unaffected by svc's backlog
        let other = session
            .try_submit_graph(
                GraphSpec::new("other").node(NodeSpec::new("n", 0), |_, _| {}),
                SubmitOpts::new()
                    .tag("batch")
                    .admission(AdmissionPolicy::Bounded { max_backlog: 1 }),
            )
            .unwrap();
        assert!(other.is_accepted());
        // draining the backlog re-opens admission
        gate.store(true, Ordering::Release);
        let report = h.join();
        assert!(report.all_completed());
        let third = session.try_submit_graph(
            GraphSpec::new("req").node(NodeSpec::new("n", 0), |_, _| {}),
            opts(),
        );
        assert!(third.unwrap().is_accepted());
        // rejected-but-malformed graphs still error
        let bad = GraphSpec::new("bad")
            .node(NodeSpec::new("n", 1).after("ghost"), |_, _| {});
        assert!(session.try_submit_graph(bad, opts()).is_err());
    }

    #[test]
    fn aging_raises_effective_priority_with_waiting() {
        let t = Tenancy::from_opts(&SubmitOpts::new().priority(1));
        assert_eq!(t.effective_priority(0.0), 1, "no waiting, no boost");
        assert_eq!(t.effective_priority(2.5 * AGING_QUANTUM_SECS), 3);
        // an actively-served contender (zero wait) can never out-age a
        // higher-priority job by merely existing longer
        let served = Tenancy::from_opts(&SubmitOpts::new());
        assert!(
            t.effective_priority(0.0) > served.effective_priority(0.0),
            "strict priority dominates when neither is starved"
        );
    }

    #[test]
    fn small_run_all_exercises_the_borrowed_batch_path() {
        // Miri-sized: the `run_all` lifetime transmute with bodies that
        // borrow the caller's stack across a fused two-graph batch.
        let e = exec();
        let session = e.session();
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        let specs = vec![
            (
                GraphSpec::new("one").node(NodeSpec::new("n", 32), |_w, r| {
                    a.fetch_add(r.len(), Ordering::Relaxed);
                }),
                SubmitOpts::new().tag("one"),
            ),
            (
                GraphSpec::new("two").node(NodeSpec::new("n", 24), |_w, r| {
                    b.fetch_add(r.len(), Ordering::Relaxed);
                }),
                SubmitOpts::new().tag("two"),
            ),
        ];
        let reports = session.run_all(specs).unwrap();
        assert!(reports.iter().all(|r| r.all_completed()));
        assert_eq!(a.load(Ordering::Relaxed), 32);
        assert_eq!(b.load(Ordering::Relaxed), 24);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 2000-item graph")]
    fn session_submit_graph_runs_like_executor_submit_graph() {
        let e = exec();
        let session = e.session();
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let spec = GraphSpec::new("g").node(
            NodeSpec::new("a", 2_000),
            move |_w, r| {
                c.fetch_add(r.len(), Ordering::Relaxed);
            },
        );
        let h = session
            .submit_graph(spec, SubmitOpts::new().tag("tenant-a"))
            .unwrap();
        let report = h.wait();
        assert!(report.all_completed());
        assert_eq!(count.load(Ordering::Relaxed), 2_000);
    }

    #[test]
    fn submit_all_is_all_or_nothing() {
        let e = exec();
        let session = e.session();
        let good = GraphSpec::new("good")
            .node(NodeSpec::new("a", 100), |_w, _r| {});
        let bad = GraphSpec::new("bad")
            .node(NodeSpec::new("a", 10).after("ghost"), |_w, _r| {});
        let err = session
            .submit_all(vec![
                (good, SubmitOpts::default()),
                (bad, SubmitOpts::default()),
            ])
            .unwrap_err();
        assert!(matches!(err, GraphError::UnknownDependency { .. }));
        // nothing dispatched — not even the valid graph
        assert_eq!(e.jobs_completed(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items")]
    fn run_all_returns_reports_in_batch_order() {
        let e = exec();
        let session = e.session();
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        let specs = vec![
            (
                GraphSpec::new("one").node(
                    NodeSpec::new("n", 1_500),
                    |_w, r| {
                        a.fetch_add(r.len(), Ordering::Relaxed);
                    },
                ),
                SubmitOpts::new().tag("one"),
            ),
            (
                GraphSpec::new("two").node(
                    NodeSpec::new("n", 700),
                    |_w, r| {
                        b.fetch_add(r.len(), Ordering::Relaxed);
                    },
                ),
                SubmitOpts::new().tag("two"),
            ),
        ];
        let reports = session.run_all(specs).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].graph, "one");
        assert_eq!(reports[1].graph, "two");
        assert!(reports.iter().all(|r| r.all_completed()));
        assert_eq!(a.load(Ordering::Relaxed), 1_500);
        assert_eq!(b.load(Ordering::Relaxed), 700);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 2000-item survivor graph")]
    fn run_all_settles_every_graph_before_resuming_a_panic() {
        let e = exec();
        let session = e.session();
        let survivor = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let specs = vec![
                    (
                        GraphSpec::new("boom").node(
                            NodeSpec::new("n", 100),
                            |_w, _r| panic!("tenant failure"),
                        ),
                        SubmitOpts::default(),
                    ),
                    (
                        GraphSpec::new("fine").node(
                            NodeSpec::new("n", 2_000),
                            |_w, r| {
                                survivor.fetch_add(r.len(), Ordering::Relaxed);
                            },
                        ),
                        SubmitOpts::default(),
                    ),
                ];
                let _ = session.run_all(specs);
            }),
        );
        assert!(result.is_err(), "the node panic must resume");
        // the independent tenant ran to completion first
        assert_eq!(survivor.load(Ordering::Relaxed), 2_000);
        // and the pool survives
        let r = e.run(JobSpec::new(500), |_w, _r| {});
        assert_eq!(r.total_items(), 500);
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-gate body on the root node")]
    fn cancelled_graph_reports_cancelled_nodes() {
        let e = exec();
        let session = e.session();
        // a graph whose second node can never start before we cancel:
        // the root blocks until we release it
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let g = Arc::clone(&gate);
        let ran = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&ran);
        let spec = GraphSpec::new("cancel-me")
            .node(NodeSpec::new("hold", 1), move |_w, _r| {
                while !g.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
            })
            .node(NodeSpec::new("rest", 10_000).after("hold"), move |_w, r| {
                r2.fetch_add(r.len(), Ordering::Relaxed);
            });
        let h = session.submit_graph(spec, SubmitOpts::default()).unwrap();
        h.cancel();
        gate.store(true, Ordering::Release);
        let report = h.join();
        assert_eq!(report.status("rest"), Some(NodeStatus::Cancelled));
        assert_eq!(ran.load(Ordering::Relaxed), 0, "rest never dispatched");
        // the pool is free for the next tenant
        let r = e.run(JobSpec::new(1_000), |_w, _r| {});
        assert_eq!(r.total_items(), 1_000);
    }
}
