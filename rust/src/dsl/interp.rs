//! The DaphneDSL interpreter: evaluates programs, lowering vectorizable
//! operators onto the VEE so they execute under the configured
//! scheduler (the DSL analog of DAPHNE's vectorized execution engine).
//!
//! Scheduled operators (items = matrix rows): `rowMaxs(G * t(c))`,
//! elementwise dense binary ops, `mean`/`stddev`, `syrk`, `gemv`.
//! Everything else (scalars, small epilogues like `solve`) runs inline.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::sync::Mutex;

use super::ast::{BinOp, Expr, Program, Stmt};
use super::value::{apply_rows, broadcast_mode, Value};
use crate::graph::{amazon_like, scale_up, SnapGraph};
use crate::matrix::{ops, DenseMatrix};
use crate::sched::SchedReport;
use crate::util::DisjointMut;
use crate::vee::Vee;

/// Result of running a program.
#[derive(Debug)]
pub struct RunOutput {
    /// Final variable bindings.
    pub vars: BTreeMap<String, Value>,
    /// `(operator, report)` for every VEE-scheduled operator execution.
    pub reports: Vec<(String, SchedReport)>,
}

impl RunOutput {
    pub fn num(&self, name: &str) -> Option<f64> {
        self.vars.get(name).and_then(|v| v.as_num().ok())
    }

    pub fn mat(&self, name: &str) -> Option<&DenseMatrix> {
        match self.vars.get(name) {
            Some(Value::Mat(m)) => Some(m),
            _ => None,
        }
    }

    /// Sum of scheduled-operator makespans (the "execution time" the
    /// paper's figures report).
    pub fn scheduled_time(&self) -> f64 {
        self.reports.iter().map(|(_, r)| r.makespan).sum()
    }
}

/// Interpreter state.
pub struct Interp {
    params: BTreeMap<String, String>,
    vee: Vee,
    vars: BTreeMap<String, Value>,
    reports: Vec<(String, SchedReport)>,
    /// Row threshold below which ops run inline (scheduling a 5-row
    /// matrix is pure overhead).
    pub parallel_threshold: usize,
}

impl Interp {
    /// `vee` is cheap to pass by value: cloning an engine shares its
    /// resident worker pool, so every operator this interpreter
    /// schedules is a job on the caller's executor — no threads are
    /// spawned per operator.
    pub fn new(params: BTreeMap<String, String>, vee: Vee) -> Self {
        Interp {
            params,
            vee,
            vars: BTreeMap::new(),
            reports: Vec::new(),
            parallel_threshold: 256,
        }
    }

    pub fn run(mut self, program: &Program) -> Result<RunOutput, String> {
        self.exec_block(&program.stmts)?;
        Ok(RunOutput { vars: self.vars, reports: self.reports })
    }

    fn exec_block(&mut self, stmts: &[Stmt]) -> Result<(), String> {
        for stmt in stmts {
            self.exec(stmt)?;
        }
        Ok(())
    }

    fn exec(&mut self, stmt: &Stmt) -> Result<(), String> {
        match stmt {
            Stmt::Assign(name, expr) => {
                let v = self.eval(expr)?;
                self.vars.insert(name.clone(), v);
                Ok(())
            }
            Stmt::While(cond, body) => {
                let mut guard = 0usize;
                while self.eval(cond)?.truthy()? {
                    self.exec_block(body)?;
                    guard += 1;
                    if guard > 1_000_000 {
                        return Err("while loop exceeded 1e6 iterations".into());
                    }
                }
                Ok(())
            }
            Stmt::Expr(e) => {
                self.eval(e)?;
                Ok(())
            }
        }
    }

    fn eval(&mut self, expr: &Expr) -> Result<Value, String> {
        match expr {
            Expr::Num(n) => Ok(Value::Num(*n)),
            Expr::Str(s) => Ok(Value::Str(s.clone())),
            Expr::Param(p) => {
                let raw = self
                    .params
                    .get(p)
                    .ok_or_else(|| format!("missing parameter ${p}"))?;
                Ok(match raw.parse::<f64>() {
                    Ok(n) => Value::Num(n),
                    Err(_) => Value::Str(raw.clone()),
                })
            }
            Expr::Var(name) => match name.as_str() {
                "inf" => Ok(Value::Num(f64::INFINITY)),
                "nan" => Ok(Value::Num(f64::NAN)),
                _ => self
                    .vars
                    .get(name)
                    .cloned()
                    .ok_or_else(|| format!("undefined variable '{name}'")),
            },
            Expr::Neg(e) => {
                let v = self.eval(e)?;
                match v {
                    Value::Num(n) => Ok(Value::Num(-n)),
                    Value::Mat(mut m) => {
                        for x in &mut m.data {
                            *x = -*x;
                        }
                        Ok(Value::Mat(m))
                    }
                    other => {
                        Err(format!("cannot negate {}", other.type_name()))
                    }
                }
            }
            Expr::Binary(op, l, r) => {
                let lv = self.eval(l)?;
                let rv = self.eval(r)?;
                self.binary(*op, lv, rv)
            }
            Expr::ColIndex(target, cols) => {
                let m = self.eval(target)?;
                let idx = self.eval(cols)?;
                let m = m.as_mat()?.clone();
                let idx = idx.as_mat()?;
                let mut out = DenseMatrix::zeros(m.rows, idx.data.len());
                for (k, &ci) in idx.data.iter().enumerate() {
                    let ci = ci as usize;
                    if ci >= m.cols {
                        return Err(format!(
                            "column index {ci} out of range ({})",
                            m.cols
                        ));
                    }
                    for r in 0..m.rows {
                        out[(r, k)] = m[(r, ci)];
                    }
                }
                Ok(Value::Mat(out))
            }
            Expr::Call(name, args) => {
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    vals.push(self.eval(a)?);
                }
                self.call(name, vals)
            }
        }
    }

    // ------------------------------------------------------------------
    // binary operators
    // ------------------------------------------------------------------

    fn binary(&mut self, op: BinOp, l: Value, r: Value) -> Result<Value, String> {
        // sparse * t(c) — the Listing 1 hot pattern: stay lazy
        if let (BinOp::Mul, Value::Sparse(g), Value::Mat(m)) = (&op, &l, &r) {
            if m.rows == 1 && m.cols == g.cols {
                return Ok(Value::SparseColScaled(
                    g.clone(),
                    Arc::new(m.data.clone()),
                ));
            }
        }
        let f = scalar_op(op);
        match (l, r) {
            (Value::Num(a), Value::Num(b)) => {
                Ok(Value::Num(f(a as f32, b as f32) as f64))
            }
            (Value::Mat(a), Value::Num(b)) => {
                let b = DenseMatrix::from_vec(1, 1, vec![b as f32]);
                self.elementwise(op, a, b)
            }
            (Value::Num(a), Value::Mat(b)) => {
                // a (op) B == map over B with a on the left
                let a = DenseMatrix::fill(a as f32, b.rows, b.cols);
                self.elementwise(op, a, b)
            }
            (Value::Mat(a), Value::Mat(b)) => {
                // (1,1) on either side degrades to scalar broadcast
                if a.rows * a.cols == 1 && b.rows * b.cols > 1 {
                    let av = DenseMatrix::fill(a.data[0], b.rows, b.cols);
                    self.elementwise(op, av, b)
                } else {
                    self.elementwise(op, a, b)
                }
            }
            (l, r) => Err(format!(
                "unsupported operands {} {op:?} {}",
                l.type_name(),
                r.type_name()
            )),
        }
    }

    /// Dense elementwise with broadcast; scheduled when large enough.
    fn elementwise(
        &mut self,
        op: BinOp,
        a: DenseMatrix,
        b: DenseMatrix,
    ) -> Result<Value, String> {
        let mode = broadcast_mode(&a, &b)?;
        let f = scalar_op(op);
        let mut out = vec![0f32; a.rows * a.cols];
        if a.rows >= self.parallel_threshold {
            let view = DisjointMut::new(&mut out);
            let (aref, bref, mref, view) = (&a, &b, &mode, &view);
            let d = a.cols;
            let report = self.vee.execute(a.rows, move |_w, range| {
                let slice = view.slice_mut(range.start * d, range.end * d);
                apply_rows(aref, bref, mref, f, slice, range.start, range.end);
            });
            self.reports.push((format!("ewise:{op:?}"), report));
        } else {
            apply_rows(&a, &b, &mode, f, &mut out, 0, a.rows);
        }
        Ok(Value::Mat(DenseMatrix::from_vec(a.rows, a.cols, out)))
    }

    // ------------------------------------------------------------------
    // builtins
    // ------------------------------------------------------------------

    fn call(&mut self, name: &str, args: Vec<Value>) -> Result<Value, String> {
        match name {
            "readMatrix" => self.read_matrix(&args),
            "nrow" => Ok(Value::Num(match &args[0] {
                Value::Mat(m) => m.rows as f64,
                Value::Sparse(g) => g.rows as f64,
                v => return Err(format!("nrow of {}", v.type_name())),
            })),
            "ncol" => Ok(Value::Num(match &args[0] {
                Value::Mat(m) => m.cols as f64,
                Value::Sparse(g) => g.cols as f64,
                v => return Err(format!("ncol of {}", v.type_name())),
            })),
            "seq" => {
                let a = args[0].as_num()? as i64;
                let b = args[1].as_num()? as i64;
                let step = if args.len() > 2 {
                    args[2].as_num()? as i64
                } else {
                    1
                };
                if step == 0 {
                    return Err("seq: zero step".into());
                }
                let mut data = Vec::new();
                let mut v = a;
                while (step > 0 && v <= b) || (step < 0 && v >= b) {
                    data.push(v as f32);
                    v += step;
                }
                let n = data.len();
                Ok(Value::Mat(DenseMatrix::from_vec(n, 1, data)))
            }
            "t" => {
                let m = args[0].as_mat()?;
                Ok(Value::Mat(m.transpose()))
            }
            "max" => self.builtin_max(args),
            "rowMaxs" => self.builtin_rowmaxs(args),
            "sum" => {
                let m = args[0].as_mat()?;
                Ok(Value::Num(m.data.iter().map(|&x| x as f64).sum()))
            }
            "mean" | "stddev" => self.builtin_colstats(name, args),
            "rand" => {
                let rows = args[0].as_num()? as usize;
                let cols = args[1].as_num()? as usize;
                let lo = args[2].as_num()? as f32;
                let hi = args[3].as_num()? as f32;
                // args[4] sparsity (1 = dense, the only supported value)
                let seed_arg = args[5].as_num()?;
                let seed = if seed_arg < 0.0 {
                    self.vee.sched.seed
                } else {
                    seed_arg as u64
                };
                Ok(Value::Mat(DenseMatrix::rand(rows, cols, lo, hi, seed)))
            }
            "fill" => {
                let v = args[0].as_num()? as f32;
                let rows = args[1].as_num()? as usize;
                let cols = args[2].as_num()? as usize;
                Ok(Value::Mat(DenseMatrix::fill(v, rows, cols)))
            }
            "as.si64" | "as.f64" | "as.scalar" => {
                Ok(Value::Num(args[0].as_num()?.trunc()))
            }
            "cbind" => {
                let a = args[0].as_mat()?;
                let b = args[1].as_mat()?;
                Ok(Value::Mat(a.cbind(b)))
            }
            "diagMatrix" => {
                let v = args[0].as_mat()?;
                Ok(Value::Mat(DenseMatrix::diag(v)))
            }
            "syrk" => self.builtin_syrk(args),
            "gemv" => self.builtin_gemv(args),
            "solve" => {
                let a = args[0].as_mat()?;
                let b = args[1].as_mat()?;
                let x = ops::cholesky_solve(a, &b.data)?;
                let n = x.len();
                Ok(Value::Mat(DenseMatrix::from_vec(n, 1, x)))
            }
            "print" => {
                match &args[0] {
                    Value::Num(n) => println!("{n}"),
                    Value::Str(s) => println!("{s}"),
                    Value::Mat(m) => {
                        println!("matrix {}x{}", m.rows, m.cols)
                    }
                    v => println!("<{}>", v.type_name()),
                }
                Ok(Value::Num(0.0))
            }
            other => Err(format!("unknown builtin '{other}'")),
        }
    }

    /// `readMatrix($f)`: SNAP edge-list path, or a `synthetic:` URI
    /// (`synthetic:amazon?nodes=..&seed=..&scale=..`) for the generated
    /// co-purchase graph. Symmetrized like the paper's two-directional
    /// scaled data set.
    fn read_matrix(&mut self, args: &[Value]) -> Result<Value, String> {
        let Value::Str(path) = &args[0] else {
            return Err("readMatrix expects a filename string".into());
        };
        if let Some(query) = path.strip_prefix("synthetic:amazon") {
            let mut nodes = 10_000usize;
            let mut seed = 0xA9u64;
            let mut scale = 1usize;
            for kv in query.trim_start_matches('?').split('&') {
                match kv.split_once('=') {
                    Some(("nodes", v)) => {
                        nodes = v.parse().map_err(|_| "bad nodes")?
                    }
                    Some(("seed", v)) => {
                        seed = v.parse().map_err(|_| "bad seed")?
                    }
                    Some(("scale", v)) => {
                        scale = v.parse().map_err(|_| "bad scale")?
                    }
                    _ => {}
                }
            }
            let g = amazon_like(&SnapGraph::small(nodes, seed)).symmetrize();
            let g = if scale > 1 { scale_up(&g, scale) } else { g };
            return Ok(Value::Sparse(Arc::new(g)));
        }
        let g = crate::graph::snap::read_edge_list(std::path::Path::new(path))
            .map_err(|e| format!("readMatrix {path}: {e}"))?;
        Ok(Value::Sparse(Arc::new(g.symmetrize())))
    }

    /// `max(a, b)` elementwise; the `max(rowMaxs(G * t(c)), c)` pattern
    /// arrives here with both sides dense column vectors.
    fn builtin_max(&mut self, args: Vec<Value>) -> Result<Value, String> {
        if args.len() != 2 {
            return Err("max expects 2 arguments".into());
        }
        let mut it = args.into_iter();
        let (l, r) = (it.next().unwrap(), it.next().unwrap());
        match (l, r) {
            (Value::Num(a), Value::Num(b)) => Ok(Value::Num(a.max(b))),
            (Value::Mat(a), Value::Mat(b)) => {
                let mode = broadcast_mode(&a, &b)?;
                let mut out = vec![0f32; a.rows * a.cols];
                apply_rows(
                    &a,
                    &b,
                    &mode,
                    |x, y| x.max(y),
                    &mut out,
                    0,
                    a.rows,
                );
                Ok(Value::Mat(DenseMatrix::from_vec(a.rows, a.cols, out)))
            }
            (Value::Mat(a), Value::Num(b)) | (Value::Num(b), Value::Mat(a)) => {
                let mut m = a;
                for x in &mut m.data {
                    *x = x.max(b as f32);
                }
                Ok(Value::Mat(m))
            }
            (l, r) => Err(format!(
                "max of {} and {}",
                l.type_name(),
                r.type_name()
            )),
        }
    }

    /// `rowMaxs(G * t(c))` — the scheduled CC hot operator. Implicit
    /// zeros participate in the max (DaphneDSL semantics), hence the 0
    /// floor for rows with no stored entries.
    fn builtin_rowmaxs(&mut self, args: Vec<Value>) -> Result<Value, String> {
        match &args[0] {
            Value::SparseColScaled(g, scale) => {
                let n = g.rows;
                let mut out = vec![0f32; n];
                let view = DisjointMut::new(&mut out);
                let (g, scale, view) = (g.clone(), scale.clone(), &view);
                let report = self.vee.execute(n, move |_w, range| {
                    let slice = view.slice_mut(range.start, range.end);
                    for (off, r) in range.iter().enumerate() {
                        let mut m = 0f32; // implicit zeros
                        for &c in g.row(r) {
                            let v = scale[c as usize];
                            if v > m {
                                m = v;
                            }
                        }
                        slice[off] = m;
                    }
                });
                self.reports.push(("rowMaxs(G*t(c))".into(), report));
                Ok(Value::Mat(DenseMatrix::from_vec(n, 1, out)))
            }
            Value::Mat(m) => {
                let out: Vec<f32> = (0..m.rows)
                    .map(|r| {
                        m.row(r).iter().copied().fold(f32::NEG_INFINITY, f32::max)
                    })
                    .collect();
                Ok(Value::Mat(DenseMatrix::from_vec(m.rows, 1, out)))
            }
            v => Err(format!("rowMaxs of {}", v.type_name())),
        }
    }

    /// `mean(X, 1)` / `stddev(X, 1)` — column statistics via a scheduled
    /// colstats pass (axis 1 = per column, the listings' only use).
    fn builtin_colstats(
        &mut self,
        which: &str,
        args: Vec<Value>,
    ) -> Result<Value, String> {
        let m = args[0].as_mat()?.clone();
        let (n, d) = (m.rows, m.cols);
        let acc: Mutex<(Vec<f32>, Vec<f32>)> =
            Mutex::new((vec![0.0; d], vec![0.0; d]));
        let (mref, accref) = (&m, &acc);
        let report = self.vee.execute(n, move |_w, range| {
            let mut s = vec![0.0; d];
            let mut sq = vec![0.0; d];
            ops::colstats_rows(mref, &mut s, &mut sq, range.start, range.end);
            let mut a = accref.lock().unwrap();
            for c in 0..d {
                a.0[c] += s[c];
                a.1[c] += sq[c];
            }
        });
        self.reports.push((format!("{which}(X,1)"), report));
        let (sum, sumsq) = acc.into_inner().unwrap();
        let out: Vec<f32> = match which {
            "mean" => sum.iter().map(|&s| s / n as f32).collect(),
            _ => sum
                .iter()
                .zip(&sumsq)
                .map(|(&s, &sq)| {
                    let mean = s / n as f32;
                    (sq / n as f32 - mean * mean).max(0.0).sqrt()
                })
                .collect(),
        };
        Ok(Value::Mat(DenseMatrix::from_vec(1, d, out)))
    }

    /// `syrk(X)` = XᵀX — scheduled over row blocks with per-task
    /// partials.
    fn builtin_syrk(&mut self, args: Vec<Value>) -> Result<Value, String> {
        let x = args[0].as_mat()?.clone();
        let d = x.cols;
        let acc: Mutex<Vec<f32>> = Mutex::new(vec![0.0; d * d]);
        let (xref, accref) = (&x, &acc);
        let report = self.vee.execute(x.rows, move |_w, range| {
            let mut a = vec![0.0f32; d * d];
            ops::syrk_rows(xref, &mut a, range.start, range.end);
            let mut acc = accref.lock().unwrap();
            for (dst, src) in acc.iter_mut().zip(&a) {
                *dst += src;
            }
        });
        self.reports.push(("syrk(X)".into(), report));
        Ok(Value::Mat(DenseMatrix::from_vec(
            d,
            d,
            acc.into_inner().unwrap(),
        )))
    }

    /// `gemv(X, y)` = Xᵀy — scheduled over row blocks.
    fn builtin_gemv(&mut self, args: Vec<Value>) -> Result<Value, String> {
        let x = args[0].as_mat()?.clone();
        let y = args[1].as_mat()?.clone();
        if y.data.len() != x.rows {
            return Err(format!(
                "gemv: X has {} rows but y has {} entries",
                x.rows,
                y.data.len()
            ));
        }
        let d = x.cols;
        let acc: Mutex<Vec<f32>> = Mutex::new(vec![0.0; d]);
        let (xref, yref, accref) = (&x, &y, &acc);
        let report = self.vee.execute(x.rows, move |_w, range| {
            let mut b = vec![0.0f32; d];
            ops::gemv_rows(xref, &yref.data, &mut b, range.start, range.end);
            let mut acc = accref.lock().unwrap();
            for (dst, src) in acc.iter_mut().zip(&b) {
                *dst += src;
            }
        });
        self.reports.push(("gemv(X,y)".into(), report));
        Ok(Value::Mat(DenseMatrix::from_vec(
            d,
            1,
            acc.into_inner().unwrap(),
        )))
    }
}

fn scalar_op(op: BinOp) -> fn(f32, f32) -> f32 {
    match op {
        BinOp::Add => |a, b| a + b,
        BinOp::Sub => |a, b| a - b,
        BinOp::Mul => |a, b| a * b,
        BinOp::Div => |a, b| a / b,
        BinOp::Gt => |a, b| f32::from(a > b),
        BinOp::Lt => |a, b| f32::from(a < b),
        BinOp::Ge => |a, b| f32::from(a >= b),
        BinOp::Le => |a, b| f32::from(a <= b),
        BinOp::Eq => |a, b| f32::from(a == b),
        BinOp::Ne => |a, b| f32::from(a != b),
        BinOp::And => |a, b| f32::from(a != 0.0 && b != 0.0),
        BinOp::Or => |a, b| f32::from(a != 0.0 || b != 0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::run_script;

    fn vee() -> Vee {
        Vee::host_default()
    }

    fn run(src: &str, params: &[(&str, &str)]) -> RunOutput {
        let params: BTreeMap<String, String> = params
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        run_script(src, &params, &vee()).unwrap()
    }

    #[test]
    fn scalar_arithmetic_and_while() {
        let out = run("x = 1;\nwhile (x < 10) { x = x * 2; }\n", &[]);
        assert_eq!(out.num("x"), Some(16.0));
    }

    #[test]
    fn param_binding_and_seq() {
        let out = run("n = $n;\ns = seq(1, n);\ntotal = sum(s);", &[("n", "5")]);
        assert_eq!(out.num("total"), Some(15.0));
    }

    #[test]
    fn elementwise_broadcast_row() {
        let out = run(
            "X = fill(2.0, 4, 3);\nm = mean(X, 1);\nY = X - m;\ns = sum(Y);",
            &[],
        );
        assert_eq!(out.num("s"), Some(0.0));
    }

    #[test]
    fn listing1_runs_and_converges() {
        let out = run(
            crate::dsl::LISTING_1_CC,
            &[("f", "synthetic:amazon?nodes=500&seed=7")],
        );
        // connected synthetic graph: all labels = n
        let c = out.mat("c").unwrap();
        assert!(c.data.iter().all(|&l| l == 500.0), "not converged");
        assert_eq!(out.num("diff"), Some(0.0));
        // the propagate operator was scheduled at least once per iter
        assert!(out
            .reports
            .iter()
            .any(|(name, _)| name == "rowMaxs(G*t(c))"));
    }

    #[test]
    fn listing1_matches_native_app() {
        use crate::apps::cc;
        use crate::config::SchedConfig;
        use crate::topology::Topology;
        let g = amazon_like(&SnapGraph::small(400, 3)).symmetrize();
        let native = cc::run_native(
            &g,
            &Topology::host(),
            &SchedConfig::default(),
            100,
        );
        let out = run(
            crate::dsl::LISTING_1_CC,
            &[("f", "synthetic:amazon?nodes=400&seed=3")],
        );
        let c = out.mat("c").unwrap();
        assert_eq!(c.data, native.labels);
    }

    #[test]
    fn listing2_trains_a_model() {
        let out = run(
            crate::dsl::LISTING_2_LINREG,
            &[("numRows", "2000"), ("numCols", "9")],
        );
        let beta = out.mat("beta").unwrap();
        assert_eq!(beta.rows, 9); // 8 features + intercept
        assert!(beta.data.iter().all(|b| b.is_finite()));
        // scheduled operators cover the three passes
        let names: Vec<&str> =
            out.reports.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"mean(X,1)"));
        assert!(names.contains(&"stddev(X,1)"));
        assert!(names.contains(&"syrk(X)"));
        assert!(names.contains(&"gemv(X,y)"));
    }

    #[test]
    fn listing2_matches_native_app() {
        use crate::apps::linreg;
        use crate::config::SchedConfig;
        use crate::topology::Topology;
        // identical data: rand(seed = vee.sched.seed) vs generate()
        let out = run(
            crate::dsl::LISTING_2_LINREG,
            &[("numRows", "1500"), ("numCols", "7")],
        );
        let spec = linreg::LinregSpec {
            rows: 1500,
            cols: 7,
            lambda: 1e-3,
            seed: SchedConfig::default().seed,
        };
        let (x, y) = linreg::generate(&spec);
        let native = linreg::run_native(
            &x,
            &y,
            1e-3,
            &Topology::host(),
            &SchedConfig::default(),
        )
        .unwrap();
        let beta = out.mat("beta").unwrap();
        assert_eq!(beta.data.len(), native.beta.len());
        for (i, (a, b)) in beta.data.iter().zip(&native.beta).enumerate() {
            assert!(
                (a - b).abs() < 1e-2 * b.abs().max(1.0),
                "beta[{i}]: dsl {a} native {b}"
            );
        }
    }

    #[test]
    fn column_indexing_selects() {
        let out = run(
            "XY = rand(10, 4, 0.0, 1.0, 1, 7);\n\
             X = XY[, seq(0, 2, 1)];\n\
             y = XY[, seq(3, 3, 1)];\n\
             nx = ncol(X);\nny = ncol(y);",
            &[],
        );
        assert_eq!(out.num("nx"), Some(3.0));
        assert_eq!(out.num("ny"), Some(1.0));
    }

    #[test]
    fn errors_are_reported() {
        let params = BTreeMap::new();
        assert!(run_script("x = nosuch(1);", &params, &vee()).is_err());
        assert!(run_script("x = y + 1;", &params, &vee()).is_err());
        assert!(run_script("x = $missing;", &params, &vee()).is_err());
        assert!(run_script("x = max(1);", &params, &vee()).is_err());
    }

    #[test]
    fn rowmaxs_implicit_zero_floor() {
        // isolated vertex: empty row -> rowMaxs gives 0, max(0, c) = c
        let out = run(
            "G = readMatrix($f);\nc = seq(1, nrow(G));\n\
             u = max(rowMaxs(G * t(c)), c);\ns = sum(u != c);",
            &[("f", "synthetic:amazon?nodes=50&seed=1")],
        );
        assert!(out.num("s").unwrap() >= 0.0);
    }
}
