//! One function per paper figure; each returns the printed rows so the
//! bench binaries and the CLI share the implementation.

use std::sync::Arc;

use crate::apps::{cc, hetero, linreg};
use crate::config::{ArrivalPattern, GraphMode, SchedConfig};
use crate::graph::{amazon_like, scale_up, SnapGraph};
use crate::matrix::CsrMatrix;
use crate::obs::critical_span_ratio;
use crate::sched::autotune::{self, SearchSpace};
use crate::sched::{
    AdmissionPolicy, ControllerCfg, Placement, QueueLayout, ScaleDecision,
    Scheme, TenancyPolicy, VictimStrategy,
};
use crate::sim::{
    self, CostModel, GraphShape, NodeModel, OpenLoopSpec, TenantSpec,
};
use crate::topology::{DeviceClass, Topology};
use crate::util::Rng;

use super::calibration::AppCosts;

/// Which figure to regenerate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FigureId {
    Fig7a,
    Fig7b,
    Fig8a,
    Fig8b,
    Fig9a,
    Fig9b,
    Fig10a,
    Fig10b,
    /// Not a paper figure: dag-vs-barrier graph replay on both modelled
    /// machines (the PR-2 executor A/B, predicted in virtual time).
    FigDag,
    /// Not a paper figure: the heterogeneous diamond under
    /// any/pinned/autotuned placement on the modelled hetero machines.
    FigHetero,
    /// Not a paper figure: multi-tenant policy comparison
    /// (fifo|fair|priority) under bursty arrivals on the modelled
    /// machines — per-tenant p50/p99 slowdown and fairness index.
    FigTenancy,
    /// Not a paper figure: open-loop serving under overload — attained
    /// QPS, p99/p999 and SLO attainment per tenancy policy × admission
    /// setting on the modelled machines ([`serve_figure`]).
    FigServe,
    /// Not a paper figure: static vs elastic device pools under a
    /// bursty interactive + moldable batch mix on the modelled hetero56
    /// — utilization, interactive p99, lends and snap-backs
    /// ([`elastic_figure`]).
    FigElastic,
}

impl FigureId {
    pub const ALL: [FigureId; 13] = [
        FigureId::Fig7a,
        FigureId::Fig7b,
        FigureId::Fig8a,
        FigureId::Fig8b,
        FigureId::Fig9a,
        FigureId::Fig9b,
        FigureId::Fig10a,
        FigureId::Fig10b,
        FigureId::FigDag,
        FigureId::FigHetero,
        FigureId::FigTenancy,
        FigureId::FigServe,
        FigureId::FigElastic,
    ];

    pub fn parse(s: &str) -> Option<FigureId> {
        match s.to_ascii_lowercase().as_str() {
            "7a" | "fig7a" => Some(FigureId::Fig7a),
            "7b" | "fig7b" => Some(FigureId::Fig7b),
            "8a" | "fig8a" => Some(FigureId::Fig8a),
            "8b" | "fig8b" => Some(FigureId::Fig8b),
            "9a" | "fig9a" => Some(FigureId::Fig9a),
            "9b" | "fig9b" => Some(FigureId::Fig9b),
            "10a" | "fig10a" => Some(FigureId::Fig10a),
            "10b" | "fig10b" => Some(FigureId::Fig10b),
            "dag" | "figdag" => Some(FigureId::FigDag),
            "het" | "hetero" | "fighetero" => Some(FigureId::FigHetero),
            "ten" | "tenancy" | "figtenancy" => Some(FigureId::FigTenancy),
            "srv" | "serve" | "figserve" => Some(FigureId::FigServe),
            "ela" | "elastic" | "figelastic" => Some(FigureId::FigElastic),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FigureId::Fig7a => "Fig 7a: CC, centralized queue, Broadwell(2x10)",
            FigureId::Fig7b => {
                "Fig 7b: CC, centralized queue, CascadeLake(2x28)"
            }
            FigureId::Fig8a => {
                "Fig 8a: CC, PERCORE queues x victims, Broadwell(2x10)"
            }
            FigureId::Fig8b => {
                "Fig 8b: CC, PERCPU queues x victims, Broadwell(2x10)"
            }
            FigureId::Fig9a => {
                "Fig 9a: CC, PERCORE queues x victims, CascadeLake(2x28)"
            }
            FigureId::Fig9b => {
                "Fig 9b: CC, PERCPU queues x victims, CascadeLake(2x28)"
            }
            FigureId::Fig10a => {
                "Fig 10a: LinReg, centralized queue, Broadwell(2x10)"
            }
            FigureId::Fig10b => {
                "Fig 10b: LinReg, centralized queue, CascadeLake(2x28)"
            }
            FigureId::FigDag => {
                "Fig DAG: dag vs barrier graph replay, both machines"
            }
            FigureId::FigHetero => {
                "Fig HET: placement any|pinned|auto, hetero machines"
            }
            FigureId::FigTenancy => {
                "Fig TEN: tenancy policy fifo|fair|priority, bursty arrivals"
            }
            FigureId::FigServe => {
                "Fig SRV: open-loop serving, admission open|bounded|shed"
            }
            FigureId::FigElastic => {
                "Fig ELA: static vs elastic pools, bursty mix, hetero56"
            }
        }
    }

    /// Machine a figure models. [`FigureId::FigDag`],
    /// [`FigureId::FigHetero`], [`FigureId::FigTenancy`] and
    /// [`FigureId::FigServe`] iterate their modelled machines
    /// internally; this returns the smallest one.
    pub fn machine(&self) -> Topology {
        match self {
            FigureId::Fig7a
            | FigureId::Fig8a
            | FigureId::Fig8b
            | FigureId::Fig10a
            | FigureId::FigDag
            | FigureId::FigTenancy
            | FigureId::FigServe => Topology::broadwell20(),
            FigureId::FigHetero => Topology::hetero20(),
            FigureId::FigElastic => Topology::hetero56(),
            _ => Topology::cascadelake56(),
        }
    }
}

/// Workload parameters. Defaults regenerate the figures at the
/// *unscaled* SNAP size (403k nodes) so a full sweep runs in minutes;
/// `scale = 50` reproduces the paper's full 20.17M-node input.
#[derive(Debug, Clone)]
pub struct FigureParams {
    pub nodes: usize,
    pub scale: usize,
    pub seed: u64,
    /// CC convergence iterations; `None` = compute natively once.
    pub iterations: Option<usize>,
    /// Linear-regression rows (paper does not state its size; chosen so
    /// the modelled run lands in Fig. 10's seconds range).
    pub lr_rows: usize,
    /// Independent repetitions (fresh graph + noise seeds) averaged per
    /// row, as the paper's measurements average repeated runs.
    pub repetitions: usize,
    /// Arrival pattern of [`FigureId::FigTenancy`]'s tenant mix
    /// (`arrival=burst|uniform|poisson`).
    pub arrival: ArrivalPattern,
    /// Virtual arrival-window seconds of [`FigureId::FigServe`]'s
    /// open-loop replay (warmup is the first quarter of it).
    pub serve_duration: f64,
    pub costs: CostModel,
    pub app_costs: AppCosts,
}

impl Default for FigureParams {
    fn default() -> Self {
        FigureParams {
            nodes: 403_394,
            scale: 1,
            // canonical dataset-instance seed: seeds 1-8 all yield the
            // paper-representative block imbalance (EXPERIMENTS.md
            // records the sweep); 1 is the documented default.
            seed: 1,
            iterations: None,
            lr_rows: 2_000_000,
            repetitions: 3,
            arrival: ArrivalPattern::Burst,
            serve_duration: 0.4,
            // DAPHNE-runtime-like dispatch costs + OS interference: the
            // environment the paper measured (see CostModel docs).
            costs: CostModel::daphne_like(),
            app_costs: AppCosts::recorded(),
        }
    }
}

impl FigureParams {
    /// Small parameters for tests.
    pub fn tiny() -> Self {
        FigureParams {
            nodes: 20_000,
            scale: 1,
            lr_rows: 100_000,
            serve_duration: 0.04,
            ..Default::default()
        }
    }

    pub fn build_graph(&self) -> CsrMatrix {
        let g = amazon_like(&SnapGraph {
            nodes: self.nodes,
            out_degree: 8,
            copy_prob: 0.7,
            seed: self.seed,
        })
        .symmetrize();
        if self.scale > 1 {
            scale_up(&g, self.scale)
        } else {
            g
        }
    }
}

/// One output row (matches what the paper plots: a bar per
/// technique/victim combination).
#[derive(Debug, Clone)]
pub struct Row {
    pub scheme: &'static str,
    pub victim: Option<&'static str>,
    /// Modelled execution time, seconds.
    pub time: f64,
    /// Relative to STATIC with the same victim (1.0 = parity; < 1 is
    /// faster than STATIC).
    pub vs_static: f64,
    pub steals: usize,
    pub cov: f64,
    /// Accumulated per-worker queue-acquisition wait
    /// ([`WorkerStats::queue_wait`](crate::sched::metrics::WorkerStats)),
    /// seconds summed over workers — the contention cost a scheme pays
    /// for its chunk strategy. Zero for rows derived from replays that
    /// do not expose per-worker reports.
    pub queue_wait: f64,
    /// Critical-path attribution: summed spans of the replay's
    /// critical-path nodes over its makespan
    /// ([`critical_span_ratio`]) — 1.0 means the reported chain tiles
    /// the makespan exactly, so every row doubles as an attribution
    /// check. Single-workload rows (Figs 7-10) are trivially 1.0 (the
    /// whole run is the chain); `None` for rows whose metric is not a
    /// graph makespan (tenancy/serve tail latencies).
    pub crit: Option<f64>,
}

impl Row {
    pub fn print(&self) {
        let victim = self.victim.unwrap_or("-");
        let crit = match self.crit {
            Some(c) => format!("{:.3}", c),
            None => "-".to_string(),
        };
        println!(
            "  {:<7} {:<7} time={:>9.3}s vs_STATIC={:>6.3} steals={:<8} \
             cov={:.3} qwait={:.4}s crit={}",
            self.scheme,
            victim,
            self.time,
            self.vs_static,
            self.steals,
            self.cov,
            self.queue_wait,
            crit
        );
    }

    /// Stable JSON form for `BENCH_*.json` reports.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(
            [
                (
                    "scheme".to_string(),
                    Json::Str(self.scheme.to_string()),
                ),
                (
                    "victim".to_string(),
                    match self.victim {
                        Some(v) => Json::Str(v.to_string()),
                        None => Json::Null,
                    },
                ),
                ("time".to_string(), Json::Num(self.time)),
                ("vs_static".to_string(), Json::Num(self.vs_static)),
                ("steals".to_string(), Json::Num(self.steals as f64)),
                ("cov".to_string(), Json::Num(self.cov)),
                ("queue_wait".to_string(), Json::Num(self.queue_wait)),
                (
                    "crit".to_string(),
                    match self.crit {
                        Some(c) => Json::Num(c),
                        None => Json::Null,
                    },
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// Serialize figure rows for the `report=json` emitter.
pub fn rows_json(rows: &[Row]) -> crate::util::json::Json {
    crate::util::json::Json::Arr(rows.iter().map(Row::to_json).collect())
}

fn fill_vs_static(rows: &mut [Row]) {
    let mut statics: Vec<(Option<&'static str>, f64)> = Vec::new();
    for r in rows.iter() {
        if r.scheme == "STATIC" {
            statics.push((r.victim, r.time));
        }
    }
    for r in rows.iter_mut() {
        if let Some(&(_, t)) =
            statics.iter().find(|(v, _)| *v == r.victim)
        {
            r.vs_static = r.time / t;
        }
    }
}

/// CC figures 7-9. `layout` selects centralized (Figs 7) / PERCORE
/// (8a, 9a) / PERCPU (8b, 9b); stealing layouts sweep all four victim
/// strategies.
pub fn cc_figure(
    machine: &Topology,
    layout: QueueLayout,
    params: &FigureParams,
) -> Vec<Row> {
    // one graph per repetition (fresh seed), shared across all rows so
    // schemes are compared on identical inputs within a repetition
    let reps: Vec<(CsrMatrix, usize)> = (0..params.repetitions.max(1))
        .map(|rep| {
            let p = FigureParams {
                seed: params.seed.wrapping_add(rep as u64 * 0x9E37),
                ..params.clone()
            };
            let g = p.build_graph();
            let iters = params
                .iterations
                .unwrap_or_else(|| cc::converge_iterations(&g, 100));
            (g, iters)
        })
        .collect();
    let victims: &[Option<VictimStrategy>] = if layout.steals() {
        &[
            Some(VictimStrategy::Seq),
            Some(VictimStrategy::SeqPri),
            Some(VictimStrategy::Rnd),
            Some(VictimStrategy::RndPri),
        ]
    } else {
        &[None]
    };
    let mut rows = Vec::new();
    for &victim in victims {
        for scheme in Scheme::FIGURES {
            let mut time = 0.0;
            let mut steals = 0usize;
            let mut cov = 0.0;
            let mut qwait = 0.0;
            for (rep, (g, iters)) in reps.iter().enumerate() {
                let sched = SchedConfig {
                    scheme,
                    layout,
                    victim: victim.unwrap_or(VictimStrategy::Seq),
                    seed: params.seed.wrapping_add(rep as u64 * 0x517C_C1B7),
                    stages: None,
                    pls_swr: 0.5,
                };
                let (t, outcomes) = cc::simulate_run(
                    g,
                    machine,
                    &sched,
                    &params.costs,
                    *iters,
                    params.app_costs.cc_per_row,
                    params.app_costs.cc_per_nnz,
                );
                time += t;
                steals += outcomes
                    .iter()
                    .map(|o| o.report.total_steals())
                    .sum::<usize>();
                cov += outcomes
                    .first()
                    .map(|o| o.report.cov())
                    .unwrap_or(0.0);
                qwait += outcomes
                    .iter()
                    .map(|o| o.report.total_queue_wait())
                    .sum::<f64>();
            }
            let n = reps.len() as f64;
            rows.push(Row {
                scheme: scheme.name(),
                victim: victim.map(|v| v.name()),
                time: time / n,
                vs_static: 1.0,
                steals: steals / reps.len(),
                cov: cov / n,
                queue_wait: qwait / n,
                // single-workload sweep: the run is its own chain
                crit: Some(1.0),
            });
        }
    }
    fill_vs_static(&mut rows);
    rows
}

/// LinReg figures 10a/10b: dense uniform workload, centralized queue.
pub fn linreg_figure(machine: &Topology, params: &FigureParams) -> Vec<Row> {
    // three scheduled passes per training run (colstats, standardize,
    // fused syrk+gemv), each a full sweep over the rows
    let passes = 3;
    let w = linreg::workload(params.lr_rows, params.app_costs.lr_per_row);
    let mut rows = Vec::new();
    for scheme in Scheme::FIGURES {
        let sched = SchedConfig {
            scheme,
            layout: QueueLayout::Centralized { atomic: false },
            victim: VictimStrategy::Seq,
            seed: params.seed,
            stages: None,
            pls_swr: 0.5,
        };
        let mut time = 0.0;
        let mut steals = 0;
        let mut cov = 0.0;
        let mut qwait = 0.0;
        let reps = params.repetitions.max(1);
        for rep in 0..reps {
            for pass in 0..passes {
                let cfg = SchedConfig {
                    seed: sched
                        .seed
                        .wrapping_add(pass as u64)
                        .wrapping_add(rep as u64 * 0x517C_C1B7),
                    ..sched.clone()
                };
                // the syrk+gemv pass pays the serialized d×d reduction
                // merge per task; modelled as an extension of the
                // queue's critical section (the merge lock)
                let mut costs = params.costs.clone();
                if pass == passes - 1 {
                    costs.serialized_extra += params.app_costs.lr_merge;
                }
                let out = sim::simulate(machine, &cfg, &w, &costs);
                time += out.makespan();
                steals += out.report.total_steals();
                cov = out.report.cov();
                qwait += out.report.total_queue_wait();
            }
        }
        let (time, steals) = (time / reps as f64, steals / reps);
        rows.push(Row {
            scheme: scheme.name(),
            victim: None,
            time,
            vs_static: 1.0,
            steals,
            cov,
            queue_wait: qwait / reps as f64,
            // single-workload sweep: the run is its own chain
            crit: Some(1.0),
        });
    }
    fill_vs_static(&mut rows);
    rows
}

/// One dag-vs-barrier comparison: a shape replayed both ways on one
/// modelled machine.
#[derive(Debug, Clone)]
pub struct DagRow {
    pub machine: &'static str,
    pub shape: &'static str,
    /// Replayed makespan with full barriers between nodes, seconds.
    pub barrier: f64,
    /// Replayed makespan under dependency-aware dispatch, seconds.
    pub dag: f64,
    /// Critical-path attribution of the dag-mode replay
    /// ([`critical_span_ratio`]).
    pub crit: f64,
}

impl DagRow {
    /// `barrier / dag` — how much DAG overlap buys on this machine.
    pub fn speedup(&self) -> f64 {
        self.barrier / self.dag
    }

    pub fn print(&self) {
        println!(
            "  {:<14} {:<9} barrier={:>9.4}s dag={:>9.4}s speedup={:.2}x \
             crit={:.3}",
            self.machine,
            self.shape,
            self.barrier,
            self.dag,
            self.speedup(),
            self.crit
        );
    }
}

/// The dag-vs-barrier figure: replay the apps' real graph shapes (and
/// the unbalanced diamond microshape) on the modelled 20- and 56-core
/// machines in both modes. This is the virtual-time prediction of what
/// PR 2's dependency-aware dispatch buys — observable here on machines
/// the host does not have, not just in `benches/micro.rs` wall-clock.
pub fn dag_figure(params: &FigureParams) -> Vec<DagRow> {
    let g = params.build_graph();
    let cc_shape = cc::iteration_shape(
        &g,
        params.app_costs.cc_per_row,
        params.app_costs.cc_per_nnz,
    );
    let lr_shape =
        linreg::graph_shape(params.lr_rows, params.app_costs.lr_per_row);
    let sched = SchedConfig { seed: params.seed, ..SchedConfig::default() };
    let mut out = Vec::new();
    for (machine, machine_name) in [
        (Topology::broadwell20(), "broadwell20"),
        (Topology::cascadelake56(), "cascadelake56"),
    ] {
        let diamond = GraphShape::unbalanced_diamond(machine.n_cores() / 2);
        for (label, shape) in [
            ("diamond", &diamond),
            ("cc:iter", &cc_shape),
            ("linreg", &lr_shape),
        ] {
            let run = |mode: GraphMode| {
                sim::replay(shape, &machine, &sched, &params.costs, mode)
                    .expect("app shapes are acyclic")
            };
            let dag = run(GraphMode::Dag);
            out.push(DagRow {
                machine: machine_name,
                shape: label,
                barrier: run(GraphMode::Barrier).makespan(),
                dag: dag.makespan(),
                crit: critical_span_ratio(&dag),
            });
        }
    }
    out
}

/// One placement-policy comparison: the heterogeneous diamond replayed
/// on one modelled hetero machine under one placement policy.
#[derive(Debug, Clone)]
pub struct HeteroRow {
    pub machine: &'static str,
    /// `any` (all-CPU), `pinned` (hand-placed classes), or `auto`
    /// (placement chosen per node by [`autotune::tune_graph`]).
    pub policy: &'static str,
    /// Dag-mode makespan (seconds) of the best assignment the shared
    /// scheduling space found under this placement policy.
    pub makespan: f64,
    /// Relative to the all-CPU `any` baseline on the same machine
    /// (< 1 = the accelerator pool paid off).
    pub vs_any: f64,
    /// Critical-path attribution of the tuned assignment's replay
    /// ([`critical_span_ratio`]).
    pub crit: f64,
}

impl HeteroRow {
    pub fn print(&self) {
        println!(
            "  {:<9} {:<7} makespan={:>9.4}s vs_any={:>6.3} crit={:.3}",
            self.machine, self.policy, self.makespan, self.vs_any, self.crit
        );
    }
}

/// The placement figure: the heterogeneous diamond
/// ([`hetero::diamond_shape`]) on the modelled hetero machines under
/// the three placement policies. Every row is tuned over the *same*
/// compact scheme/layout space (via [`autotune::tune_graph`]) with only
/// the placement dimension varying — all-`Any` for the baseline, the
/// shape's pinned classes for `pinned`, the machine's placement
/// candidates for `auto` — so `vs_any` isolates what placement buys,
/// not scheduling-config tuning artifacts.
pub fn hetero_figure(params: &FigureParams) -> Vec<HeteroRow> {
    let mut out = Vec::new();
    for (machine, machine_name) in
        [(Topology::hetero20(), "hetero20"), (Topology::hetero56(), "hetero56")]
    {
        let w = machine.class_cores(DeviceClass::Cpu);
        let tune = |shape: &GraphShape, placements: Vec<Placement>| {
            let space = SearchSpace {
                schemes: vec![Scheme::Static, Scheme::Gss, Scheme::Mfsc],
                layouts: vec![
                    QueueLayout::Centralized { atomic: false },
                    QueueLayout::PerCore,
                ],
                victims: vec![VictimStrategy::SeqPri],
                placements,
            };
            let tuning = autotune::tune_graph(
                shape,
                &machine,
                &params.costs,
                &space,
                params.seed,
                1,
            )
            .expect("hetero shapes resolve on the hetero machines");
            let configs: Vec<SchedConfig> =
                tuning.per_node.iter().map(|c| c.config.clone()).collect();
            let places: Vec<Placement> =
                tuning.per_node.iter().map(|c| c.placement).collect();
            let replayed = sim::replay_placed(
                shape,
                &machine,
                &configs,
                &places,
                &params.costs,
                GraphMode::Dag,
            )
            .expect("tuned assignments replay on the machine they tuned on");
            (tuning.predicted, critical_span_ratio(&replayed))
        };
        let any_shape = hetero::diamond_shape(w);
        let (any, any_crit) = tune(&any_shape, vec![Placement::Any]);
        // empty placement list = keep the shape's hand-pinned classes
        let (pinned, pinned_crit) =
            tune(&hetero::pinned_diamond(w, DeviceClass::Gpu), Vec::new());
        let (auto, auto_crit) =
            tune(&any_shape, SearchSpace::for_machine(&machine).placements);
        for (policy, makespan, crit) in [
            ("any", any, any_crit),
            ("pinned", pinned, pinned_crit),
            ("auto", auto, auto_crit),
        ] {
            out.push(HeteroRow {
                machine: machine_name,
                policy,
                makespan,
                vs_any: makespan / any,
                crit,
            });
        }
    }
    out
}

/// One tenancy-policy comparison row: a tenant mix replayed on one
/// modelled machine under one cross-job pick policy.
#[derive(Debug, Clone)]
pub struct TenancyRow {
    pub machine: &'static str,
    pub policy: &'static str,
    /// Median per-tenant slowdown (latency / isolated makespan).
    pub p50_slowdown: f64,
    /// Tail per-tenant slowdown — the metric bursty multi-tenancy is
    /// judged by.
    pub p99_slowdown: f64,
    /// Jain fairness index over per-tenant slowdowns.
    pub fairness: f64,
    /// Virtual completion time of the whole mix, seconds.
    pub makespan: f64,
}

impl TenancyRow {
    pub fn print(&self) {
        println!(
            "  {:<9} {:<9} p50={:>7.2}x p99={:>8.2}x fairness={:>5.3} \
             makespan={:>8.4}s",
            self.machine,
            self.policy,
            self.p50_slowdown,
            self.p99_slowdown,
            self.fairness,
            self.makespan
        );
    }
}

/// The tenant mix of the tenancy figure, scaled to a machine's CPU
/// width: two heavy batch pipelines (3-node chains) submitted at t=0
/// plus ten short interactive tenants whose arrival offsets follow
/// `pattern` inside the burst window. Interactive tenants carry
/// priority 2 and fair-share weight 4 under the `interactive` tag, the
/// batch pipelines priority 0 / weight 1 under `batch` — so each
/// policy has something to act on.
pub fn tenancy_tenants(
    cores: usize,
    pattern: ArrivalPattern,
    seed: u64,
) -> Vec<TenantSpec> {
    let heavy = |name: &str| {
        GraphShape::new(name)
            .node(NodeModel::uniform("s1", cores * 96, 1e-4))
            .node(NodeModel::uniform("s2", cores * 96, 1e-4).after("s1"))
            .node(NodeModel::uniform("s3", cores * 96, 1e-4).after("s2"))
    };
    let n_short = 10usize;
    // Burst window: well inside the heavy pipelines' span, so every
    // interactive tenant contends with the batch work.
    let window = 0.010;
    let offsets: Vec<f64> = match pattern {
        ArrivalPattern::Burst => (0..n_short)
            .map(|i| {
                // two tight bursts of five
                let burst = if i < n_short / 2 { 0.001 } else { 0.005 };
                burst + i as f64 * 1e-5
            })
            .collect(),
        ArrivalPattern::Uniform => (0..n_short)
            .map(|i| (i + 1) as f64 * window / n_short as f64)
            .collect(),
        ArrivalPattern::Poisson => {
            let mut rng = Rng::new(seed ^ 0xA881_7E9A);
            let rate = n_short as f64 / window;
            let mut t = 0.0;
            (0..n_short)
                .map(|_| {
                    t += rng.exponential(rate);
                    t
                })
                .collect()
        }
    };
    let mut out = vec![
        TenantSpec::new("batch0", heavy("batch0"), 0.0).tag("batch"),
        TenantSpec::new("batch1", heavy("batch1"), 0.0).tag("batch"),
    ];
    for (i, off) in offsets.iter().enumerate() {
        out.push(
            TenantSpec::new(
                &format!("interactive{i}"),
                GraphShape::new("interactive")
                    .node(NodeModel::uniform("q", cores * 4, 1e-4)),
                *off,
            )
            .tag("interactive")
            .priority(2)
            .weight(4),
        );
    }
    out
}

/// The tenancy figure: the bursty tenant mix replayed on the modelled
/// symmetric 20- and 56-core machines and the heterogeneous 56-core
/// machine (its CPU pool carries the unplaced mix) under the three
/// cross-job pick policies. Per-item SS chunks on the atomic central
/// queue keep the preemption quantum fine, so the rows isolate the
/// *policy* dimension: under bursty arrivals FIFO parks the
/// interactive tenants behind the batch pipelines' backlog, which Fair
/// and Priority avoid — visible as the p99 slowdown gap.
pub fn tenancy_figure(params: &FigureParams) -> Vec<TenancyRow> {
    let mut out = Vec::new();
    for (machine, machine_name) in [
        (Topology::broadwell20(), "sym20"),
        (Topology::cascadelake56(), "sym56"),
        (Topology::hetero56(), "hetero56"),
    ] {
        let cores = machine.class_cores(DeviceClass::Cpu);
        let tenants = tenancy_tenants(cores, params.arrival, params.seed);
        let sched = SchedConfig::fine_grained().with_seed(params.seed);
        // policy-independent baselines, computed once per machine
        let isolated =
            sim::isolated_makespans(&tenants, &machine, &sched, &params.costs)
                .expect("tenancy shapes are acyclic");
        for policy in TenancyPolicy::ALL {
            let sim = sim::replay_tenants_with(
                &tenants,
                &machine,
                &sched,
                &params.costs,
                policy,
                &isolated,
            )
            .expect("tenancy shapes are acyclic");
            out.push(TenancyRow {
                machine: machine_name,
                policy: policy.name(),
                p50_slowdown: sim.p50_slowdown(),
                p99_slowdown: sim.p99_slowdown(),
                fairness: sim.fairness(),
                makespan: sim.makespan,
            });
        }
    }
    out
}

/// One open-loop serving comparison row: one modelled machine × tenancy
/// policy × admission setting under the same overloaded request stream.
#[derive(Debug, Clone)]
pub struct ServeRow {
    pub machine: &'static str,
    pub policy: &'static str,
    pub admission: &'static str,
    /// Served requests per second over the measurement window.
    pub attained_qps: f64,
    /// Offered load the generator sustained, requests per second.
    pub offered_qps: f64,
    /// Tail latency over served measured requests, seconds.
    pub p99: f64,
    pub p999: f64,
    /// Fraction of served measured requests within [`SERVE_SLO`].
    pub slo_attainment: f64,
    /// Fraction of measured requests rejected at admission.
    pub shed_rate: f64,
}

impl ServeRow {
    pub fn print(&self) {
        println!(
            "  {:<9} {:<9} {:<8} qps={:>7.0}/{:<6.0} p99={:>8.2}ms \
             p999={:>8.2}ms slo={:>5.1}% shed={:>5.1}%",
            self.machine,
            self.policy,
            self.admission,
            self.attained_qps,
            self.offered_qps,
            self.p99 * 1e3,
            self.p999 * 1e3,
            self.slo_attainment * 100.0,
            self.shed_rate * 100.0
        );
    }
}

/// Latency SLO of the serving figure (and the CLI soak default): 10 ms.
pub const SERVE_SLO: f64 = 0.010;

/// The serving figure's open-loop scenario on one modelled machine: a
/// linreg-inference request (the training pipeline's standardize
/// prefix, sized to the machine so per-request *machine time* — and
/// with it service capacity — is core-count-independent) offered at
/// 1.5× the serve tag's fair-share capacity, over two heavy batch
/// pipelines. Requests carry priority 2 / weight 4 like the tenancy
/// figure's interactive tenants, so the serve tag's fair share is 4/5
/// of the machine: capacity ≈ 0.8 / 1.2 ms ≈ 667 req/s, offered 1000.
/// Uniform arrivals keep the trace (and the acceptance test)
/// deterministic.
pub fn serve_spec(
    cores: usize,
    admission: AdmissionPolicy,
    params: &FigureParams,
) -> OpenLoopSpec {
    let per_item = 1e-4;
    let request = GraphShape::new("linreg-infer")
        .node(NodeModel::uniform("colstats", cores * 4, per_item))
        .node(NodeModel::uniform("stats", 1, per_item).after("colstats"))
        .node(
            NodeModel::uniform("standardize", cores * 4, per_item)
                .after("stats"),
        );
    let heavy = |name: &str| {
        GraphShape::new(name)
            .node(NodeModel::uniform("s1", cores * 96, per_item))
            .node(NodeModel::uniform("s2", cores * 96, per_item).after("s1"))
            .node(NodeModel::uniform("s3", cores * 96, per_item).after("s2"))
    };
    // per-request machine time at full width: 2 sweeps of 4·cores items
    // plus the stats point, ≈ 1.2 ms; ÷ 0.8 fair share ≈ 1.5 ms
    let est_cost = (2.0 * 4.0 * per_item + per_item / cores as f64) / 0.8;
    OpenLoopSpec {
        request,
        qps: 1_000.0,
        duration: params.serve_duration,
        warmup: params.serve_duration / 4.0,
        slo: SERVE_SLO,
        admission,
        est_cost,
        arrival: ArrivalPattern::Uniform,
        seed: params.seed,
        priority: 2,
        weight: 4,
        batch: vec![
            TenantSpec::new("batch0", heavy("batch0"), 0.0).tag("batch"),
            TenantSpec::new("batch1", heavy("batch1"), 0.0).tag("batch"),
        ],
    }
}

/// The admission settings the serving figure (and the acceptance
/// criterion) compares: open, a backlog bound of 4, and load shedding
/// at a 5 ms estimated-wait deadline.
pub fn serve_admissions() -> [AdmissionPolicy; 3] {
    [
        AdmissionPolicy::Open,
        AdmissionPolicy::Bounded { max_backlog: 4 },
        AdmissionPolicy::Shed { deadline: 0.005 },
    ]
}

/// The serving figure: the overloaded open-loop scenario replayed on
/// the modelled symmetric 20- and 56-core machines and the
/// heterogeneous 56-core machine (CPU pool), per tenancy policy ×
/// admission setting. The headline is the fair-policy block: `open`
/// admission lets queueing delay — and with it p99/p999 — diverge with
/// the backlog, while `bounded` and `shed` hold the served tail inside
/// the SLO and surface the overload as a counted shed rate instead.
pub fn serve_figure(params: &FigureParams) -> Vec<ServeRow> {
    let mut out = Vec::new();
    for (machine, machine_name) in [
        (Topology::broadwell20(), "sym20"),
        (Topology::cascadelake56(), "sym56"),
        (Topology::hetero56(), "hetero56"),
    ] {
        let cores = machine.class_cores(DeviceClass::Cpu);
        let sched = SchedConfig::fine_grained().with_seed(params.seed);
        for policy in TenancyPolicy::ALL {
            for admission in serve_admissions() {
                let spec = serve_spec(cores, admission, params);
                let sim = sim::replay_open_loop(
                    &spec,
                    &machine,
                    &sched,
                    &params.costs,
                    policy,
                )
                .expect("serve shapes are acyclic");
                out.push(ServeRow {
                    machine: machine_name,
                    policy: policy.name(),
                    admission: admission.name(),
                    attained_qps: sim.attained_qps,
                    offered_qps: spec.qps,
                    p99: sim.p99,
                    p999: sim.p999,
                    slo_attainment: sim.slo_attainment,
                    shed_rate: sim.shed_rate(),
                });
            }
        }
    }
    out
}

/// One static-vs-elastic pool comparison row on the modelled
/// heterogeneous 56-core machine ([`elastic_figure`]).
#[derive(Debug, Clone)]
pub struct ElasticRow {
    pub machine: &'static str,
    /// `"static"` (no controller) or `"elastic"`.
    pub mode: &'static str,
    /// Busy time over (all workers × makespan).
    pub utilization: f64,
    /// p99 latency over the interactive tenants, seconds.
    pub interactive_p99: f64,
    /// Virtual completion time of the whole mix, seconds.
    pub makespan: f64,
    /// Lend decisions that moved workers.
    pub lends: usize,
    /// Eager reclaims forced by pinned arrivals on the donor pool.
    pub snapbacks: usize,
    /// No pinned chunk ever ran on a borrowed worker.
    pub invariant_ok: bool,
}

impl ElasticRow {
    pub fn print(&self) {
        println!(
            "  {:<9} {:<8} util={:>5.1}% p99={:>7.2}ms makespan={:>7.2}ms \
             lends={} snapbacks={} invariant={}",
            self.machine,
            self.mode,
            self.utilization * 100.0,
            self.interactive_p99 * 1e3,
            self.makespan * 1e3,
            self.lends,
            self.snapbacks,
            if self.invariant_ok { "ok" } else { "VIOLATED" }
        );
    }
}

/// Interactive latency objective of the elastic figure: 0.5 ms — tight
/// enough that a burst queueing behind the batch breaches it on the
/// static assignment and keeps the controller's lend pressure on.
pub const ELASTIC_SLO: f64 = 0.0005;

/// The elastic figure's workload on the modelled hetero56: a deep
/// moldable batch backlog of many *small* pipelines (0.5 ms chunks, so
/// borrowed workers always find batch work and release it quickly),
/// bursts of pinned interactive tenants on the CPU pool, and one pinned
/// GPU pipeline mid-run whose arrival must snap borrowed workers home.
pub fn elastic_mix(cores: usize) -> Vec<sim::ElasticJob> {
    let per_item = 1e-4;
    let mut jobs: Vec<sim::ElasticJob> = (0..180)
        .map(|b| {
            sim::ElasticJob::new(&format!("batch{b}"), 0.0, 320, per_item)
                .moldable()
        })
        .collect();
    for i in 0..56 {
        let t = 0.02 + 0.015 * (i / 8) as f64 + 0.0005 * (i % 8) as f64;
        jobs.push(
            sim::ElasticJob::new(&format!("rq{i}"), t, cores * 4, per_item)
                .interactive(),
        );
    }
    jobs.push(sim::ElasticJob::new("gpu", 0.06, 512, per_item).pool(1));
    jobs
}

/// The elastic figure: [`elastic_mix`] replayed on the modelled
/// heterogeneous 56-core machine with pools held static vs resized by
/// the [`crate::sched::ScalingController`]. The headline: lending the
/// idle GPU pool's workers to the moldable batch lifts machine
/// utilization without costing the interactive tail — borrowed workers
/// only ever drain the batch, so home-worker timelines (and with them
/// interactive latencies) never get worse, and the pinned GPU arrival
/// snaps the lease back before its first chunk runs.
pub fn elastic_figure(params: &FigureParams) -> Vec<ElasticRow> {
    let topo = Arc::new(Topology::hetero56());
    let cores = topo.class_cores(DeviceClass::Cpu);
    let accel = topo.class_cores(DeviceClass::Gpu);
    let jobs = elastic_mix(cores);
    let cfg = ControllerCfg {
        slo: ELASTIC_SLO,
        min_workers: cores,
        max_workers: cores + accel,
        patience: 2,
        step: accel,
        ..ControllerCfg::default()
    };
    let mut out = Vec::new();
    for (mode, controller) in [("static", None), ("elastic", Some(cfg))] {
        let sim = sim::replay_elastic(
            &topo,
            &sim::ElasticSimSpec {
                jobs: jobs.clone(),
                seed: params.seed,
                controller,
                ..sim::ElasticSimSpec::default()
            },
        );
        out.push(ElasticRow {
            machine: "hetero56",
            mode,
            utilization: sim.utilization,
            interactive_p99: sim.interactive_p99,
            makespan: sim.makespan,
            lends: sim
                .decisions
                .iter()
                .filter(|d| matches!(d, ScaleDecision::Lend(_)))
                .count(),
            snapbacks: sim.snapbacks,
            invariant_ok: sim.invariant_ok,
        });
    }
    out
}

/// Regenerate one figure. [`FigureId::FigDag`] / [`FigureId::FigHetero`]
/// / [`FigureId::FigTenancy`] rows are mapped into the common [`Row`]
/// shape (machine in the scheme column, shape/policy in the victim
/// column, the comparison ratio in `vs_static`); use [`dag_figure`] /
/// [`hetero_figure`] / [`tenancy_figure`] directly for the structured
/// forms.
pub fn run_figure(id: FigureId, params: &FigureParams) -> Vec<Row> {
    let machine = id.machine();
    match id {
        FigureId::Fig7a | FigureId::Fig7b => cc_figure(
            &machine,
            QueueLayout::Centralized { atomic: false },
            params,
        ),
        FigureId::Fig8a | FigureId::Fig9a => {
            cc_figure(&machine, QueueLayout::PerCore, params)
        }
        FigureId::Fig8b | FigureId::Fig9b => {
            cc_figure(&machine, QueueLayout::PerGroup, params)
        }
        FigureId::Fig10a | FigureId::Fig10b => {
            linreg_figure(&machine, params)
        }
        FigureId::FigDag => {
            dag_figure(params).into_iter().map(dag_row_to_row).collect()
        }
        FigureId::FigHetero => hetero_figure(params)
            .into_iter()
            .map(hetero_row_to_row)
            .collect(),
        FigureId::FigTenancy => {
            let rows = tenancy_figure(params);
            tenancy_rows_to_rows(&rows)
        }
        FigureId::FigServe => {
            let rows = serve_figure(params);
            serve_rows_to_rows(&rows)
        }
        FigureId::FigElastic => {
            let rows = elastic_figure(params);
            elastic_rows_to_rows(&rows)
        }
    }
}

fn dag_row_to_row(r: DagRow) -> Row {
    Row {
        scheme: r.machine,
        victim: Some(r.shape),
        time: r.dag,
        vs_static: r.dag / r.barrier,
        steals: 0,
        cov: 0.0,
        queue_wait: 0.0,
        crit: Some(r.crit),
    }
}

fn hetero_row_to_row(r: HeteroRow) -> Row {
    Row {
        scheme: r.machine,
        victim: Some(r.policy),
        time: r.makespan,
        vs_static: r.vs_any,
        steals: 0,
        cov: 0.0,
        queue_wait: 0.0,
        crit: Some(r.crit),
    }
}

/// Map tenancy rows into the common [`Row`] shape: p99 slowdown in the
/// time column, its ratio vs the same machine's FIFO row in
/// `vs_static` (< 1 = the policy tames the tail).
fn tenancy_rows_to_rows(rows: &[TenancyRow]) -> Vec<Row> {
    rows.iter()
        .map(|r| {
            let fifo_p99 = rows
                .iter()
                .find(|f| f.machine == r.machine && f.policy == "fifo")
                .map(|f| f.p99_slowdown)
                .unwrap_or(r.p99_slowdown);
            Row {
                scheme: r.machine,
                victim: Some(r.policy),
                time: r.p99_slowdown,
                vs_static: if fifo_p99 > 0.0 {
                    r.p99_slowdown / fifo_p99
                } else {
                    1.0
                },
                steals: 0,
                cov: 0.0,
                queue_wait: 0.0,
                // slowdown rows aggregate many graphs; no single chain
                crit: None,
            }
        })
        .collect()
}

/// Map serve rows into the common [`Row`] shape: p99 latency in the
/// time column, its ratio vs the same machine+policy `open` row in
/// `vs_static` (< 1 = admission control tames the tail), and the
/// policy/admission pair in the victim column.
fn serve_rows_to_rows(rows: &[ServeRow]) -> Vec<Row> {
    fn combo(policy: &str, admission: &str) -> &'static str {
        match (policy, admission) {
            ("fifo", "open") => "fifo/open",
            ("fifo", "bounded") => "fifo/bounded",
            ("fifo", "shed") => "fifo/shed",
            ("fair", "open") => "fair/open",
            ("fair", "bounded") => "fair/bounded",
            ("fair", "shed") => "fair/shed",
            ("priority", "open") => "priority/open",
            ("priority", "bounded") => "priority/bounded",
            ("priority", "shed") => "priority/shed",
            _ => "?",
        }
    }
    rows.iter()
        .map(|r| {
            let open_p99 = rows
                .iter()
                .find(|o| {
                    o.machine == r.machine
                        && o.policy == r.policy
                        && o.admission == "open"
                })
                .map(|o| o.p99)
                .unwrap_or(r.p99);
            Row {
                scheme: r.machine,
                victim: Some(combo(r.policy, r.admission)),
                time: r.p99,
                vs_static: if open_p99 > 0.0 {
                    r.p99 / open_p99
                } else {
                    1.0
                },
                steals: 0,
                cov: 0.0,
                queue_wait: 0.0,
                // tail-latency rows aggregate many requests; no chain
                crit: None,
            }
        })
        .collect()
}

/// Map elastic rows into the common [`Row`] shape: interactive p99 in
/// the time column, its ratio vs the static row in `vs_static` (<= 1 =
/// elastic pools never cost the interactive tail), and the mode in the
/// victim column.
fn elastic_rows_to_rows(rows: &[ElasticRow]) -> Vec<Row> {
    rows.iter()
        .map(|r| {
            let static_p99 = rows
                .iter()
                .find(|s| s.machine == r.machine && s.mode == "static")
                .map(|s| s.interactive_p99)
                .unwrap_or(r.interactive_p99);
            Row {
                scheme: r.machine,
                victim: Some(r.mode),
                time: r.interactive_p99,
                vs_static: if static_p99 > 0.0 {
                    r.interactive_p99 / static_p99
                } else {
                    1.0
                },
                steals: 0,
                cov: 0.0,
                queue_wait: 0.0,
                // tail rows aggregate many interactive jobs; no chain
                crit: None,
            }
        })
        .collect()
}

/// Print a figure with the paper's expected shape annotated.
pub fn print_figure(id: FigureId, params: &FigureParams) -> Vec<Row> {
    println!("== {} ==", id.name());
    if id == FigureId::FigDag {
        let dag_rows = dag_figure(params);
        for r in &dag_rows {
            r.print();
        }
        return dag_rows.into_iter().map(dag_row_to_row).collect();
    }
    if id == FigureId::FigHetero {
        let rows = hetero_figure(params);
        for r in &rows {
            r.print();
        }
        return rows.into_iter().map(hetero_row_to_row).collect();
    }
    if id == FigureId::FigTenancy {
        let rows = tenancy_figure(params);
        for r in &rows {
            r.print();
        }
        return tenancy_rows_to_rows(&rows);
    }
    if id == FigureId::FigServe {
        let rows = serve_figure(params);
        for r in &rows {
            r.print();
        }
        return serve_rows_to_rows(&rows);
    }
    if id == FigureId::FigElastic {
        let rows = elastic_figure(params);
        for r in &rows {
            r.print();
        }
        return elastic_rows_to_rows(&rows);
    }
    let rows = run_figure(id, params);
    for r in &rows {
        r.print();
    }
    if let Some(best) = rows
        .iter()
        .min_by(|a, b| a.time.total_cmp(&b.time))
    {
        println!(
            "  -> best: {} {} ({:.1}% vs STATIC)",
            best.scheme,
            best.victim.unwrap_or("-"),
            (1.0 - best.vs_static) * 100.0
        );
    }
    rows
}

// ---------------------------------------------------------------------------
// ablations (§4 SS omission, §5 lock vs atomic)
// ---------------------------------------------------------------------------

/// §4: SS under central-queue contention vs MFSC (why SS is omitted
/// from the figures). Returns `(ss_time, mfsc_time)` per machine.
pub fn ablation_ss(params: &FigureParams) -> Vec<(String, f64, f64)> {
    let g = params.build_graph();
    let iters = params.iterations.unwrap_or(3);
    let mut out = Vec::new();
    for machine in [Topology::broadwell20(), Topology::cascadelake56()] {
        let base = SchedConfig { seed: params.seed, ..SchedConfig::default() };
        let (t_ss, _) = cc::simulate_run(
            &g,
            &machine,
            &base.clone().with_scheme(Scheme::Ss),
            &params.costs,
            iters,
            params.app_costs.cc_per_row,
            params.app_costs.cc_per_nnz,
        );
        let (t_mfsc, _) = cc::simulate_run(
            &g,
            &machine,
            &base.clone().with_scheme(Scheme::Mfsc),
            &params.costs,
            iters,
            params.app_costs.cc_per_row,
            params.app_costs.cc_per_nnz,
        );
        out.push((machine.name.clone(), t_ss, t_mfsc));
    }
    out
}

/// §5: locked vs atomic central queue across schemes.
/// Returns `(scheme, locked_time, atomic_time)`.
pub fn ablation_lock_vs_atomic(
    machine: &Topology,
    params: &FigureParams,
) -> Vec<(&'static str, f64, f64)> {
    let g = params.build_graph();
    let iters = params.iterations.unwrap_or(3);
    let mut out = Vec::new();
    for scheme in [Scheme::Ss, Scheme::Mfsc, Scheme::Gss, Scheme::Fac2] {
        let time = |atomic: bool| {
            let sched = SchedConfig {
                scheme,
                layout: QueueLayout::Centralized { atomic },
                seed: params.seed,
                ..SchedConfig::default()
            };
            cc::simulate_run(
                &g,
                machine,
                &sched,
                &params.costs,
                iters,
                params.app_costs.cc_per_row,
                params.app_costs.cc_per_nnz,
            )
            .0
        };
        out.push((scheme.name(), time(false), time(true)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_ids_parse() {
        for id in FigureId::ALL {
            let key = &id.name()[4..7]; // "7a:" etc
            let key = key.trim_end_matches([':', ' ']);
            assert_eq!(FigureId::parse(key), Some(id), "{key}");
        }
        assert_eq!(FigureId::parse("11z"), None);
    }

    #[test]
    fn fig7a_shape_dynamic_beats_static() {
        // Full SNAP-size graph, fixed iteration count: the Fig. 7a
        // headline — MFSC (and the dynamic pack) beats STATIC on the
        // sparse CC workload.
        let params = FigureParams {
            iterations: Some(8),
            ..FigureParams::default()
        };
        let rows = run_figure(FigureId::Fig7a, &params);
        assert_eq!(rows.len(), 10);
        let get = |s: &str| rows.iter().find(|r| r.scheme == s).unwrap();
        assert!(
            get("MFSC").time < get("STATIC").time,
            "MFSC {} vs STATIC {}",
            get("MFSC").time,
            get("STATIC").time
        );
        // "almost all scheduling techniques outperform the default
        // STATIC" (§4; the paper's own exception is FISS)
        let winners = rows
            .iter()
            .filter(|r| r.scheme != "STATIC" && r.vs_static < 1.0)
            .count();
        assert!(winners >= 6, "only {winners}/9 dynamic schemes beat STATIC");
        // STATIC is a valid baseline row
        assert!((get("STATIC").vs_static - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fig10_shape_static_wins_tiny() {
        let params = FigureParams::tiny();
        let rows = run_figure(FigureId::Fig10a, &params);
        let t_static =
            rows.iter().find(|r| r.scheme == "STATIC").unwrap().time;
        for r in &rows {
            assert!(
                r.time >= t_static * 0.98,
                "{} ({}) beat STATIC ({t_static}) on dense LR",
                r.scheme,
                r.time
            );
        }
    }

    #[test]
    fn stealing_figures_have_40_rows() {
        let params = FigureParams::tiny();
        let rows = run_figure(FigureId::Fig8a, &params);
        assert_eq!(rows.len(), 40, "10 schemes x 4 victims");
        assert!(rows.iter().all(|r| r.victim.is_some()));
    }

    #[test]
    fn dag_figure_covers_both_machines_and_overlaps_the_diamond() {
        let params = FigureParams::tiny();
        let rows = dag_figure(&params);
        assert_eq!(rows.len(), 6, "2 machines x 3 shapes");
        for machine in ["broadwell20", "cascadelake56"] {
            let diamond = rows
                .iter()
                .find(|r| r.machine == machine && r.shape == "diamond")
                .unwrap();
            assert!(
                diamond.dag < diamond.barrier,
                "{machine}: dag {} vs barrier {}",
                diamond.dag,
                diamond.barrier
            );
        }
        // app shapes never get *slower* than the barrier baseline by
        // more than replay noise (the tiny cc shape spans ~tens of µs,
        // so a single modelled OS-interference event is a few percent)
        for r in &rows {
            assert!(
                r.dag <= r.barrier * 1.15,
                "{} {}: dag {} vs barrier {}",
                r.machine,
                r.shape,
                r.dag,
                r.barrier
            );
        }
        // critical-path attribution: every replay has a chain covering
        // a meaningful share of its makespan, and never more than all
        // of it
        for r in &rows {
            assert!(
                r.crit > 0.0 && r.crit <= 1.0 + 1e-9,
                "{} {}: crit {}",
                r.machine,
                r.shape,
                r.crit
            );
        }
        // mapped Row form preserves the comparison
        let mapped = run_figure(FigureId::FigDag, &params);
        assert_eq!(mapped.len(), rows.len());
        assert!(mapped.iter().all(|r| r.vs_static <= 1.15));
        assert!(mapped.iter().all(|r| r.crit.is_some()));
    }

    #[test]
    fn hetero_figure_placement_beats_all_cpu_on_both_machines() {
        let params = FigureParams {
            // recorded costs: deterministic, no OS-interference noise
            costs: CostModel::recorded(),
            ..FigureParams::tiny()
        };
        let rows = hetero_figure(&params);
        assert_eq!(rows.len(), 6, "2 machines x 3 policies");
        for machine in ["hetero20", "hetero56"] {
            let get = |policy: &str| {
                rows.iter()
                    .find(|r| r.machine == machine && r.policy == policy)
                    .unwrap()
            };
            let (any, pinned, auto) =
                (get("any"), get("pinned"), get("auto"));
            assert!((any.vs_any - 1.0).abs() < 1e-12);
            assert!(
                pinned.makespan < any.makespan,
                "{machine}: pinned {} vs any {}",
                pinned.makespan,
                any.makespan
            );
            assert!(
                auto.makespan < any.makespan,
                "{machine}: auto {} vs any {}",
                auto.makespan,
                any.makespan
            );
            // autotuned placement is at least competitive with the
            // hand-pinned assignment (it searches a superset)
            assert!(
                auto.makespan <= pinned.makespan * 1.05,
                "{machine}: auto {} vs pinned {}",
                auto.makespan,
                pinned.makespan
            );
        }
        // mapped Row form preserves the comparison (map the rows we
        // already computed — re-running the figure would double the
        // tuner cost for a shape check)
        for r in &rows {
            assert!(
                r.crit > 0.0 && r.crit <= 1.0 + 1e-9,
                "{} {}: crit {}",
                r.machine,
                r.policy,
                r.crit
            );
        }
        let mapped: Vec<Row> =
            rows.into_iter().map(hetero_row_to_row).collect();
        assert_eq!(mapped.len(), 6);
        assert!(mapped.iter().all(|r| r.vs_static <= 1.0 + 1e-12));
    }

    #[test]
    fn tenancy_figure_fair_and_priority_beat_fifo_on_p99() {
        // The acceptance criterion: under bursty arrivals, Fair and
        // Priority beat FIFO on tail tenant slowdown on every modelled
        // machine — including the 56-core ones.
        let params = FigureParams {
            // recorded costs: deterministic, no OS-interference noise
            costs: CostModel::recorded(),
            ..FigureParams::tiny()
        };
        let rows = tenancy_figure(&params);
        assert_eq!(rows.len(), 9, "3 machines x 3 policies");
        for machine in ["sym20", "sym56", "hetero56"] {
            let get = |policy: &str| {
                rows.iter()
                    .find(|r| r.machine == machine && r.policy == policy)
                    .unwrap()
            };
            let (fifo, fair, prio) =
                (get("fifo"), get("fair"), get("priority"));
            assert!(
                fair.p99_slowdown < fifo.p99_slowdown,
                "{machine}: fair p99 {} vs fifo p99 {}",
                fair.p99_slowdown,
                fifo.p99_slowdown
            );
            assert!(
                prio.p99_slowdown < fifo.p99_slowdown,
                "{machine}: priority p99 {} vs fifo p99 {}",
                prio.p99_slowdown,
                fifo.p99_slowdown
            );
            assert!(
                fair.fairness > fifo.fairness,
                "{machine}: fair index {} vs fifo index {}",
                fair.fairness,
                fifo.fairness
            );
        }
        // mapped Row form preserves the comparison
        let mapped = tenancy_rows_to_rows(&rows);
        assert_eq!(mapped.len(), 9);
        for r in mapped.iter().filter(|r| r.victim != Some("fifo")) {
            assert!(r.vs_static < 1.0, "{:?}", r);
        }
    }

    #[test]
    fn tenancy_arrival_patterns_generate_valid_mixes() {
        for pattern in [
            ArrivalPattern::Burst,
            ArrivalPattern::Uniform,
            ArrivalPattern::Poisson,
        ] {
            let tenants = tenancy_tenants(8, pattern, 7);
            assert_eq!(tenants.len(), 12, "2 batch + 10 interactive");
            assert!(tenants.iter().all(|t| t.arrival >= 0.0));
            assert!(tenants.iter().all(|t| t.shape.validate().is_ok()));
            // batch tenants anchor the burst at t=0
            assert_eq!(tenants[0].arrival, 0.0);
            assert!(tenants[2..].iter().all(|t| t.arrival > 0.0));
        }
    }

    #[test]
    fn serve_figure_bounded_and_shed_hold_the_slo_where_open_diverges() {
        // The acceptance criterion: on every modelled machine, under
        // the fair policy, bounded and shed admission hold the served
        // p99 inside the SLO at ≥90% attainment while open admission's
        // p99 diverges past it under the same 1.5× offered load.
        let params = FigureParams {
            // recorded costs: deterministic, no OS-interference noise
            costs: CostModel::recorded(),
            ..FigureParams::tiny()
        };
        let rows = serve_figure(&params);
        assert_eq!(rows.len(), 27, "3 machines x 3 policies x 3 admissions");
        for machine in ["sym20", "sym56", "hetero56"] {
            let get = |admission: &str| {
                rows.iter()
                    .find(|r| {
                        r.machine == machine
                            && r.policy == "fair"
                            && r.admission == admission
                    })
                    .unwrap()
            };
            let open = get("open");
            assert_eq!(open.shed_rate, 0.0);
            assert!(
                open.p99 > SERVE_SLO,
                "{machine}: open p99 {} should diverge past the SLO",
                open.p99
            );
            for r in [get("bounded"), get("shed")] {
                assert!(
                    r.p99 <= SERVE_SLO,
                    "{machine}/{}: p99 {} vs slo {SERVE_SLO}",
                    r.admission,
                    r.p99
                );
                assert!(
                    r.slo_attainment >= 0.9,
                    "{machine}/{}: attainment {}",
                    r.admission,
                    r.slo_attainment
                );
                assert!(
                    r.shed_rate > 0.0,
                    "{machine}/{}: overload must shed",
                    r.admission
                );
                // shedding must not collapse throughput: the served
                // rate stays a solid fraction of what open serves
                assert!(
                    r.attained_qps > open.attained_qps * 0.5,
                    "{machine}/{}: attained {} vs open {}",
                    r.admission,
                    r.attained_qps,
                    open.attained_qps
                );
            }
        }
        // mapped Row form preserves the comparison
        let mapped = serve_rows_to_rows(&rows);
        assert_eq!(mapped.len(), 27);
        for r in mapped.iter().filter(|r| {
            r.victim == Some("fair/bounded") || r.victim == Some("fair/shed")
        }) {
            assert!(r.vs_static < 1.0, "{:?}", r);
        }
    }

    #[test]
    fn elastic_figure_beats_static_on_util_and_interactive_p99() {
        // The acceptance criterion: on the modelled hetero56, elastic
        // pools are at least as good as static on BOTH machine
        // utilization and interactive p99, the controller lent during
        // the bursts, and the pinned GPU arrival forced a snap-back.
        let params = FigureParams::tiny();
        let rows = elastic_figure(&params);
        assert_eq!(rows.len(), 2, "static + elastic");
        let stat = rows.iter().find(|r| r.mode == "static").unwrap();
        let elas = rows.iter().find(|r| r.mode == "elastic").unwrap();
        assert!(
            stat.invariant_ok && elas.invariant_ok,
            "pinned work never ran on a borrowed worker"
        );
        assert_eq!((stat.lends, stat.snapbacks), (0, 0));
        assert!(elas.lends >= 1, "the controller lent into the bursts");
        assert!(
            elas.snapbacks >= 1,
            "the pinned GPU arrival snapped workers home"
        );
        assert!(
            elas.utilization >= stat.utilization,
            "elastic util {} < static {}",
            elas.utilization,
            stat.utilization
        );
        assert!(
            elas.interactive_p99 <= stat.interactive_p99,
            "elastic p99 {} > static {}",
            elas.interactive_p99,
            stat.interactive_p99
        );
        assert!(
            elas.makespan <= stat.makespan,
            "elastic makespan {} > static {}",
            elas.makespan,
            stat.makespan
        );
        // mapped Row form preserves the comparison
        let mapped = run_figure(FigureId::FigElastic, &params);
        assert_eq!(mapped.len(), 2);
        assert!(mapped.iter().all(|r| r.vs_static <= 1.0 + 1e-12));
        assert!(mapped.iter().all(|r| r.crit.is_none()));
    }

    #[test]
    fn ablation_ss_explodes_tiny() {
        let params = FigureParams::tiny();
        for (machine, t_ss, t_mfsc) in ablation_ss(&params) {
            assert!(
                t_ss > 2.0 * t_mfsc,
                "{machine}: SS {t_ss} vs MFSC {t_mfsc}"
            );
        }
    }

    #[test]
    fn ablation_atomic_helps_fine_grained_tiny() {
        let params = FigureParams::tiny();
        let rows =
            ablation_lock_vs_atomic(&Topology::cascadelake56(), &params);
        let ss = rows.iter().find(|(s, _, _)| *s == "SS").unwrap();
        assert!(
            ss.2 < ss.1,
            "atomic must beat locked for SS: {} vs {}",
            ss.2,
            ss.1
        );
    }
}
