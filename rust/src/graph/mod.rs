//! Graph substrate for the connected-components workload.
//!
//! The paper uses the Stanford SNAP Amazon co-purchase graph (403,394
//! nodes / 3,387,388 directed edges) scaled up 50×. That dataset is not
//! redistributable here, so [`generator`] synthesises a co-purchase-like
//! graph with the same density and heavy-tailed degree distribution
//! (copying model, per Leskovec et al.'s analysis of the viral-marketing
//! data), and [`scale`] applies the paper's block scale-up. [`snap`]
//! reads the real SNAP edge-list format for users who have the file.

pub mod generator;
pub mod scale;
pub mod snap;

pub use generator::{amazon_like, SnapGraph};
pub use scale::scale_up;
