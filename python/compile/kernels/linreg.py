"""Pallas kernels for the linear-regression pipeline (Listing 2).

Four kernels cover the dense hot-spots of the pipeline:

- ``colstats``    — column sum / sum-of-squares (lines 8-9, mean/stddev)
- ``standardize`` — ``(X - mean) / std`` (line 10)
- ``syrk``        — ``A = X^T X`` row-block partial (line 12)
- ``gemv``        — ``b = X^T y`` row-block partial (line 15)

TPU adaptation: ``syrk`` is expressed as an MXU-shaped 128x128-tile
matmul with a k-grid accumulating into the output block; ``colstats`` /
``standardize`` are VPU elementwise tiles. All are lowered with
``interpret=True`` for CPU-PJRT execution (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128  # k-dimension tile for syrk/gemv, row tile elsewhere
COL_TILE = 128


def _colstats_kernel(x_ref, s_ref, sq_ref):
    i = pl.program_id(0)
    x = x_ref[...]

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.sum(x, axis=0)
        sq_ref[...] = jnp.sum(x * x, axis=0)

    @pl.when(i != 0)
    def _fold():
        s_ref[...] += jnp.sum(x, axis=0)
        sq_ref[...] += jnp.sum(x * x, axis=0)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def colstats(x, *, row_tile=ROW_TILE):
    """``(sum(X, axis=0), sum(X*X, axis=0))`` for an ``[R, C]`` block."""
    rows, cols = x.shape
    assert rows % row_tile == 0, rows
    out = jax.ShapeDtypeStruct((cols,), jnp.float32)
    return pl.pallas_call(
        _colstats_kernel,
        grid=(rows // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, cols), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ),
        out_shape=(out, out),
        interpret=True,
    )(x)


def _standardize_kernel(x_ref, m_ref, s_ref, o_ref):
    o_ref[...] = (x_ref[...] - m_ref[...]) / s_ref[...]


@functools.partial(jax.jit, static_argnames=("row_tile",))
def standardize(x, mean, std, *, row_tile=ROW_TILE):
    """``(X - mean) / std`` column-broadcast over an ``[R, C]`` block."""
    rows, cols = x.shape
    assert rows % row_tile == 0, rows
    return pl.pallas_call(
        _standardize_kernel,
        grid=(rows // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x, mean.reshape(1, cols), std.reshape(1, cols))


def _syrk_kernel(x_ref, a_ref):
    k = pl.program_id(0)
    x = x_ref[...]  # [KT, C] slab of X
    partial = jnp.dot(x.T, x, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        a_ref[...] = partial

    @pl.when(k != 0)
    def _fold():
        a_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("row_tile",))
def syrk(x, *, row_tile=ROW_TILE):
    """``X^T X`` for an ``[R, C]`` block, accumulated over k-tiles of rows."""
    rows, cols = x.shape
    assert rows % row_tile == 0, rows
    return pl.pallas_call(
        _syrk_kernel,
        grid=(rows // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, cols), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((cols, cols), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((cols, cols), jnp.float32),
        interpret=True,
    )(x)


def _gemv_kernel(x_ref, y_ref, b_ref):
    k = pl.program_id(0)
    partial = jnp.dot(
        x_ref[...].T, y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == 0)
    def _init():
        b_ref[...] = partial

    @pl.when(k != 0)
    def _fold():
        b_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("row_tile",))
def gemv(x, y, *, row_tile=ROW_TILE):
    """``X^T y`` for an ``[R, C]`` block, accumulated over k-tiles of rows."""
    rows, cols = x.shape
    assert rows % row_tile == 0, rows
    return pl.pallas_call(
        _gemv_kernel,
        grid=(rows // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, cols), lambda k: (k, 0)),
            pl.BlockSpec((row_tile,), lambda k: (k,)),
        ],
        out_specs=pl.BlockSpec((cols,), lambda k: (0,)),
        out_shape=jax.ShapeDtypeStruct((cols,), jnp.float32),
        interpret=True,
    )(x, y)
