//! Trace export: merge drained ring buffers into a Chrome trace-event
//! JSON file (loadable in Perfetto / `chrome://tracing`) and distill a
//! compact [`ObsSummary`] for the CLI.
//!
//! The Chrome format is the stable subset every viewer understands: a
//! top-level `traceEvents` array of objects with `ph` (phase), `pid`,
//! `tid`, `ts` (microseconds, f64) and `name`. We emit one `tid` lane
//! per worker (plus the control lane), `B`/`E` duration pairs for
//! chunk execution, `i` instants for everything else, `C` counter
//! tracks for backlog and admissions, and `M` metadata naming the
//! lanes. Written via `util::json` — no serializer dependency.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use crate::obs::trace::{tag_name, TraceEvent, TraceKind};
use crate::util::json::{self, Json};

/// The process id used for every emitted event (single-process traces).
const TRACE_PID: f64 = 1.0;

/// Queue-delay histogram buckets, log decades in nanoseconds:
/// `<10µs, <100µs, <1ms, <10ms, <100ms, ≥100ms`.
const DELAY_BUCKET_EDGES_NS: [u64; 5] = [10_000, 100_000, 1_000_000, 10_000_000, 100_000_000];
const DELAY_BUCKET_LABELS: [&str; 6] = ["<10us", "<100us", "<1ms", "<10ms", "<100ms", ">=100ms"];

fn bucket_of(delay_ns: u64) -> usize {
    DELAY_BUCKET_EDGES_NS
        .iter()
        .position(|edge| delay_ns < *edge)
        .unwrap_or(DELAY_BUCKET_EDGES_NS.len())
}

/// Resolve a hash to a human-readable label: the interned string when
/// one exists (tags always; job names when a submission site interned
/// them), a short hex form otherwise.
fn label(hash: u64) -> String {
    if hash == 0 {
        return "(untagged)".to_string();
    }
    tag_name(hash).unwrap_or_else(|| format!("{:012x}", hash & 0xFFFF_FFFF_FFFF))
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn event_args(e: &TraceEvent) -> Json {
    let mut fields = vec![("job", Json::Num(e.job as f64))];
    if e.name_hash != 0 {
        fields.push(("name", Json::Str(label(e.name_hash))));
    }
    if e.tag_hash != 0 {
        fields.push(("tag", Json::Str(label(e.tag_hash))));
    }
    obj(fields)
}

/// Build the Chrome trace-event document for a drained event stream.
/// Events must be timestamp-sorted, which [`crate::obs::trace::drain`]
/// guarantees.
pub fn chrome_trace_json(events: &[TraceEvent]) -> Json {
    let mut out: Vec<Json> = Vec::new();

    // Lane metadata: name every tid that appears. The highest lane is
    // the control lane (submission-side events) by construction.
    let max_worker = events.iter().map(|e| e.worker).max();
    for w in events.iter().map(|e| e.worker).collect::<std::collections::BTreeSet<_>>() {
        let name = if Some(w) == max_worker && events.iter().any(|e| {
            e.worker == w && matches!(e.kind, TraceKind::Admit | TraceKind::Shed | TraceKind::Enqueue)
        }) {
            "control".to_string()
        } else {
            format!("worker {}", w)
        };
        out.push(obj(vec![
            ("ph", Json::Str("M".to_string())),
            ("name", Json::Str("thread_name".to_string())),
            ("pid", Json::Num(TRACE_PID)),
            ("tid", Json::Num(w as f64)),
            ("ts", Json::Num(0.0)),
            ("args", obj(vec![("name", Json::Str(name))])),
        ]));
    }

    // Counter-track state, sampled at each contributing event.
    let (mut enq, mut done, mut admitted, mut shed) = (0u64, 0u64, 0u64, 0u64);
    // Per-lane open-slice depth so an orphaned TaskEnd (its TaskStart
    // was overwritten in the ring) cannot emit an unbalanced `E`.
    let mut depth: BTreeMap<u32, u64> = BTreeMap::new();

    for e in events {
        let ts_us = e.ts_ns as f64 / 1_000.0;
        let base = |ph: &str| {
            vec![
                ("ph", Json::Str(ph.to_string())),
                ("pid", Json::Num(TRACE_PID)),
                ("tid", Json::Num(e.worker as f64)),
                ("ts", Json::Num(ts_us)),
            ]
        };
        match e.kind {
            TraceKind::TaskStart => {
                let mut f = base("B");
                f.push(("name", Json::Str(format!("run {}", label(e.name_hash)))));
                f.push(("cat", Json::Str("task".to_string())));
                f.push(("args", event_args(e)));
                out.push(obj(f));
                *depth.entry(e.worker).or_insert(0) += 1;
            }
            TraceKind::TaskEnd => {
                let d = depth.entry(e.worker).or_insert(0);
                if *d > 0 {
                    *d -= 1;
                    let mut f = base("E");
                    f.push(("name", Json::Str(format!("run {}", label(e.name_hash)))));
                    f.push(("cat", Json::Str("task".to_string())));
                    out.push(obj(f));
                }
            }
            kind => {
                let mut f = base("i");
                f.push(("name", Json::Str(kind.name().to_string())));
                f.push(("cat", Json::Str("sched".to_string())));
                f.push(("s", Json::Str("t".to_string())));
                f.push(("args", event_args(e)));
                out.push(obj(f));
            }
        }
        // Counter tracks: backlog (enqueued minus completed jobs) and
        // cumulative admission decisions.
        match e.kind {
            TraceKind::Enqueue | TraceKind::NodeComplete | TraceKind::Cancel => {
                match e.kind {
                    TraceKind::Enqueue => enq += 1,
                    _ => done += 1,
                }
                out.push(obj(vec![
                    ("ph", Json::Str("C".to_string())),
                    ("pid", Json::Num(TRACE_PID)),
                    ("name", Json::Str("backlog".to_string())),
                    ("ts", Json::Num(ts_us)),
                    ("args", obj(vec![("jobs", Json::Num(enq.saturating_sub(done) as f64))])),
                ]));
            }
            TraceKind::Admit | TraceKind::Shed => {
                match e.kind {
                    TraceKind::Admit => admitted += 1,
                    _ => shed += 1,
                }
                out.push(obj(vec![
                    ("ph", Json::Str("C".to_string())),
                    ("pid", Json::Num(TRACE_PID)),
                    ("name", Json::Str("admissions".to_string())),
                    ("ts", Json::Num(ts_us)),
                    ("args", obj(vec![
                        ("admitted", Json::Num(admitted as f64)),
                        ("shed", Json::Num(shed as f64)),
                    ])),
                ]));
            }
            _ => {}
        }
    }

    obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Serialize a drained event stream to `path` as Chrome trace-event
/// JSON. Load the file in <https://ui.perfetto.dev> or
/// `chrome://tracing`.
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> io::Result<()> {
    fs::write(path, json::to_string(&chrome_trace_json(events)))
}

/// Compact digest of a drained trace, printed by the CLI after traced
/// runs: steal efficiency, park/unpark churn, and a per-tag queue-delay
/// histogram (first `Dispatch` minus `Enqueue` per job).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsSummary {
    pub events: usize,
    pub steals: u64,
    pub failed_steals: u64,
    pub parks: u64,
    pub unparks: u64,
    /// tag hash -> delay histogram (buckets per [`DELAY_BUCKET_LABELS`]).
    pub queue_delay_hist: BTreeMap<u64, [u64; 6]>,
    /// Summed `WorkerStats.queue_wait` (seconds) when the caller has a
    /// `SchedReport` in hand — see [`ObsSummary::with_queue_wait`].
    pub queue_wait_secs: Option<f64>,
}

impl ObsSummary {
    pub fn from_events(events: &[TraceEvent]) -> ObsSummary {
        let mut s = ObsSummary { events: events.len(), ..ObsSummary::default() };
        // (tag, job) -> (enqueue ts, first dispatch ts)
        let mut jobs: BTreeMap<(u64, u64), (Option<u64>, Option<u64>)> = BTreeMap::new();
        for e in events {
            match e.kind {
                TraceKind::Steal => s.steals += 1,
                TraceKind::FailedSteal => s.failed_steals += 1,
                TraceKind::Park => s.parks += 1,
                TraceKind::Unpark => s.unparks += 1,
                TraceKind::Enqueue => {
                    let entry = jobs.entry((e.tag_hash, e.job)).or_default();
                    entry.0.get_or_insert(e.ts_ns);
                }
                TraceKind::Dispatch => {
                    let entry = jobs.entry((e.tag_hash, e.job)).or_default();
                    entry.1.get_or_insert(e.ts_ns);
                }
                _ => {}
            }
        }
        for ((tag, _job), (enq, disp)) in jobs {
            if let (Some(e), Some(d)) = (enq, disp) {
                let hist = s.queue_delay_hist.entry(tag).or_insert([0; 6]);
                hist[bucket_of(d.saturating_sub(e))] += 1;
            }
        }
        s
    }

    /// Attach the summed per-worker `queue_wait` from a `SchedReport`,
    /// surfacing queue-acquisition overhead next to the event digest.
    pub fn with_queue_wait(mut self, secs: f64) -> ObsSummary {
        self.queue_wait_secs = Some(secs);
        self
    }

    /// `steals / (steals + failed_steals)`, or `None` when no steal
    /// rounds ran.
    pub fn steal_efficiency(&self) -> Option<f64> {
        let total = self.steals + self.failed_steals;
        (total > 0).then(|| self.steals as f64 / total as f64)
    }
}

impl fmt::Display for ObsSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "obs summary: {} events", self.events)?;
        match self.steal_efficiency() {
            Some(eff) => writeln!(
                f,
                "  steal efficiency: {:.1}% ({} hit / {} missed)",
                eff * 100.0,
                self.steals,
                self.failed_steals
            )?,
            None => writeln!(f, "  steal efficiency: n/a (no steal rounds)")?,
        }
        writeln!(f, "  park/unpark churn: {} parks, {} unparks", self.parks, self.unparks)?;
        if let Some(qw) = self.queue_wait_secs {
            writeln!(f, "  worker queue_wait total: {:.6} s", qw)?;
        }
        if !self.queue_delay_hist.is_empty() {
            writeln!(f, "  queue delay (enqueue -> first dispatch), jobs per tag:")?;
            for (tag, hist) in &self.queue_delay_hist {
                let cells: Vec<String> = DELAY_BUCKET_LABELS
                    .iter()
                    .zip(hist.iter())
                    .map(|(l, n)| format!("{}:{}", l, n))
                    .collect();
                writeln!(f, "    {:<12} {}", label(*tag), cells.join(" "))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::intern_tag;

    fn ev(ts_ns: u64, worker: u32, kind: TraceKind, job: u64, tag_hash: u64) -> TraceEvent {
        TraceEvent { ts_ns, worker, kind, job, name_hash: 0, tag_hash }
    }

    #[test]
    fn delay_buckets_split_on_log_decades() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(9_999), 0);
        assert_eq!(bucket_of(10_000), 1);
        assert_eq!(bucket_of(999_999), 2);
        assert_eq!(bucket_of(5_000_000), 3);
        assert_eq!(bucket_of(250_000_000), 5);
    }

    #[test]
    fn summary_counts_steals_parks_and_queue_delay() {
        let tag = intern_tag("export-test");
        let events = vec![
            ev(0, 2, TraceKind::Enqueue, 1, tag),
            ev(5_000, 0, TraceKind::Dispatch, 1, tag),
            ev(6_000, 0, TraceKind::Dispatch, 1, tag), // later re-dispatch ignored
            ev(7_000, 1, TraceKind::Steal, 1, tag),
            ev(8_000, 1, TraceKind::FailedSteal, u64::MAX, 0),
            ev(9_000, 1, TraceKind::Park, u64::MAX, 0),
            ev(9_500, 1, TraceKind::Unpark, u64::MAX, 0),
            ev(10_000, 2, TraceKind::Enqueue, 2, tag),
            ev(2_010_000, 0, TraceKind::Dispatch, 2, tag),
        ];
        let s = ObsSummary::from_events(&events);
        assert_eq!(s.events, 9);
        assert_eq!((s.steals, s.failed_steals), (1, 1));
        assert_eq!((s.parks, s.unparks), (1, 1));
        assert_eq!(s.steal_efficiency(), Some(0.5));
        let hist = s.queue_delay_hist.get(&tag).expect("tag histogram");
        assert_eq!(hist[0], 1, "5us delay lands in <10us");
        assert_eq!(hist[3], 1, "2ms delay lands in <10ms");
        let rendered = format!("{}", s.with_queue_wait(0.5));
        assert!(rendered.contains("export-test"));
        assert!(rendered.contains("queue_wait total: 0.500000 s"));
    }

    #[test]
    fn empty_summary_renders_without_panicking() {
        let s = ObsSummary::from_events(&[]);
        assert_eq!(s.steal_efficiency(), None);
        let rendered = format!("{}", s);
        assert!(rendered.contains("0 events"));
        assert!(rendered.contains("n/a"));
    }

    #[test]
    fn chrome_trace_round_trips_through_util_json() {
        let tag = intern_tag("chrome-test");
        let events = vec![
            ev(1_000, 2, TraceKind::Admit, 0, tag),
            ev(1_100, 2, TraceKind::Enqueue, 0, tag),
            ev(2_000, 0, TraceKind::Dispatch, 0, tag),
            ev(2_000, 0, TraceKind::TaskStart, 0, tag),
            ev(3_000, 0, TraceKind::TaskEnd, 0, tag),
            ev(3_500, 0, TraceKind::NodeComplete, 0, tag),
            ev(4_000, 2, TraceKind::Shed, 1, tag),
        ];
        let doc = json::parse(&json::to_string(&chrome_trace_json(&events))).expect("valid json");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        assert!(!evs.is_empty());
        for e in evs {
            for key in ["ph", "pid", "ts"] {
                assert!(e.get(key).is_some(), "every event carries {}", key);
            }
        }
        let phases: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert!(phases.contains(&"M"), "lane metadata present");
        assert!(phases.contains(&"B") && phases.contains(&"E"), "duration pair present");
        assert!(phases.contains(&"C"), "counter track present");
        assert!(phases.contains(&"i"), "instants present");
        // B/E balance per tid
        assert_eq!(
            phases.iter().filter(|p| **p == "B").count(),
            phases.iter().filter(|p| **p == "E").count()
        );
        // control lane named: highest tid with admission events
        let control = evs.iter().find(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M")
                && e.get("tid").and_then(|t| t.as_f64()) == Some(2.0)
        });
        let name = control
            .and_then(|e| e.get("args"))
            .and_then(|a| a.get("name"))
            .and_then(|n| n.as_str());
        assert_eq!(name, Some("control"));
    }

    #[test]
    fn orphaned_task_end_does_not_emit_unbalanced_e() {
        let events = vec![ev(1_000, 0, TraceKind::TaskEnd, 0, 0)];
        let doc = chrome_trace_json(&events);
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
        assert!(evs
            .iter()
            .all(|e| e.get("ph").and_then(|p| p.as_str()) != Some("E")));
    }
}
