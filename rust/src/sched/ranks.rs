//! The scheduler's declared lock order — the single source of truth
//! for deadlock freedom, enforced twice:
//!
//! - at **runtime** by [`crate::util::ordered::OrderedMutex`] /
//!   [`OrderedCondvar`](crate::util::ordered::OrderedCondvar): under
//!   `debug_assertions` every acquisition must be strictly up-rank on
//!   its thread, so the whole test suite continuously checks the order;
//! - **syntactically** by `tools/repolint`, which parses this file for
//!   the numeric order and flags nested `.lock()` calls that go
//!   down-rank (run `cargo run -p repolint`).
//!
//! # Why graph progress is the *outermost* rank
//!
//! The borrowed-body soundness argument of
//! [`Executor::run_graph`](super::Executor::run_graph) requires that
//! every node body is dropped **before** the graph's completion
//! (`remaining == 0`) becomes observable: a waiter may free the `'env`
//! data the bodies borrow the moment it wakes. Cancellation therefore
//! *must* drop undispatched bodies while still holding the progress
//! lock — releasing it first would open a window where a concurrent
//! completion lets the waiter run while the cancel sweep still owns
//! live body boxes. So `Job`-level locks (body, panic, stats, done,
//! on_done) must be acquirable *under* the graph progress lock, which
//! pins `graph.progress` below every job rank. The run queue sits
//! between the graph layer and the job locks: dispatch enqueues while
//! holding no graph lock, and nothing acquires a graph or queue lock
//! while holding a job lock.
//!
//! # The order
//!
//! | rank | lock | guards |
//! |-----:|------|--------|
//! | 10 | `graph.progress` | `GraphRun.progress` — per-graph node statuses, pending counts, cancel flag |
//! | 20 | `graph.jobs` | `GraphRun.jobs` — registry of dispatched jobs (cancellation fan-out) |
//! | 30 | `scope.pending` | `Scope.pending` — jobs a borrowed-body scope must await |
//! | 35 | `elastic.lease` | `ElasticPools.lease` — worker-lease table for runtime pool resizing |
//! | 40 | `exec.run_queue` | `Shared.queue` — the executor's live-job run queue (`RunState`) |
//! | 50 | `job.body` | `Job.body` — the task body box (dropped before completion publishes) |
//! | 60 | `job.panic` | `Job.panic` — first panic payload |
//! | 70 | `job.stats` | `Job.stats[w]` — per-worker counters |
//! | 80 | `job.done` | `Job.done` — the published `SchedReport` (completion event) |
//! | 90 | `job.on_done` | `Job.on_done` — the graph layer's completion hook |
//!
//! Condvars pair with their mutex's rank: `work_cv` with
//! `exec.run_queue`, a job's `done_cv` with `job.done`, a graph's
//! `done_cv` with `graph.progress`. The wait discipline (a waiter
//! holds exactly the waited lock — see
//! [`crate::util::ordered::OrderedCondvar::wait`]) is part of the
//! declared order.
//!
//! Gaps of 10 leave room to slot new locks in without renumbering;
//! repolint only compares relative order, never absolute values.

use crate::util::ordered::LockRank;

/// `GraphRun.progress`: per-graph dispatch/completion state.
pub const GRAPH_PROGRESS: LockRank = LockRank::new(10, "graph.progress");
/// `GraphRun.jobs`: dispatched-job registry for cancellation.
pub const GRAPH_JOBS: LockRank = LockRank::new(20, "graph.jobs");
/// `Scope.pending`: borrowed-body jobs the scope must await.
pub const SCOPE_PENDING: LockRank = LockRank::new(30, "scope.pending");
/// `ElasticPools.lease`: the worker-lease table serializing runtime
/// pool resizing (lend/reclaim/resize). Sits below the run queue so a
/// resize decision may briefly take the queue lock (e.g. to check the
/// donor's live jobs or to wake parked workers) while the lease is
/// held, but never the reverse — the dispatch path reads the elastic
/// assignment through atomics only and never touches this lock.
pub const ELASTIC_LEASE: LockRank = LockRank::new(35, "elastic.lease");
/// `Shared.queue`: the executor's policy-ordered live-job run queue.
pub const RUN_QUEUE: LockRank = LockRank::new(40, "exec.run_queue");
/// `Job.body`: the task body box.
pub const JOB_BODY: LockRank = LockRank::new(50, "job.body");
/// `Job.panic`: the recorded panic payload.
pub const JOB_PANIC: LockRank = LockRank::new(60, "job.panic");
/// `Job.stats[w]`: per-worker execution counters.
pub const JOB_STATS: LockRank = LockRank::new(70, "job.stats");
/// `Job.done`: the published completion report.
pub const JOB_DONE: LockRank = LockRank::new(80, "job.done");
/// `Job.on_done`: the graph layer's completion hook.
pub const JOB_ON_DONE: LockRank = LockRank::new(90, "job.on_done");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn declared_order_is_strictly_increasing() {
        let order = [
            GRAPH_PROGRESS,
            GRAPH_JOBS,
            SCOPE_PENDING,
            ELASTIC_LEASE,
            RUN_QUEUE,
            JOB_BODY,
            JOB_PANIC,
            JOB_STATS,
            JOB_DONE,
            JOB_ON_DONE,
        ];
        for pair in order.windows(2) {
            assert!(
                pair[0].rank < pair[1].rank,
                "{} must rank below {}",
                pair[0],
                pair[1]
            );
        }
        // names are unique (diagnostics would mislead otherwise)
        for (i, a) in order.iter().enumerate() {
            for b in &order[i + 1..] {
                assert_ne!(a.name, b.name);
            }
        }
    }
}
