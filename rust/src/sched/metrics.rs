//! Scheduling metrics: per-worker counters and the aggregate report the
//! evaluation (and the DES) emits for every run.

use crate::util::stats;

/// Counters for one worker.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerStats {
    /// Tasks (chunks) executed.
    pub tasks: usize,
    /// Work items executed (sum of chunk sizes).
    pub items: usize,
    /// Seconds spent executing task bodies.
    pub busy: f64,
    /// Seconds spent acquiring tasks (queue access incl. lock waits).
    pub queue_wait: f64,
    /// Successful steals.
    pub steals: usize,
    /// Steal probes that found the victim empty.
    pub failed_steals: usize,
    /// Items obtained via stealing.
    pub stolen_items: usize,
}

/// Aggregate result of one scheduled execution.
#[derive(Debug, Clone)]
pub struct SchedReport {
    /// Scheme / layout / victim names (for printing).
    pub scheme: String,
    pub layout: String,
    pub victim: String,
    /// Wall-clock (real executor) or virtual (DES) makespan in seconds.
    pub makespan: f64,
    /// Seconds between admission (enqueue) and the first chunk dispatch —
    /// the queueing component of the end-to-end latency. 0 when the job
    /// was served immediately (or never served at all).
    pub queue_delay: f64,
    pub per_worker: Vec<WorkerStats>,
}

impl SchedReport {
    /// Coefficient of variation of per-worker busy times — the paper's
    /// load-imbalance indicator.
    pub fn cov(&self) -> f64 {
        let busy: Vec<f64> = self.per_worker.iter().map(|w| w.busy).collect();
        stats::cov(&busy)
    }

    /// max/mean of per-worker busy times.
    pub fn imbalance(&self) -> f64 {
        let busy: Vec<f64> = self.per_worker.iter().map(|w| w.busy).collect();
        stats::imbalance(&busy)
    }

    pub fn total_tasks(&self) -> usize {
        self.per_worker.iter().map(|w| w.tasks).sum()
    }

    pub fn total_items(&self) -> usize {
        self.per_worker.iter().map(|w| w.items).sum()
    }

    pub fn total_steals(&self) -> usize {
        self.per_worker.iter().map(|w| w.steals).sum()
    }

    pub fn total_failed_steals(&self) -> usize {
        self.per_worker.iter().map(|w| w.failed_steals).sum()
    }

    /// Total seconds spent waiting on queues — the contention signal the
    /// paper discusses for SS and PERCPU/MFSC.
    pub fn total_queue_wait(&self) -> f64 {
        self.per_worker.iter().map(|w| w.queue_wait).sum()
    }

    /// Seconds between first dispatch and completion — the end-to-end
    /// makespan with the admission queueing delay stripped out.
    pub fn service_time(&self) -> f64 {
        (self.makespan - self.queue_delay).max(0.0)
    }

    /// One formatted row (used by the figure harness and CLI).
    pub fn row(&self) -> String {
        format!(
            "{:<8} {:<14} {:<7} time={:>10} tasks={:<7} steals={:<6} \
             cov={:.3} qwait={:.4}s",
            self.scheme,
            self.layout,
            self.victim,
            crate::util::fmt_duration(self.makespan),
            self.total_tasks(),
            self.total_steals(),
            self.cov(),
            self.total_queue_wait(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(busys: &[f64]) -> SchedReport {
        SchedReport {
            scheme: "STATIC".into(),
            layout: "CENTRAL".into(),
            victim: "SEQ".into(),
            makespan: 1.0,
            queue_delay: 0.25,
            per_worker: busys
                .iter()
                .map(|&b| WorkerStats { busy: b, tasks: 1, items: 10, ..Default::default() })
                .collect(),
        }
    }

    #[test]
    fn aggregates() {
        let r = report(&[1.0, 1.0, 2.0]);
        assert_eq!(r.total_tasks(), 3);
        assert_eq!(r.total_items(), 30);
        assert!((r.imbalance() - 1.5).abs() < 1e-12);
        assert!(r.cov() > 0.0);
    }

    #[test]
    fn row_contains_names() {
        let r = report(&[1.0]);
        let row = r.row();
        assert!(row.contains("STATIC") && row.contains("CENTRAL"));
    }

    #[test]
    fn service_time_strips_queue_delay() {
        let r = report(&[1.0]);
        assert!((r.service_time() - 0.75).abs() < 1e-12);
        let degenerate = SchedReport { queue_delay: 2.0, ..report(&[1.0]) };
        assert_eq!(degenerate.service_time(), 0.0);
    }
}
