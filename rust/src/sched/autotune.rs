//! Automatic selection of scheduling options — the paper's §5 future
//! work: "the multitude of scheduling options ... renders the offline or
//! online selection of the right scheduling option for an
//! application-system pair very challenging. We plan to extend
//! DaphneSched to support automatic selection."
//!
//! The tuner reuses the DES as an *offline oracle*: given the workload's
//! per-item cost profile (known after one profiled pass, or estimated
//! from data statistics like row nnz) and the machine model, it sweeps
//! candidate (scheme × layout × victim) configurations in virtual time
//! and returns the best — milliseconds of simulation instead of hours of
//! grid-running the real application.

use crate::config::SchedConfig;
use crate::sched::{QueueLayout, Scheme, VictimStrategy};
use crate::sim::{self, CostModel, Workload};
use crate::topology::Topology;

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub config: SchedConfig,
    /// Predicted makespan, seconds (virtual).
    pub predicted: f64,
}

/// Search space for the tuner.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub schemes: Vec<Scheme>,
    pub layouts: Vec<QueueLayout>,
    pub victims: Vec<VictimStrategy>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            // SS excluded by default: the §4 explosion makes it never
            // competitive on a locked central queue.
            schemes: Scheme::FIGURES.to_vec(),
            layouts: vec![
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ],
            victims: VictimStrategy::ALL.to_vec(),
        }
    }
}

/// Sweep the space and return candidates sorted best-first.
///
/// `repeats` averages over seeds (the DES models OS interference, so a
/// single draw can be lucky). Centralized layouts ignore the victim
/// dimension (evaluated once).
pub fn tune(
    workload: &Workload,
    topo: &Topology,
    costs: &CostModel,
    space: &SearchSpace,
    seed: u64,
    repeats: usize,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    for &scheme in &space.schemes {
        for &layout in &space.layouts {
            let victims: &[VictimStrategy] = if layout.steals() {
                &space.victims
            } else {
                &[VictimStrategy::Seq]
            };
            for &victim in victims {
                let config = SchedConfig {
                    scheme,
                    layout,
                    victim,
                    seed,
                    stages: None,
                    pls_swr: 0.5,
                };
                let mut total = 0.0;
                for r in 0..repeats.max(1) {
                    let cfg = SchedConfig {
                        seed: seed.wrapping_add(r as u64 * 0x9E37_79B9),
                        ..config.clone()
                    };
                    total += sim::simulate(topo, &cfg, workload, costs)
                        .makespan();
                }
                out.push(Candidate {
                    config,
                    predicted: total / repeats.max(1) as f64,
                });
            }
        }
    }
    out.sort_by(|a, b| a.predicted.total_cmp(&b.predicted));
    out
}

/// Convenience: best configuration for a workload/machine pair.
pub fn best(
    workload: &Workload,
    topo: &Topology,
    costs: &CostModel,
    seed: u64,
) -> Candidate {
    tune(workload, topo, costs, &SearchSpace::default(), seed, 3)
        .into_iter()
        .next()
        .expect("non-empty search space")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_workload() -> Workload {
        // heavy tail at the end: dynamic schemes needed
        let per: Vec<f64> = (0..100_000)
            .map(|i| if i >= 50_000 { 9e-7 } else { 1e-8 })
            .collect();
        Workload::from_costs("skew", &per)
    }

    #[test]
    fn tuner_ranks_whole_space() {
        let w = Workload::uniform("u", 20_000, 1e-7);
        let topo = Topology::broadwell20();
        let ranked = tune(
            &w,
            &topo,
            &CostModel::recorded(),
            &SearchSpace::default(),
            1,
            1,
        );
        // 10 schemes x (2 central + 2 stealing x 4 victims) = 100
        assert_eq!(ranked.len(), 100);
        assert!(ranked.windows(2).all(|w| w[0].predicted <= w[1].predicted));
    }

    #[test]
    fn picks_non_static_for_skewed_work() {
        let topo = Topology::broadwell20();
        let choice = best(
            &skewed_workload(),
            &topo,
            &CostModel::daphne_like(),
            1,
        );
        // STATIC parks the heavy half on half the workers; any sane
        // choice beats it clearly
        let static_cfg = SchedConfig::default();
        let static_time = sim::simulate(
            &topo,
            &static_cfg,
            &skewed_workload(),
            &CostModel::daphne_like(),
        )
        .makespan();
        assert!(
            choice.predicted < static_time,
            "tuned {:?} ({}) must beat default STATIC ({static_time})",
            choice.config.scheme,
            choice.predicted
        );
    }

    #[test]
    fn picks_cheap_config_for_uniform_work() {
        // uniform dense work: the winner must not be a fine-grained
        // locked-central config (those pay pure overhead, Fig. 10)
        let w = Workload::uniform("u", 200_000, 3e-8);
        let topo = Topology::broadwell20();
        let choice = best(&w, &topo, &CostModel::daphne_like(), 1);
        let fine_locked = SchedConfig::default().with_scheme(Scheme::Ss);
        let fine_time =
            sim::simulate(&topo, &fine_locked, &w, &CostModel::daphne_like())
                .makespan();
        assert!(choice.predicted < fine_time / 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = Workload::uniform("u", 10_000, 1e-7);
        let topo = Topology::cascadelake56();
        let a = best(&w, &topo, &CostModel::recorded(), 7);
        let b = best(&w, &topo, &CostModel::recorded(), 7);
        assert_eq!(a.config.scheme, b.config.scheme);
        assert_eq!(a.predicted, b.predicted);
    }
}
