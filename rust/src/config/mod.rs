//! Configuration system: scheduling options, machine selection, workload
//! parameters. Parsed from CLI-style `key=value` pairs and simple config
//! files (a `key = value` line format, TOML-flavoured but std-only).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use crate::sched::{
    PlacementPolicy, QueueLayout, Scheme, TenancyPolicy, VictimStrategy,
};
use crate::topology::Topology;

/// Everything needed to schedule one pipeline run.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Task-partitioning scheme (paper: 11 options).
    pub scheme: Scheme,
    /// Work-queue layout (paper: centralized / per-CPU-group / per-core).
    pub layout: QueueLayout,
    /// Victim-selection strategy for work-stealing layouts.
    pub victim: VictimStrategy,
    /// RNG seed (PSS chunking, RND/RNDPRI victims, workloads).
    pub seed: u64,
    /// FISS/VISS stage count; `None` = ceil(log2 P) + 1.
    pub stages: Option<usize>,
    /// PLS static workload ratio (fraction scheduled statically first).
    pub pls_swr: f64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            scheme: Scheme::Static,
            layout: QueueLayout::Centralized { atomic: false },
            victim: VictimStrategy::Seq,
            seed: 0xDA9E,
            stages: None,
            pls_swr: 0.5,
        }
    }
}

impl SchedConfig {
    /// Fine-grained multiplexing config: per-item SS chunks served from
    /// the atomic centralized queue — the smallest preemption quantum
    /// the scheduler offers. The canonical config of the multi-tenant
    /// surface (`figure tenancy`, `tune tenancy`, the tenancy tests),
    /// so the cross-job pick policy — not chunk granularity — decides
    /// how tenants interleave.
    pub fn fine_grained() -> Self {
        SchedConfig {
            scheme: Scheme::Ss,
            layout: QueueLayout::Centralized { atomic: true },
            ..SchedConfig::default()
        }
    }

    pub fn with_scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    pub fn with_layout(mut self, layout: QueueLayout) -> Self {
        self.layout = layout;
        self
    }

    pub fn with_victim(mut self, victim: VictimStrategy) -> Self {
        self.victim = victim;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// How the real-thread executor is provisioned for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorMode {
    /// Spawn the worker pool once and park it between jobs (the DAPHNE
    /// runtime model; default).
    #[default]
    Persistent,
    /// Spawn and join a fresh pool per scheduled operator (the legacy
    /// spawn-per-stage behaviour, kept for A/B comparison).
    Oneshot,
}

impl ExecutorMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorMode::Persistent => "persistent",
            ExecutorMode::Oneshot => "oneshot",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "persistent" | "pool" => Some(ExecutorMode::Persistent),
            "oneshot" | "spawn" | "legacy" => Some(ExecutorMode::Oneshot),
            _ => None,
        }
    }
}

/// How a pipeline's stages are ordered on the executor
/// (`graph=barrier|dag`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GraphMode {
    /// Full barrier between consecutive stages: stages execute serially
    /// in dependency order (the pre-task-graph behaviour, kept for A/B
    /// comparison in the figures).
    Barrier,
    /// Dependency-aware task-graph dispatch: only explicit `after(...)`
    /// edges order stages, so independent stages overlap on the
    /// resident pool (default).
    #[default]
    Dag,
}

impl GraphMode {
    pub fn name(&self) -> &'static str {
        match self {
            GraphMode::Barrier => "barrier",
            GraphMode::Dag => "dag",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" | "serial" => Some(GraphMode::Barrier),
            "dag" | "graph" => Some(GraphMode::Dag),
            _ => None,
        }
    }
}

/// Arrival pattern of the multi-tenant workload (`arrival=`): how the
/// tenant submission offsets of `figure tenancy` (and any
/// [`crate::sim::graph::replay_tenants`] scenario built from a config)
/// are spread over the burst window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArrivalPattern {
    /// Tenants arrive in tight bursts (default — the tail-latency
    /// stress case the tenancy figure is about).
    #[default]
    Burst,
    /// Evenly spaced arrivals over the window.
    Uniform,
    /// Exponential (Poisson-process) inter-arrival gaps, seeded.
    Poisson,
}

impl ArrivalPattern {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Burst => "burst",
            ArrivalPattern::Uniform => "uniform",
            ArrivalPattern::Poisson => "poisson",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "burst" | "bursty" => Some(ArrivalPattern::Burst),
            "uniform" | "even" => Some(ArrivalPattern::Uniform),
            "poisson" | "exp" => Some(ArrivalPattern::Poisson),
            _ => None,
        }
    }
}

/// Event-trace gate (`trace=`): whether the `obs::trace` ring buffers
/// record scheduler events. `Off` compiles the hook points down to one
/// relaxed load and a branch; `Sampled(n)` keeps every n-th job
/// (job-id modulo, so a job's events are kept or dropped together).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// No recording (default): hook points are a branch-on-relaxed-load.
    #[default]
    Off,
    /// Record every event.
    On,
    /// Record events of every n-th job (plus job-less events).
    Sampled(u32),
}

impl TraceMode {
    pub fn name(&self) -> String {
        match self {
            TraceMode::Off => "off".to_string(),
            TraceMode::On => "on".to_string(),
            TraceMode::Sampled(n) => format!("sampled:{n}"),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase();
        match s.as_str() {
            "off" | "0" | "false" => Some(TraceMode::Off),
            "on" | "1" | "true" => Some(TraceMode::On),
            _ => {
                let n = s.strip_prefix("sampled:")?;
                n.parse().ok().filter(|&n| n >= 1).map(TraceMode::Sampled)
            }
        }
    }
}

/// A full experiment configuration (scheduling + machine + workload).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub sched: SchedConfig,
    pub topology: Topology,
    /// Worker-pool provisioning (`executor=persistent|oneshot`).
    pub executor: ExecutorMode,
    /// Pipeline dispatch mode (`graph=barrier|dag`).
    pub graph: GraphMode,
    /// Number of identical jobs submitted concurrently to the one
    /// resident pool (`jobs=<n>`; 1 = a single job stream).
    pub jobs: usize,
    /// How heterogeneous-pipeline nodes are placed on device pools
    /// (`placement=any|pinned|auto`; used by `figure hetero` /
    /// `tune graph=hetero`).
    pub placement: PlacementPolicy,
    /// Cross-job pick policy of the executor's run queue
    /// (`policy=fifo|fair|priority`; how concurrent tenants share the
    /// pool).
    pub policy: TenancyPolicy,
    /// Arrival pattern of the multi-tenant workload
    /// (`arrival=burst|uniform|poisson`; used by `figure tenancy`).
    pub arrival: ArrivalPattern,
    /// Event-trace gate (`trace=off|on|sampled:<n>`; see
    /// [`crate::obs::trace`]).
    pub trace: TraceMode,
    /// Free-form workload parameters (apps interpret their own keys).
    pub params: BTreeMap<String, String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            sched: SchedConfig::default(),
            topology: Topology::host(),
            executor: ExecutorMode::default(),
            graph: GraphMode::default(),
            jobs: 1,
            placement: PlacementPolicy::default(),
            policy: TenancyPolicy::default(),
            arrival: ArrivalPattern::default(),
            trace: TraceMode::default(),
            params: BTreeMap::new(),
        }
    }
}

/// Error for config parsing.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl RunConfig {
    /// The pipeline dispatch mode actually in effect: `graph=dag` needs
    /// the resident executor, so `executor=oneshot` downgrades to
    /// barrier (banners should print this, not the raw `graph` field).
    pub fn effective_graph(&self) -> GraphMode {
        match self.executor {
            ExecutorMode::Oneshot => GraphMode::Barrier,
            ExecutorMode::Persistent => self.graph,
        }
    }

    /// Apply one `key=value` option.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        match key {
            "scheme" | "partitioning" => {
                self.sched.scheme = Scheme::parse(value)
                    .ok_or_else(|| ConfigError(format!("unknown scheme '{value}'")))?;
            }
            "layout" | "queue" => {
                self.sched.layout = QueueLayout::parse(value)
                    .ok_or_else(|| ConfigError(format!("unknown layout '{value}'")))?;
            }
            "victim" => {
                self.sched.victim = VictimStrategy::parse(value)
                    .ok_or_else(|| ConfigError(format!("unknown victim '{value}'")))?;
            }
            "machine" | "topology" => {
                self.topology = Topology::preset(value)
                    .ok_or_else(|| ConfigError(format!("unknown machine '{value}'")))?;
            }
            "seed" => {
                self.sched.seed = value
                    .parse()
                    .map_err(|_| ConfigError(format!("bad seed '{value}'")))?;
            }
            "stages" => {
                self.sched.stages = Some(
                    value
                        .parse()
                        .map_err(|_| ConfigError(format!("bad stages '{value}'")))?,
                );
            }
            "pls_swr" => {
                self.sched.pls_swr = value
                    .parse()
                    .map_err(|_| ConfigError(format!("bad pls_swr '{value}'")))?;
            }
            "executor" => {
                self.executor = ExecutorMode::parse(value).ok_or_else(|| {
                    ConfigError(format!("unknown executor mode '{value}'"))
                })?;
            }
            "graph" => {
                self.graph = GraphMode::parse(value).ok_or_else(|| {
                    ConfigError(format!("unknown graph mode '{value}'"))
                })?;
            }
            "jobs" => {
                self.jobs = value
                    .parse()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| ConfigError(format!("bad jobs '{value}'")))?;
            }
            "placement" => {
                self.placement =
                    PlacementPolicy::parse(value).ok_or_else(|| {
                        ConfigError(format!(
                            "unknown placement policy '{value}' \
                             (any | pinned | auto)"
                        ))
                    })?;
            }
            "policy" | "tenancy" => {
                self.policy = TenancyPolicy::parse(value).ok_or_else(|| {
                    ConfigError(format!(
                        "unknown tenancy policy '{value}' \
                         (fifo | fair | priority)"
                    ))
                })?;
            }
            "arrival" => {
                self.arrival = ArrivalPattern::parse(value).ok_or_else(|| {
                    ConfigError(format!(
                        "unknown arrival pattern '{value}' \
                         (burst | uniform | poisson)"
                    ))
                })?;
            }
            "trace" => {
                self.trace = TraceMode::parse(value).ok_or_else(|| {
                    ConfigError(format!(
                        "unknown trace mode '{value}' \
                         (off | on | sampled:<n>)"
                    ))
                })?;
            }
            _ => {
                self.params.insert(key.to_string(), value.to_string());
            }
        }
        Ok(())
    }

    /// Parse a sequence of `key=value` CLI options.
    pub fn from_pairs<'a>(
        pairs: impl IntoIterator<Item = &'a str>,
    ) -> Result<Self, ConfigError> {
        let mut cfg = RunConfig::default();
        for pair in pairs {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| ConfigError(format!("expected key=value, got '{pair}'")))?;
            cfg.set(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }

    /// Load a `key = value` config file; '#' starts a comment.
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("read {}: {e}", path.display())))?;
        Self::from_text(&text)
            .map_err(|e| ConfigError(format!("{}: {}", path.display(), e.0)))
    }

    /// Parse the `key = value` line format (the same one `Display`
    /// emits); '#' starts a comment.
    pub fn from_text(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = RunConfig::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                ConfigError(format!("line {}: expected key = value", lineno + 1))
            })?;
            cfg.set(k.trim(), v.trim())?;
        }
        Ok(cfg)
    }

    /// Integer workload parameter with default.
    pub fn param_usize(&self, key: &str, default: usize) -> usize {
        self.params
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Float workload parameter with default.
    pub fn param_f64(&self, key: &str, default: f64) -> f64 {
        self.params
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// String workload parameter with default (e.g. the serve
    /// subcommand's `admission=open|bounded|shed` key).
    pub fn param_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.params.get(key).map(String::as_str).unwrap_or(default)
    }

    /// Switch workload parameter with default (e.g. the serve
    /// subcommand's `elastic=on` key). Accepts `on|true|1|yes` and
    /// `off|false|0|no`; anything else falls back to the default.
    pub fn param_bool(&self, key: &str, default: bool) -> bool {
        match self.params.get(key).map(String::as_str) {
            Some("on") | Some("true") | Some("1") | Some("yes") => true,
            Some("off") | Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }
}

/// Emits the `key = value` line format accepted by
/// [`RunConfig::from_file`], so a config round-trips through `Display`.
/// (The `machine` line only re-parses for preset topology names —
/// `host`, `broadwell20`, `cascadelake56`.)
impl fmt::Display for RunConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "scheme = {}", self.sched.scheme.name())?;
        writeln!(f, "layout = {}", self.sched.layout.name())?;
        writeln!(f, "victim = {}", self.sched.victim.name())?;
        writeln!(f, "machine = {}", self.topology.name)?;
        writeln!(f, "seed = {}", self.sched.seed)?;
        if let Some(stages) = self.sched.stages {
            writeln!(f, "stages = {stages}")?;
        }
        writeln!(f, "pls_swr = {}", self.sched.pls_swr)?;
        writeln!(f, "executor = {}", self.executor.name())?;
        writeln!(f, "graph = {}", self.graph.name())?;
        writeln!(f, "jobs = {}", self.jobs)?;
        writeln!(f, "placement = {}", self.placement.name())?;
        writeln!(f, "policy = {}", self.policy.name())?;
        writeln!(f, "arrival = {}", self.arrival.name())?;
        writeln!(f, "trace = {}", self.trace.name())?;
        for (k, v) in &self.params {
            writeln!(f, "{k} = {v}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_pairs() {
        let cfg = RunConfig::from_pairs([
            "scheme=mfsc",
            "layout=percore",
            "victim=rndpri",
            "machine=broadwell20",
            "seed=7",
            "rows=100000",
        ])
        .unwrap();
        assert_eq!(cfg.sched.scheme, Scheme::Mfsc);
        assert_eq!(cfg.sched.victim, VictimStrategy::RndPri);
        assert_eq!(cfg.topology.n_cores(), 20);
        assert_eq!(cfg.sched.seed, 7);
        assert_eq!(cfg.param_usize("rows", 0), 100_000);
    }

    #[test]
    fn serve_keys_flow_through_params() {
        // the serve subcommand's keys ride the free-form param map
        let cfg = RunConfig::from_pairs([
            "qps=800",
            "duration=2.5",
            "slo_ms=10",
            "admission=bounded",
            "max_backlog=32",
        ])
        .unwrap();
        assert_eq!(cfg.param_f64("qps", 0.0), 800.0);
        assert_eq!(cfg.param_f64("duration", 0.0), 2.5);
        assert_eq!(cfg.param_f64("slo_ms", 0.0), 10.0);
        assert_eq!(cfg.param_str("admission", "open"), "bounded");
        assert_eq!(cfg.param_str("missing", "open"), "open");
        assert_eq!(cfg.param_usize("max_backlog", 0), 32);
        // and round-trip through the Display text format
        let back = RunConfig::from_text(&cfg.to_string()).unwrap();
        assert_eq!(back.param_str("admission", ""), "bounded");
        assert_eq!(back.param_f64("qps", 0.0), 800.0);
    }

    #[test]
    fn elastic_keys_flow_through_params() {
        // the serve subcommand's elastic-pool keys ride the free-form
        // param map and round-trip through the Display text format
        let cfg = RunConfig::from_pairs([
            "elastic=on",
            "min_workers=4",
            "max_workers=6",
        ])
        .unwrap();
        assert!(cfg.param_bool("elastic", false));
        assert!(!cfg.param_bool("missing", false));
        assert!(cfg.param_bool("missing", true));
        assert_eq!(cfg.param_usize("min_workers", 0), 4);
        assert_eq!(cfg.param_usize("max_workers", 0), 6);
        let back = RunConfig::from_text(&cfg.to_string()).unwrap();
        assert!(back.param_bool("elastic", false));
        assert_eq!(back.param_usize("min_workers", 0), 4);
        assert_eq!(back.param_usize("max_workers", 0), 6);
        // off/false/0 parse as false even with a true default
        let off = RunConfig::from_pairs(["elastic=off"]).unwrap();
        assert!(!off.param_bool("elastic", true));
    }

    #[test]
    fn report_keys_flow_through_params() {
        // report=json / bench_name= / calibrate= ride the free-form
        // param map like the serve keys do
        let cfg = RunConfig::from_pairs([
            "report=json",
            "bench_name=smoke",
            "calibrate=trace.json",
        ])
        .unwrap();
        assert_eq!(cfg.param_str("report", ""), "json");
        assert_eq!(cfg.param_str("bench_name", ""), "smoke");
        assert_eq!(cfg.param_str("calibrate", ""), "trace.json");
        let back = RunConfig::from_text(&cfg.to_string()).unwrap();
        assert_eq!(back.param_str("report", ""), "json");
        assert_eq!(back.param_str("bench_name", ""), "smoke");
        assert_eq!(back.param_str("calibrate", ""), "trace.json");
    }

    #[test]
    fn unknown_scheme_is_error() {
        assert!(RunConfig::from_pairs(["scheme=bogus"]).is_err());
        assert!(RunConfig::from_pairs(["machine=bogus"]).is_err());
        assert!(RunConfig::from_pairs(["noequals"]).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("daphne_sched_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.conf");
        std::fs::write(
            &path,
            "# experiment\nscheme = gss\nmachine = cascadelake56\nrows = 42\n",
        )
        .unwrap();
        let cfg = RunConfig::from_file(&path).unwrap();
        assert_eq!(cfg.sched.scheme, Scheme::Gss);
        assert_eq!(cfg.topology.n_cores(), 56);
        assert_eq!(cfg.param_usize("rows", 0), 42);
    }

    #[test]
    fn executor_and_jobs_keys_parse() {
        let cfg =
            RunConfig::from_pairs(["executor=oneshot", "jobs=4"]).unwrap();
        assert_eq!(cfg.executor, ExecutorMode::Oneshot);
        assert_eq!(cfg.jobs, 4);
        let cfg = RunConfig::from_pairs(["executor=persistent"]).unwrap();
        assert_eq!(cfg.executor, ExecutorMode::Persistent);
        assert_eq!(cfg.jobs, 1, "jobs defaults to a single stream");
        assert!(RunConfig::from_pairs(["executor=bogus"]).is_err());
        assert!(RunConfig::from_pairs(["jobs=0"]).is_err());
        assert!(RunConfig::from_pairs(["jobs=-1"]).is_err());
    }

    #[test]
    fn placement_key_parses_and_roundtrips() {
        let cfg = RunConfig::from_pairs(["placement=pinned"]).unwrap();
        assert_eq!(cfg.placement, PlacementPolicy::Pinned);
        assert_eq!(
            RunConfig::default().placement,
            PlacementPolicy::Auto,
            "autotuned placement is the default policy"
        );
        assert!(RunConfig::from_pairs(["placement=bogus"]).is_err());
        let text = cfg.to_string();
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.placement, PlacementPolicy::Pinned);
        // hetero machine presets resolve through the machine key
        let cfg = RunConfig::from_pairs(["machine=hetero56"]).unwrap();
        assert_eq!(cfg.topology.n_cores(), 64);
    }

    #[test]
    fn policy_and_arrival_keys_parse_and_round_trip() {
        let cfg = RunConfig::from_pairs(["policy=fair", "arrival=poisson"])
            .unwrap();
        assert_eq!(cfg.policy, TenancyPolicy::Fair);
        assert_eq!(cfg.arrival, ArrivalPattern::Poisson);
        assert_eq!(
            RunConfig::default().policy,
            TenancyPolicy::Fifo,
            "FIFO multiplexing is the default"
        );
        assert_eq!(RunConfig::default().arrival, ArrivalPattern::Burst);
        assert!(RunConfig::from_pairs(["policy=bogus"]).is_err());
        assert!(RunConfig::from_pairs(["arrival=bogus"]).is_err());
        let text = cfg.to_string();
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.policy, TenancyPolicy::Fair);
        assert_eq!(back.arrival, ArrivalPattern::Poisson);
        for p in TenancyPolicy::ALL {
            assert_eq!(TenancyPolicy::parse(p.name()), Some(p));
        }
        for a in [
            ArrivalPattern::Burst,
            ArrivalPattern::Uniform,
            ArrivalPattern::Poisson,
        ] {
            assert_eq!(ArrivalPattern::parse(a.name()), Some(a));
        }
    }

    #[test]
    fn trace_key_parses_and_round_trips() {
        assert_eq!(RunConfig::default().trace, TraceMode::Off);
        let cfg = RunConfig::from_pairs(["trace=on"]).unwrap();
        assert_eq!(cfg.trace, TraceMode::On);
        assert!(cfg.params.is_empty(), "trace is a typed key, not a param");
        let cfg = RunConfig::from_pairs(["trace=sampled:8"]).unwrap();
        assert_eq!(cfg.trace, TraceMode::Sampled(8));
        assert!(RunConfig::from_pairs(["trace=bogus"]).is_err());
        assert!(RunConfig::from_pairs(["trace=sampled:0"]).is_err());
        let back = RunConfig::from_text(&cfg.to_string()).unwrap();
        assert_eq!(back.trace, TraceMode::Sampled(8));
        for mode in [TraceMode::Off, TraceMode::On, TraceMode::Sampled(4)] {
            assert_eq!(TraceMode::parse(&mode.name()), Some(mode));
        }
    }

    #[test]
    fn effective_graph_downgrades_for_oneshot() {
        let cfg =
            RunConfig::from_pairs(["executor=oneshot", "graph=dag"]).unwrap();
        assert_eq!(cfg.graph, GraphMode::Dag, "raw knob preserved");
        assert_eq!(
            cfg.effective_graph(),
            GraphMode::Barrier,
            "dag needs the resident executor"
        );
        let cfg = RunConfig::from_pairs(["graph=dag"]).unwrap();
        assert_eq!(cfg.effective_graph(), GraphMode::Dag);
    }

    #[test]
    fn graph_mode_key_parses() {
        let cfg = RunConfig::from_pairs(["graph=barrier"]).unwrap();
        assert_eq!(cfg.graph, GraphMode::Barrier);
        let cfg = RunConfig::from_pairs(["graph=dag"]).unwrap();
        assert_eq!(cfg.graph, GraphMode::Dag);
        assert_eq!(
            RunConfig::default().graph,
            GraphMode::Dag,
            "dependency-aware dispatch is the default"
        );
        assert!(RunConfig::from_pairs(["graph=bogus"]).is_err());
        for mode in [GraphMode::Barrier, GraphMode::Dag] {
            assert_eq!(GraphMode::parse(mode.name()), Some(mode));
        }
    }

    #[test]
    fn display_round_trips_through_from_text() {
        let cfg = RunConfig::from_pairs([
            "scheme=tfss",
            "layout=percore",
            "victim=seqpri",
            "machine=broadwell20",
            "seed=41",
            "stages=6",
            "pls_swr=0.25",
            "executor=oneshot",
            "graph=barrier",
            "jobs=3",
            "rows=4096",
        ])
        .unwrap();
        let text = cfg.to_string();
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.sched.scheme, cfg.sched.scheme);
        assert_eq!(back.sched.layout, cfg.sched.layout);
        assert_eq!(back.sched.victim, cfg.sched.victim);
        assert_eq!(back.sched.seed, cfg.sched.seed);
        assert_eq!(back.sched.stages, cfg.sched.stages);
        assert_eq!(back.sched.pls_swr, cfg.sched.pls_swr);
        assert_eq!(back.topology.name, cfg.topology.name);
        assert_eq!(back.topology.n_cores(), cfg.topology.n_cores());
        assert_eq!(back.executor, cfg.executor);
        assert_eq!(back.graph, cfg.graph);
        assert_eq!(back.jobs, cfg.jobs);
        assert_eq!(back.params, cfg.params);
    }

    #[test]
    fn display_round_trips_defaults_and_all_modes() {
        // default config (no stages line) must round-trip too
        let text = RunConfig::default().to_string();
        let back = RunConfig::from_text(&text).unwrap();
        assert_eq!(back.sched.stages, None);
        assert_eq!(back.executor, ExecutorMode::Persistent);
        assert_eq!(back.graph, GraphMode::Dag);
        assert_eq!(back.jobs, 1);
        // every executor mode's name re-parses
        for mode in [ExecutorMode::Persistent, ExecutorMode::Oneshot] {
            assert_eq!(ExecutorMode::parse(mode.name()), Some(mode));
        }
    }

    #[test]
    fn defaults_are_paper_defaults() {
        let cfg = RunConfig::default();
        assert_eq!(cfg.sched.scheme, Scheme::Static); // DAPHNE default
        assert!(matches!(
            cfg.sched.layout,
            QueueLayout::Centralized { atomic: false }
        ));
    }
}
