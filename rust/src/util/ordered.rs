//! Rank-ordered lock wrappers: deadlock freedom as a machine-checked
//! runtime invariant.
//!
//! The scheduler's hot paths are Mutex/Condvar choreography spread over
//! `sched::{executor,graph,session}`: coordinator-free `on_done`
//! dispatch, policy re-picks mid-stint, cancellation racing completion.
//! The classical way to make such a web deadlock-free is a *total lock
//! order*: every lock carries a rank, and a thread may only acquire a
//! lock of strictly higher rank than any lock it already holds. If
//! every thread obeys that rule, a cycle of waiters is impossible.
//!
//! This module makes the rule executable:
//!
//! - [`LockRank`] — a named rank. The repo's declared order lives in
//!   [`crate::sched::ranks`]; `tools/repolint` cross-checks the same
//!   order syntactically (nested `.lock()` calls must go up-rank).
//! - [`OrderedMutex`] / [`OrderedCondvar`] — drop-in `std::sync`
//!   wrappers that keep a per-thread stack of held ranks and panic on a
//!   rank inversion **under `debug_assertions` only**; in release builds
//!   every check compiles away and the wrappers are zero-cost
//!   pass-throughs to `std::sync::Mutex` / `Condvar`.
//! - Waiting discipline: [`OrderedCondvar::wait`] additionally asserts
//!   the waited lock is the *only* ranked lock the thread holds —
//!   blocking on a condvar while holding a second ranked lock would
//!   stall every thread that needs it, which is a deadlock in all but
//!   name even when the rank order is respected.
//!
//! Because every existing test runs with `debug_assertions` on under
//! `cargo test`, migrating a lock onto these wrappers turns the whole
//! suite into a continuous check of the declared order.

#[cfg(debug_assertions)]
use std::cell::RefCell;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError};

/// A named lock rank. Acquisition must be strictly up-rank: a thread
/// holding a lock of rank `r` may only acquire locks of rank `> r`.
/// Ranks are compared by number; the name only serves diagnostics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockRank {
    pub rank: u16,
    pub name: &'static str,
}

impl LockRank {
    pub const fn new(rank: u16, name: &'static str) -> Self {
        LockRank { rank, name }
    }
}

impl fmt::Display for LockRank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}(rank {})", self.name, self.rank)
    }
}

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks this thread currently holds, in acquisition order. The
    /// up-rank rule keeps it sorted, so `last()` is the maximum.
    static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
}

/// Record an acquisition; panics on a rank inversion (debug only).
#[inline]
fn rank_acquire(rank: LockRank) {
    #[cfg(debug_assertions)]
    HELD.with(|held| {
        let mut held = held.borrow_mut();
        if let Some(top) = held.last() {
            assert!(
                rank.rank > top.rank,
                "lock-rank inversion: acquiring {rank} while holding {top} \
                 (held: {held:?}); see sched::ranks for the declared order"
            );
        }
        held.push(rank);
    });
    #[cfg(not(debug_assertions))]
    let _ = rank;
}

/// Record a release (debug only). Releases may come out of acquisition
/// order (guards can be dropped early), so remove the newest matching
/// entry rather than popping blindly.
#[inline]
fn rank_release(rank: LockRank) {
    #[cfg(debug_assertions)]
    HELD.with(|held| {
        let mut held = held.borrow_mut();
        let i = held
            .iter()
            .rposition(|r| r.rank == rank.rank)
            .expect("released a rank this thread never recorded");
        held.remove(i);
    });
    #[cfg(not(debug_assertions))]
    let _ = rank;
}

/// Assert the thread is about to block on the condvar of `rank` while
/// holding *only* that ranked lock (debug only).
#[inline]
fn rank_assert_lone_wait(rank: LockRank) {
    #[cfg(debug_assertions)]
    HELD.with(|held| {
        let held = held.borrow();
        assert!(
            held.len() == 1 && held[0].rank == rank.rank,
            "Condvar::wait on {rank} while holding {held:?}: a waiter \
             must hold exactly the waited lock and nothing else"
        );
    });
    #[cfg(not(debug_assertions))]
    let _ = rank;
}

/// A `std::sync::Mutex` that carries a [`LockRank`] and enforces
/// strictly up-rank acquisition per thread under `debug_assertions`.
/// API mirrors `Mutex` for the subset the scheduler uses, so call
/// sites keep the `.lock().unwrap()` poisoned-lock idiom.
pub struct OrderedMutex<T: ?Sized> {
    rank: LockRank,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub const fn new(rank: LockRank, value: T) -> Self {
        OrderedMutex { rank, inner: Mutex::new(value) }
    }

    pub fn rank(&self) -> LockRank {
        self.rank
    }

    /// Acquire the lock, checking the rank order first (debug only). A
    /// poisoned inner mutex surfaces exactly as with `std::sync::Mutex`.
    pub fn lock(&self) -> LockResult<OrderedMutexGuard<'_, T>> {
        rank_acquire(self.rank);
        match self.inner.lock() {
            Ok(guard) => Ok(OrderedMutexGuard {
                rank: self.rank,
                guard: Some(guard),
            }),
            Err(poisoned) => Err(PoisonError::new(OrderedMutexGuard {
                rank: self.rank,
                guard: Some(poisoned.into_inner()),
            })),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        // No rank bookkeeping: consuming the mutex acquires nothing.
        self.inner.into_inner()
    }
}

impl<T: ?Sized + fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard returned by [`OrderedMutex::lock`]; releases the rank record
/// when dropped. The inner guard is held in an `Option` (same size —
/// `MutexGuard` has a niche) solely so [`OrderedCondvar::wait`] can
/// move it out without `unsafe` destructuring; it is `Some` for the
/// guard's entire client-visible lifetime.
pub struct OrderedMutexGuard<'a, T: ?Sized> {
    rank: LockRank,
    guard: Option<MutexGuard<'a, T>>,
}

impl<T: ?Sized> Deref for OrderedMutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard holds its lock")
    }
}

impl<T: ?Sized> DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard holds its lock")
    }
}

impl<T: ?Sized> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        // `None` only transiently inside `OrderedCondvar::wait`, which
        // does its own release bookkeeping.
        if self.guard.is_some() {
            rank_release(self.rank);
        }
    }
}

/// A `std::sync::Condvar` paired with [`OrderedMutex`] guards. The
/// rank record is parked while the thread is blocked in `wait` (the
/// mutex is not held there) and restored on wake-up.
pub struct OrderedCondvar {
    inner: Condvar,
}

impl Default for OrderedCondvar {
    fn default() -> Self {
        OrderedCondvar::new()
    }
}

impl OrderedCondvar {
    pub const fn new() -> Self {
        OrderedCondvar { inner: Condvar::new() }
    }

    /// Atomically release `guard`, block, and reacquire on wake-up.
    /// Must be called from a predicate loop (spurious wake-ups are
    /// possible — `tools/repolint` enforces the loop syntactically).
    pub fn wait<'a, T>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
    ) -> LockResult<OrderedMutexGuard<'a, T>> {
        let rank = guard.rank;
        rank_assert_lone_wait(rank);
        // Move the inner guard out (the emptied shell's Drop then skips
        // its release) and park the rank record while blocked: the
        // mutex is not held inside `Condvar::wait`, so the record must
        // not claim it is.
        let inner = guard
            .guard
            .take()
            .expect("guard holds its lock until wait consumes it");
        drop(guard);
        rank_release(rank);
        let result = self.inner.wait(inner);
        rank_acquire(rank);
        match result {
            Ok(guard) => Ok(OrderedMutexGuard { rank, guard: Some(guard) }),
            Err(poisoned) => Err(PoisonError::new(OrderedMutexGuard {
                rank,
                guard: Some(poisoned.into_inner()),
            })),
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl fmt::Debug for OrderedCondvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("OrderedCondvar")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    const LOW: LockRank = LockRank::new(10, "test.low");
    const HIGH: LockRank = LockRank::new(20, "test.high");

    #[test]
    fn up_rank_nesting_is_allowed() {
        let low = OrderedMutex::new(LOW, 1u32);
        let high = OrderedMutex::new(HIGH, 2u32);
        let g1 = low.lock().unwrap();
        let g2 = high.lock().unwrap();
        assert_eq!(*g1 + *g2, 3);
        drop(g2);
        drop(g1);
        // and again, to prove the records were released
        let _g = low.lock().unwrap();
    }

    #[test]
    fn out_of_order_release_keeps_records_consistent() {
        let low = OrderedMutex::new(LOW, ());
        let high = OrderedMutex::new(HIGH, ());
        let g1 = low.lock().unwrap();
        let g2 = high.lock().unwrap();
        drop(g1); // release the *older* record first
        drop(g2);
        let _g1 = low.lock().unwrap();
        let _g2 = high.lock().unwrap();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "release builds skip checks")]
    fn down_rank_nesting_panics_in_debug() {
        let low = OrderedMutex::new(LOW, ());
        let high = OrderedMutex::new(HIGH, ());
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _g2 = high.lock().unwrap();
            let _g1 = low.lock().unwrap(); // inversion: 10 under 20
        }));
        let msg = *result
            .expect_err("rank inversion must panic under debug_assertions")
            .downcast::<String>()
            .unwrap();
        assert!(msg.contains("lock-rank inversion"), "got: {msg}");
        // The panic unwound the held guard, so this thread's rank
        // records are clean again. (`high` is poisoned by the unwind —
        // orthogonal to rank bookkeeping.) `low` itself was never
        // locked: the check fires before the inner acquisition.
        let _g2 = match high.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        drop(_g2);
        let _g1 = low.lock().unwrap();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "release builds skip checks")]
    fn same_rank_nesting_panics_in_debug() {
        // two *distinct* locks of equal rank still may not nest: the
        // order between them is undeclared, which is how classic ABBA
        // deadlocks happen.
        let a = OrderedMutex::new(LOW, ());
        let b = OrderedMutex::new(LOW, ());
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }));
        assert!(result.is_err(), "same-rank nesting must panic");
    }

    #[test]
    fn condvar_wait_wakes_and_restores_the_record() {
        let pair = Arc::new((OrderedMutex::new(LOW, false), OrderedCondvar::new()));
        let woke = Arc::new(AtomicUsize::new(0));
        let (p2, w2) = (Arc::clone(&pair), Arc::clone(&woke));
        let waiter = std::thread::spawn(move || {
            let (lock, cv) = &*p2;
            let mut g = lock.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            w2.fetch_add(1, Ordering::SeqCst);
            // after the wait returns, the record must show the lock
            // held: an up-rank acquisition is still legal...
            drop(g);
            // ...and after dropping, a fresh acquisition succeeds.
            let _g = lock.lock().unwrap();
        });
        {
            let (lock, cv) = &*pair;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        waiter.join().unwrap();
        assert_eq!(woke.load(Ordering::SeqCst), 1);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "release builds skip checks")]
    fn waiting_while_holding_a_second_lock_panics_in_debug() {
        let low = OrderedMutex::new(LOW, ());
        let high = OrderedMutex::new(HIGH, false);
        let cv = OrderedCondvar::new();
        let result = catch_unwind(AssertUnwindSafe(|| {
            let _outer = low.lock().unwrap();
            let g = high.lock().unwrap();
            let _ = cv.wait(g); // would block holding `low` — forbidden
        }));
        assert!(result.is_err(), "lone-wait discipline must panic");
    }

    #[test]
    fn poisoned_lock_still_releases_the_rank_record() {
        let m = Arc::new(OrderedMutex::new(LOW, 7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        // this thread's record is untouched by the poisoner; the value
        // is still reachable through the PoisonError
        let g = m.lock();
        let guard = match g {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        assert_eq!(*guard, 7);
        drop(guard);
        let _again = match m.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
    }
}
