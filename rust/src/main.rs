//! `daphne-sched` — CLI launcher for the DaphneSched reproduction.
//!
//! Subcommands:
//!
//! ```text
//! run        run an app natively on this host      (cc | linreg)
//! dsl        run a DaphneDSL script file
//! serve      open-loop request serving soak on this host: a stream of
//!            small request graphs (linreg inference | cc queries) at a
//!            target QPS over batch tenants, with per-request admission
//!            (`admission=open|bounded|shed`), SLO attainment and
//!            p50/p99/p999 reporting
//! figure     regenerate a paper figure on a modelled machine (DES);
//!            `figure dag` is the dag-vs-barrier graph-replay figure,
//!            `figure hetero` the placement any|pinned|auto comparison,
//!            `figure tenancy` the fifo|fair|priority multi-tenant
//!            policy comparison under bursty arrivals,
//!            `figure serve` the open-loop serving prediction (attained
//!            QPS and tail latency per policy × admission setting),
//!            `figure elastic` the static-vs-elastic device-pool
//!            comparison (utilization and interactive p99 on hetero56)
//! ablation   §4/§5 ablations (ss | atomic)
//! calibrate  measure the DES cost-model constants on this host
//! tune       automatic config selection via the DES oracle;
//!            `tune graph=<linreg|cc|diamond|hetero>` selects per-node
//!            configs (and, for hetero, placements) over the app's task
//!            graph by virtual-time replay; `tune tenancy` ranks the
//!            cross-job pick policies for a bursty tenant mix
//! worker     start a distributed worker daemon (Fig. 5)
//! leader     drive distributed CC against worker daemons (Fig. 5)
//! ```
//!
//! Options are `key=value` pairs (see `config::RunConfig::set`):
//! `scheme=`, `layout=`, `victim=`, `machine=` (incl. the modelled
//! heterogeneous `hetero20`/`hetero56`), `seed=`,
//! `executor=persistent|oneshot`, `graph=barrier|dag` (pipeline
//! dispatch: full barriers vs dependency-aware task-graph overlap),
//! `jobs=<n>` (concurrent pipelines submitted through one `Session`
//! of the resident pool), `policy=fifo|fair|priority` (cross-job pick
//! policy multiplexing those pipelines), `arrival=burst|uniform|poisson`
//! (tenant arrival pattern of `figure tenancy`),
//! `placement=any|pinned|auto` (device-pool policy for the
//! heterogeneous pipeline), plus app parameters like `nodes=`,
//! `scale=`, `rows=`, `cols=`. The `serve` soak adds `qps=`,
//! `duration=`, `warmup=`, `slo_ms=`, `admission=open|bounded|shed`,
//! `max_backlog=`, `deadline_ms=`, `est_cost_ms=`,
//! `requests=linreg|cc`, `work=` and `batch=` (all riding the
//! free-form parameter map), and on heterogeneous machines
//! `elastic=on` arms the SLO-driven scaling controller over the
//! elastic device pools (`min_workers=` / `max_workers=` bound the
//! serving pool's width; 0 = derive from the machine).
//!
//! Observability: `trace=off|on|sampled:<n>` arms the per-worker event
//! trace (`run`, `serve` and the DES-backed `figure` replays all emit
//! the same stream), `trace_file=` picks the Chrome-trace output path
//! (default `trace.json`, loadable in Perfetto), and
//! `metrics_interval=<secs>` samples the live metrics registry during
//! `serve` soaks. Traced runs also print the critical-path attribution
//! ([`daphne_sched::obs::Analysis`]). `report=json` writes a
//! machine-readable `BENCH_<name>.json` (`bench_name=` overrides the
//! stem) collecting the run's figure rows, serve report, obs summary
//! and critical-path breakdown under a stable schema; `tune
//! graph=<app> calibrate=<trace.json>` re-costs the graph's nodes from
//! a recorded Chrome trace before tuning, so the DES oracle tunes on
//! observed — not assumed — workloads.

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::process::ExitCode;
use std::sync::Arc;

use daphne_sched::apps::{cc, linreg};
use daphne_sched::bench::{figures, AppCosts, FigureId, FigureParams};
use daphne_sched::config::RunConfig;
use daphne_sched::coordinator::{worker as coord_worker, Leader};
use daphne_sched::dsl;
use daphne_sched::graph::{amazon_like, scale_up, SnapGraph};
use daphne_sched::runtime::DeviceService;
use daphne_sched::sim::calibrate;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "usage: daphne-sched <run|dsl|serve|figure|ablation|calibrate|tune|worker|leader> \
     [args] [key=value ...]\n\
     examples:\n\
     \x20 daphne-sched run cc nodes=50000 scheme=mfsc layout=percore victim=seqpri\n\
     \x20 daphne-sched run cc nodes=50000 jobs=4 policy=fair  # 4 tenants, one session\n\
     \x20 daphne-sched run linreg rows=100000 graph=barrier # serial stages (A/B baseline)\n\
     \x20 daphne-sched run linreg rows=100000 executor=oneshot  # legacy spawn-per-stage\n\
     \x20 daphne-sched run linreg rows=100000 cols=65 scheme=static\n\
     \x20 daphne-sched dsl script.daph f=synthetic:amazon?nodes=10000\n\
     \x20 daphne-sched figure 7a [nodes=403394 scale=1 measure=1]\n\
     \x20 daphne-sched figure dag nodes=20000 lr_rows=100000  # dag-vs-barrier replay\n\
     \x20 daphne-sched figure hetero            # placement any|pinned|auto, hetero machines\n\
     \x20 daphne-sched figure tenancy arrival=burst  # fifo|fair|priority tenant mix\n\
     \x20 daphne-sched figure serve              # open-loop serving, policy x admission\n\
     \x20 daphne-sched figure elastic            # static vs elastic pools, hetero56\n\
     \x20 daphne-sched serve qps=400 duration=2 slo_ms=10 admission=bounded \
     max_backlog=4 policy=fair\n\
     \x20 daphne-sched serve machine=hetero56 elastic=on metrics_interval=0.5 \
     # elastic soak\n\
     \x20 daphne-sched serve qps=400 trace=on trace_file=serve.json \
     metrics_interval=0.5  # traced soak\n\
     \x20 daphne-sched run cc nodes=50000 trace=sampled:8  # 1-in-8 jobs traced\n\
     \x20 daphne-sched figure dag trace=on report=json bench_name=smoke  # BENCH_smoke.json\n\
     \x20 daphne-sched tune nodes=100000 machine=broadwell20  # single-workload sweep\n\
     \x20 daphne-sched tune graph=linreg rows=100000 machine=cascadelake56\n\
     \x20 daphne-sched tune graph=linreg calibrate=trace.json  # trace-calibrated costs\n\
     \x20 daphne-sched tune graph=hetero machine=hetero56 placement=auto\n\
     \x20 daphne-sched tune tenancy machine=cascadelake56 arrival=poisson\n\
     \x20 daphne-sched ablation ss\n\
     \x20 daphne-sched worker 127.0.0.1:7701\n\
     \x20 daphne-sched leader cc 127.0.0.1:7701,127.0.0.1:7702 nodes=10000"
        .to_string()
}

fn parse_pairs(rest: &[String]) -> Result<RunConfig, String> {
    RunConfig::from_pairs(rest.iter().map(|s| s.as_str()))
        .map_err(|e| e.to_string())
}

/// Arm the event trace per the `trace=` key, sized for `workers` lanes
/// (plus the control lane). Must run before the executor spawns (or the
/// replay starts) so every hook sees the gate open; a no-op for
/// `trace=off`, which leaves the hooks as one relaxed load each.
fn trace_init(cfg: &RunConfig, workers: usize) {
    use daphne_sched::obs::trace;
    if cfg.trace != daphne_sched::config::TraceMode::Off {
        trace::enable(cfg.trace, workers, trace::DEFAULT_CAPACITY);
    }
}

/// Drain the rings into a Chrome-trace JSON file (`trace_file=`,
/// default `trace.json`) and print the [`ObsSummary`] plus the
/// critical-path attribution; a no-op when tracing never armed.
/// `queue_wait` is the run's accumulated per-worker
/// `WorkerStats::queue_wait`, when the caller has a scheduler report
/// to read it from. When a `report=json` bench report is accumulating,
/// the summary and the attribution land in it as sections.
fn trace_finish(
    cfg: &RunConfig,
    queue_wait: Option<f64>,
    report: Option<&mut daphne_sched::obs::BenchReport>,
) -> Result<(), String> {
    use daphne_sched::obs::{export, trace, Analysis, ObsSummary};
    if !trace::enabled() {
        return Ok(());
    }
    let events = trace::drain();
    let path = cfg.param_str("trace_file", "trace.json").to_string();
    export::write_chrome_trace(std::path::Path::new(&path), &events)
        .map_err(|e| format!("writing trace file {path}: {e}"))?;
    let mut summary = ObsSummary::from_events(&events);
    if let Some(qw) = queue_wait {
        summary = summary.with_queue_wait(qw);
    }
    println!("{summary}");
    let analysis = Analysis::from_events(&events);
    print!("{}", analysis.render());
    println!(
        "trace: {} event(s) -> {path} (open in Perfetto or chrome://tracing)",
        events.len()
    );
    if let Some(rep) = report {
        rep.section("obs_summary", summary.to_json());
        rep.section("critical_path", analysis.to_json());
    }
    Ok(())
}

/// `report=json` support: start an accumulating [`BenchReport`]
/// (`daphne_sched::obs::BenchReport`) named by `bench_name=` (falling
/// back to the subcommand's default stem); `None` when no report was
/// requested.
fn bench_report(
    cfg: &RunConfig,
    default_name: &str,
) -> Option<daphne_sched::obs::BenchReport> {
    if cfg.param_str("report", "") != "json" {
        return None;
    }
    let name = cfg.param_str("bench_name", default_name).to_string();
    Some(daphne_sched::obs::BenchReport::new(&name))
}

/// Write an accumulated bench report as `BENCH_<name>.json` in the
/// working directory; a no-op for `None`.
fn write_report(
    rep: Option<daphne_sched::obs::BenchReport>,
) -> Result<(), String> {
    if let Some(rep) = rep {
        let path = rep
            .write_to(std::path::Path::new("."))
            .map_err(|e| format!("writing bench report: {e}"))?;
        println!("bench report -> {}", path.display());
    }
    Ok(())
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        return Err(usage());
    };
    match cmd.as_str() {
        "run" => cmd_run(&args[1..]),
        "dsl" => cmd_dsl(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "figure" => cmd_figure(&args[1..]),
        "ablation" => cmd_ablation(&args[1..]),
        "calibrate" => cmd_calibrate(),
        "tune" => cmd_tune(&args[1..]),
        "worker" => cmd_worker(&args[1..]),
        "leader" => cmd_leader(&args[1..]),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n{}", usage())),
    }
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let Some(app) = args.first() else {
        return Err("run: expected app (cc | linreg)".into());
    };
    let cfg = parse_pairs(&args[1..])?;
    // `run` executes natively on this host; `machine=` presets are for
    // `figure` (DES). Still allowed here for thread-count experiments.
    let topo = cfg.topology.clone();
    let mut rep = bench_report(&cfg, &format!("run_{app}"));
    trace_init(&cfg, topo.n_cores());
    match app.as_str() {
        "cc" => {
            let nodes = cfg.param_usize("nodes", 50_000);
            let scale = cfg.param_usize("scale", 1);
            let g = amazon_like(&SnapGraph::small(nodes, cfg.sched.seed))
                .symmetrize();
            let g = if scale > 1 { scale_up(&g, scale) } else { g };
            println!(
                "cc: {} nodes, {} edges ({:.4}% dense), machine={} [{} cores, \
                 {} executor, {} graph, {} job(s), {} policy]",
                g.rows,
                g.nnz(),
                g.density() * 100.0,
                topo.name,
                topo.n_cores(),
                cfg.executor.name(),
                cfg.effective_graph().name(),
                cfg.jobs,
                cfg.policy.name()
            );
            let use_pjrt = cfg.param_usize("pjrt", 0) == 1;
            let result = if use_pjrt {
                let (service, client) = DeviceService::start_default()
                    .map_err(|e| format!("{e:#}"))?;
                println!("pjrt platform: {}", service.platform);
                cc::run_pjrt(&g, &client, &service.manifest, &topo, &cfg.sched, 100)
                    .map_err(|e| format!("{e:#}"))?
            } else {
                let vee = Vee::with_mode(
                    Arc::new(topo.clone()),
                    Arc::new(cfg.sched.clone()),
                    cfg.executor,
                )
                .with_graph_mode(cfg.graph)
                .with_tenancy_policy(cfg.policy);
                if cfg.jobs > 1 {
                    // multi-tenant: every pipeline is submitted through
                    // ONE session of the resident pool, from this
                    // thread — the executor's workers are the only OS
                    // threads involved, and `policy=` decides how they
                    // interleave the tenants. Fused submission is dag
                    // dispatch by construction, so the `graph=barrier`
                    // A/B baseline (and the pool-less oneshot engine)
                    // runs its pipelines back-to-back instead.
                    let fused = cfg.effective_graph()
                        == daphne_sched::config::GraphMode::Dag;
                    let mut results: Vec<cc::CcResult> = if fused {
                        cc::run_concurrent(&vee, &g, cfg.jobs, 100)
                    } else {
                        println!(
                            "note: {} pipelines run back-to-back (fused \
                             concurrent submission needs graph=dag on the \
                             persistent executor)",
                            cfg.jobs
                        );
                        (0..cfg.jobs)
                            .map(|_| cc::run_with(&vee, &g, 100))
                            .collect()
                    };
                    for (i, r) in results.iter().enumerate() {
                        println!(
                            "  job {i}: {} iterations, {} components, \
                             {:.4}s scheduled",
                            r.iterations,
                            r.components,
                            r.total_time()
                        );
                    }
                    results.swap_remove(0)
                } else {
                    cc::run_with(&vee, &g, 100)
                }
            };
            println!(
                "converged in {} iterations, {} components, scheduled time {:.4}s",
                result.iterations,
                result.components,
                result.total_time()
            );
            for (i, r) in result.reports.iter().enumerate().take(3) {
                println!("  iter {i}: {}", r.row());
            }
            let qwait: f64 =
                result.reports.iter().map(|r| r.total_queue_wait()).sum();
            trace_finish(&cfg, Some(qwait), rep.as_mut())?;
            write_report(rep)?;
            Ok(())
        }
        "linreg" => {
            let spec = linreg::LinregSpec {
                rows: cfg.param_usize("rows", 100_000),
                cols: cfg.param_usize("cols", 65),
                lambda: cfg.param_f64("lambda", 1e-3) as f32,
                seed: cfg.sched.seed,
            };
            let (x, y) = linreg::generate(&spec);
            println!(
                "linreg: {}x{} design matrix, machine={} [{} cores, \
                 {} executor, {} graph, {} job(s), {} policy]",
                x.rows,
                x.cols,
                topo.name,
                topo.n_cores(),
                cfg.executor.name(),
                cfg.effective_graph().name(),
                cfg.jobs,
                cfg.policy.name()
            );
            let vee = Vee::with_mode(
                Arc::new(topo.clone()),
                Arc::new(cfg.sched.clone()),
                cfg.executor,
            )
            .with_graph_mode(cfg.graph)
            .with_tenancy_policy(cfg.policy);
            let result = if cfg.jobs > 1 {
                // one session, many training pipelines, no submission
                // threads; serialized fallback for graph=barrier (fused
                // submission is dag dispatch by construction) and for
                // the pool-less one-shot engine
                let fused = cfg.effective_graph()
                    == daphne_sched::config::GraphMode::Dag;
                let results: Vec<linreg::LinregResult> = if fused {
                    linreg::run_concurrent(&vee, &x, &y, spec.lambda, cfg.jobs)?
                } else {
                    println!(
                        "note: {} pipelines run back-to-back (fused \
                         concurrent submission needs graph=dag on the \
                         persistent executor)",
                        cfg.jobs
                    );
                    (0..cfg.jobs)
                        .map(|_| linreg::run_with(&vee, &x, &y, spec.lambda))
                        .collect::<Result<_, _>>()?
                };
                let mut results = results;
                for (i, r) in results.iter().enumerate() {
                    println!("  job {i}: wall {:.4}s", r.report.total_time());
                }
                results.swap_remove(0)
            } else {
                linreg::run_with(&vee, &x, &y, spec.lambda)?
            };
            println!(
                "beta[0..4] = {:?}, rmse = {:.4}",
                &result.beta[..result.beta.len().min(4)],
                linreg::rmse(&x, &y, &result.beta)
            );
            println!(
                "pipeline wall {:.4}s, serial (sum of stage makespans) {:.4}s",
                result.report.total_time(),
                result.report.serial_time()
            );
            for (name, r) in &result.report.stages {
                println!("  {name}: {}", r.row());
            }
            let qwait: f64 = result
                .report
                .stages
                .iter()
                .map(|(_, r)| r.total_queue_wait())
                .sum();
            trace_finish(&cfg, Some(qwait), rep.as_mut())?;
            write_report(rep)?;
            Ok(())
        }
        other => Err(format!("unknown app '{other}'")),
    }
}

/// Open-loop serving soak on the host executor — the real-run
/// confirmation of `figure serve`'s DES prediction. Serve-specific
/// options ride the free-form parameter map (`config::RunConfig`
/// params); `policy=`, `machine=`, `seed=` and `arrival=` are the usual
/// first-class keys. Arrivals default to `uniform` (an open-loop
/// generator paces requests; pass `arrival=burst` explicitly for the
/// all-at-once stress).
fn cmd_serve(args: &[String]) -> Result<(), String> {
    use daphne_sched::sched::{AdmissionPolicy, Executor};
    use daphne_sched::serve::{run_serve, RequestKind, ServeReport, ServeSpec};

    let cfg = parse_pairs(args)?;
    let requests_key = cfg.param_str("requests", "linreg").to_string();
    let requests = RequestKind::parse(&requests_key).ok_or_else(|| {
        format!("serve: unknown requests '{requests_key}' (linreg | cc)")
    })?;
    let duration = cfg.param_f64("duration", 2.0);
    let max_backlog = cfg.param_usize("max_backlog", 4);
    let deadline = cfg.param_f64("deadline_ms", 5.0) / 1e3;
    let admission_key = cfg.param_str("admission", "open").to_string();
    let admission =
        AdmissionPolicy::parse(&admission_key, max_backlog, deadline)
            .ok_or_else(|| {
                format!(
                    "serve: unknown admission '{admission_key}' \
                     (open | bounded | shed)"
                )
            })?;
    let arrival = if args.iter().any(|a| a.starts_with("arrival=")) {
        cfg.arrival
    } else {
        daphne_sched::config::ArrivalPattern::Uniform
    };
    let spec = ServeSpec {
        requests,
        qps: cfg.param_f64("qps", 200.0),
        duration,
        warmup: cfg.param_f64("warmup", duration / 4.0),
        slo: cfg.param_f64("slo_ms", 10.0) / 1e3,
        admission,
        est_cost: cfg.param_f64("est_cost_ms", 1.0) / 1e3,
        arrival,
        seed: cfg.sched.seed,
        rows: cfg.param_usize("rows", 32),
        work: cfg.param_usize("work", 2_000) as u64,
        batch_tenants: cfg.param_usize("batch", 1),
        metrics_interval: cfg.param_f64("metrics_interval", 0.0),
        elastic: cfg.param_bool("elastic", false),
        min_workers: cfg.param_usize("min_workers", 0),
        max_workers: cfg.param_usize("max_workers", 0),
        ..ServeSpec::default()
    };
    let topo = cfg.topology.clone();
    trace_init(&cfg, topo.n_cores());
    let exec = Executor::new_with_policy(
        Arc::new(topo.clone()),
        Arc::new(cfg.sched.clone()),
        cfg.policy,
    );
    println!(
        "serve: {} requests at {:.0} qps ({} arrivals) for {:.2}s \
         (warmup {:.2}s) on {} ({} cores), policy={}, admission={}, \
         slo={:.1}ms, {} batch tenant(s)",
        spec.requests.name(),
        spec.qps,
        spec.arrival.name(),
        spec.duration,
        spec.warmup,
        topo.name,
        topo.n_cores(),
        cfg.policy.name(),
        spec.admission.name(),
        spec.slo * 1e3,
        spec.batch_tenants
    );
    let report = run_serve(&exec, &spec).map_err(|e| e.to_string())?;
    println!("{}", ServeReport::header());
    println!("{}", report.row());
    println!(
        "offered {} ({} in measurement window), shed rate {:.1}%, mean \
         queue delay {:.2}ms, wall {:.2}s",
        report.offered,
        report.measured,
        report.shed_rate() * 100.0,
        report.mean_queue_delay * 1e3,
        report.wall
    );
    if !report.metrics.is_empty() {
        use daphne_sched::obs::MetricsSnapshot;
        println!("live metrics ({} snapshot(s)):", report.metrics.len());
        println!("{}", MetricsSnapshot::header());
        for snap in &report.metrics {
            println!("{}", snap.row());
        }
    }
    let mut rep = bench_report(&cfg, "serve");
    if let Some(r) = rep.as_mut() {
        r.section("serve", report.to_json());
    }
    trace_finish(&cfg, None, rep.as_mut())?;
    write_report(rep)?;
    Ok(())
}

fn cmd_dsl(args: &[String]) -> Result<(), String> {
    let Some(path) = args.first() else {
        return Err("dsl: expected script path".into());
    };
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {path}: {e}"))?;
    let cfg = parse_pairs(&args[1..])?;
    let params: BTreeMap<String, String> = cfg.params.clone();
    let vee = Vee::new(cfg.topology.clone(), cfg.sched.clone())
        .with_graph_mode(cfg.graph);
    let out = dsl::run_script(&src, &params, &vee)?;
    println!(
        "script ok; {} scheduled operators, total scheduled time {:.4}s",
        out.reports.len(),
        out.scheduled_time()
    );
    for (name, value) in &out.vars {
        match value {
            dsl::Value::Num(n) => println!("  {name} = {n}"),
            dsl::Value::Mat(m) => {
                println!("  {name} = matrix {}x{}", m.rows, m.cols)
            }
            dsl::Value::Sparse(g) => {
                println!("  {name} = sparse {}x{} ({} nnz)", g.rows, g.cols, g.nnz())
            }
            _ => {}
        }
    }
    Ok(())
}

fn figure_params(cfg: &RunConfig) -> FigureParams {
    let mut p = FigureParams {
        nodes: cfg.param_usize("nodes", 403_394),
        scale: cfg.param_usize("scale", 1),
        seed: cfg.sched.seed,
        iterations: cfg.params.get("iterations").and_then(|v| v.parse().ok()),
        lr_rows: cfg.param_usize("lr_rows", 2_000_000),
        arrival: cfg.arrival,
        ..FigureParams::default()
    };
    if cfg.param_usize("measure", 0) == 1 {
        println!("calibrating cost model on this host...");
        p.costs = calibrate::measure();
        p.app_costs = AppCosts::measure();
        println!("  {:?}", p.costs);
        println!("  {:?}", p.app_costs);
    }
    p
}

fn cmd_figure(args: &[String]) -> Result<(), String> {
    let Some(which) = args.first() else {
        return Err(
            "figure: expected id \
             (7a 7b 8a 8b 9a 9b 10a 10b dag hetero tenancy serve | all)"
                .into(),
        );
    };
    let cfg = parse_pairs(&args[1..])?;
    let params = figure_params(&cfg);
    let mut rep = bench_report(&cfg, &format!("figure_{which}"));
    // Figures replay on modelled machines whose virtual worker count
    // varies per figure; 64 lanes covers the largest (cascadelake56).
    trace_init(&cfg, 64);
    let rows: Vec<figures::Row> = if which == "all" {
        FigureId::ALL
            .into_iter()
            .flat_map(|id| figures::print_figure(id, &params))
            .collect()
    } else {
        let id = FigureId::parse(which)
            .ok_or_else(|| format!("unknown figure '{which}'"))?;
        figures::print_figure(id, &params)
    };
    if let Some(r) = rep.as_mut() {
        r.section("figures", figures::rows_json(&rows));
    }
    trace_finish(&cfg, None, rep.as_mut())?;
    write_report(rep)?;
    Ok(())
}

fn cmd_ablation(args: &[String]) -> Result<(), String> {
    let Some(which) = args.first() else {
        return Err("ablation: expected (ss | atomic)".into());
    };
    let cfg = parse_pairs(&args[1..])?;
    let params = figure_params(&cfg);
    match which.as_str() {
        "ss" => {
            println!("== SS central-queue explosion (why Figs 7-10 omit SS) ==");
            for (machine, t_ss, t_mfsc) in figures::ablation_ss(&params) {
                println!(
                    "  {machine}: SS={t_ss:.3}s MFSC={t_mfsc:.3}s ({:.1}x worse)",
                    t_ss / t_mfsc
                );
            }
            Ok(())
        }
        "atomic" => {
            println!("== locked vs atomic central queue (§5 future work) ==");
            for machine in [Topology::broadwell20(), Topology::cascadelake56()] {
                println!("  {}:", machine.name);
                for (scheme, locked, atomic) in
                    figures::ablation_lock_vs_atomic(&machine, &params)
                {
                    println!(
                        "    {scheme:<6} locked={locked:>9.3}s atomic={atomic:>9.3}s \
                         speedup={:.2}x",
                        locked / atomic
                    );
                }
            }
            Ok(())
        }
        other => Err(format!("unknown ablation '{other}'")),
    }
}

fn cmd_calibrate() -> Result<(), String> {
    println!("measuring scheduler primitives on this host...");
    let m = calibrate::measure();
    println!("  queue_access  = {:.1} ns (locked pull incl. getNextChunk)", m.queue_access * 1e9);
    println!("  atomic_access = {:.1} ns (fetch_add pull)", m.atomic_access * 1e9);
    let (per_row, per_nnz) = daphne_sched::bench::calibration::measure_cc();
    println!("  cc_per_row    = {:.2} ns", per_row * 1e9);
    println!("  cc_per_nnz    = {:.2} ns", per_nnz * 1e9);
    let lr = daphne_sched::bench::calibration::measure_lr(64);
    println!("  lr_per_row    = {:.1} ns (d=64)", lr * 1e9);
    Ok(())
}

/// §5 future work: automatic selection of the scheduling configuration,
/// using the DES as an offline oracle. Three surfaces:
///
/// - `tune [nodes=..]` — single-workload sweep (CC propagate pass).
/// - `tune graph=<linreg|cc|diamond|hetero> [..]` — graph-level search:
///   a per-node (scheme × layout × victim × placement) assignment over
///   the app's real task-graph shape, evaluated by dag-mode
///   virtual-time replay with greedy critical-path-first refinement.
///   `graph=hetero` tunes the heterogeneous diamond on a hetero machine
///   model; `placement=any|pinned|auto` picks the placement policy.
/// - `tune tenancy [machine=.. arrival=..]` — rank the cross-job pick
///   policies (`policy=` knob) for the bursty tenant mix by replayed
///   p99 tenant slowdown (`sim::replay_tenants` as the oracle).
fn cmd_tune(args: &[String]) -> Result<(), String> {
    use daphne_sched::apps::{cc, hetero, linreg};
    use daphne_sched::bench::AppCosts;
    use daphne_sched::config::GraphMode;
    use daphne_sched::sched::autotune;
    use daphne_sched::sched::{Placement, PlacementPolicy};
    use daphne_sched::sim::{CostModel, GraphShape};
    use daphne_sched::topology::DeviceClass;

    if args.first().map(String::as_str) == Some("tenancy") {
        use daphne_sched::config::SchedConfig;
        let cfg = parse_pairs(&args[1..])?;
        let machine = cfg.topology.clone();
        let cores = machine.class_cores(DeviceClass::Cpu).max(1);
        let tenants =
            figures::tenancy_tenants(cores, cfg.arrival, cfg.sched.seed);
        // explicit scheme=/layout=/victim= keys are honoured; otherwise
        // default to the figure's fine-grained per-item chunks (a
        // preemption quantum small enough for the policies to differ)
        let custom = args[1..].iter().any(|a| {
            a.starts_with("scheme=")
                || a.starts_with("layout=")
                || a.starts_with("victim=")
        });
        let sched = if custom {
            cfg.sched.clone()
        } else {
            SchedConfig::fine_grained().with_seed(cfg.sched.seed)
        };
        println!(
            "ranking tenancy policies: {} tenants ({} arrivals) on {} \
             ({} cpu cores, {} {} {})...",
            tenants.len(),
            cfg.arrival.name(),
            machine.name,
            cores,
            sched.scheme.name(),
            sched.layout.name(),
            sched.victim.name()
        );
        let ranked = autotune::tune_tenancy(
            &tenants,
            &machine,
            &CostModel::daphne_like(),
            &sched,
        )
        .map_err(|e| e.to_string())?;
        for c in &ranked {
            println!(
                "  {:<9} p99_slowdown={:>8.2}x fairness={:.3} \
                 makespan={:.4}s",
                c.policy.name(),
                c.p99_slowdown,
                c.fairness,
                c.makespan
            );
        }
        println!("-> best policy: {}", ranked[0].policy.name());
        return Ok(());
    }

    // `graph=<target>` selects graph-level tuning. A dispatch-mode
    // value (`graph=dag|barrier`) is rejected rather than silently
    // ignored — that knob has no effect on tuning.
    let mut rest: Vec<String> = Vec::new();
    let mut target: Option<String> = None;
    for a in args {
        match a.strip_prefix("graph=") {
            Some(v) if GraphMode::parse(v).is_some() => {
                return Err(format!(
                    "tune: 'graph={v}' is the pipeline-dispatch knob and has \
                     no effect on tuning; to tune per-node configs over a \
                     task graph use graph=linreg | graph=cc | graph=diamond \
                     | graph=hetero"
                ));
            }
            Some(v) => target = Some(v.to_string()),
            None => rest.push(a.clone()),
        }
    }
    let cfg = parse_pairs(&rest)?;
    let app = AppCosts::recorded();
    let machine = cfg.topology.clone();

    let Some(target) = target else {
        // single-workload sweep (the original `tune` surface)
        let nodes = cfg.param_usize("nodes", 100_000);
        let g = amazon_like(&SnapGraph::small(nodes, cfg.sched.seed))
            .symmetrize();
        let workload = cc::workload(&g, app.cc_per_row, app.cc_per_nnz);
        println!(
            "tuning cc ({} nodes) on {} ({} cores)...",
            g.rows,
            machine.name,
            machine.n_cores()
        );
        let ranked = autotune::tune(
            &workload,
            &machine,
            &CostModel::daphne_like(),
            &autotune::SearchSpace::default(),
            cfg.sched.seed,
            3,
        );
        println!("top 5 of {} candidates:", ranked.len());
        for c in ranked.iter().take(5) {
            println!(
                "  {:<7} {:<14} {:<7} predicted {:.4}s",
                c.config.scheme.name(),
                c.config.layout.name(),
                c.config.victim.name(),
                c.predicted
            );
        }
        let worst = ranked.last().unwrap();
        println!(
            "worst: {} {} {} predicted {:.4}s",
            worst.config.scheme.name(),
            worst.config.layout.name(),
            worst.config.victim.name(),
            worst.predicted
        );
        return Ok(());
    };

    // graph-level tuning over the app's real task-graph shape
    let mut machine = machine;
    let mut space = autotune::SearchSpace::default();
    let shape = match target.as_str() {
        "linreg" => linreg::graph_shape(
            cfg.param_usize("rows", 100_000),
            app.lr_per_row,
        ),
        "cc" => {
            let nodes = cfg.param_usize("nodes", 100_000);
            let g = amazon_like(&SnapGraph::small(nodes, cfg.sched.seed))
                .symmetrize();
            cc::iteration_shape(&g, app.cc_per_row, app.cc_per_nnz)
        }
        "diamond" => {
            GraphShape::unbalanced_diamond(machine.n_cores() / 2)
        }
        "hetero" => {
            // placement needs an accelerator pool to route to; default
            // to the modelled hetero56 when the selected machine is
            // CPU-only (e.g. the default host topology).
            if machine.device_classes().len() < 2 {
                println!(
                    "note: machine '{}' has no accelerator pool; using \
                     machine=hetero56 (pass machine=hetero20|hetero56 to \
                     choose)",
                    machine.name
                );
                machine = Topology::hetero56();
            }
            let w = machine.class_cores(DeviceClass::Cpu);
            match cfg.placement {
                PlacementPolicy::Any => {
                    // placement forced to Any everywhere: tune only the
                    // scheduling dimensions of the all-CPU baseline
                    space.placements = vec![Placement::Any];
                    hetero::diamond_shape(w)
                }
                PlacementPolicy::Pinned => {
                    // keep the hand-pinned classes fixed (empty
                    // placement space = shape placements are kept)
                    hetero::pinned_diamond(w, DeviceClass::Gpu)
                }
                PlacementPolicy::Auto => {
                    space.placements =
                        autotune::SearchSpace::for_machine(&machine)
                            .placements;
                    hetero::diamond_shape(w)
                }
            }
        }
        other => {
            return Err(format!(
                "tune: unknown graph target '{other}' \
                 (linreg | cc | diamond | hetero)"
            ))
        }
    };
    println!(
        "graph-tuning '{}' ({} nodes) on {} ({} cores{})...",
        shape.name,
        shape.len(),
        machine.name,
        machine.n_cores(),
        if space.placements.is_empty() {
            String::new()
        } else {
            format!(", {} placement candidates", space.placements.len())
        }
    );
    // `calibrate=<trace.json>`: re-cost the shape's nodes from a
    // recorded Chrome trace (measured per-node service time replaces
    // the assumed workload total) before searching — online graph
    // retuning on the observed workload.
    let calibrate_path = cfg.param_str("calibrate", "").to_string();
    let tuning = if calibrate_path.is_empty() {
        autotune::tune_graph(
            &shape,
            &machine,
            &CostModel::daphne_like(),
            &space,
            cfg.sched.seed,
            1,
        )
        .map_err(|e| e.to_string())?
    } else {
        let src = std::fs::read_to_string(&calibrate_path).map_err(|e| {
            format!("reading calibration trace {calibrate_path}: {e}")
        })?;
        let doc = daphne_sched::util::json::parse(&src).map_err(|e| {
            format!("parsing calibration trace {calibrate_path}: {e}")
        })?;
        let cal = daphne_sched::sim::TraceCalibration::from_chrome_trace(&doc);
        if cal.is_empty() {
            return Err(format!(
                "calibration trace {calibrate_path} holds no task slices \
                 (was it recorded with trace=on?)"
            ));
        }
        println!(
            "calibrating node costs from {calibrate_path} \
             ({} measured node(s))",
            cal.len()
        );
        let (_, tuning) = autotune::tune_graph_calibrated(
            &shape,
            &machine,
            &CostModel::daphne_like(),
            &space,
            cfg.sched.seed,
            1,
            &cal,
        )
        .map_err(|e| e.to_string())?;
        tuning
    };
    println!(
        "best uniform: {:<7} {:<14} {:<7} {:<10} predicted {:.4}s",
        tuning.uniform.config.scheme.name(),
        tuning.uniform.config.layout.name(),
        tuning.uniform.config.victim.name(),
        tuning
            .uniform_placement
            .map(|p| p.describe())
            // placement fixed by the shape (e.g. placement=pinned):
            // the uniform row has no single placement
            .unwrap_or_else(|| "(shape)".to_string()),
        tuning.uniform.predicted
    );
    println!("per-node selection:");
    for c in &tuning.per_node {
        println!(
            "  {:<12} {:<7} {:<14} {:<7} {:<10}",
            c.name,
            c.config.scheme.name(),
            c.config.layout.name(),
            c.config.victim.name(),
            c.placement.describe()
        );
    }
    println!(
        "per-node predicted {:.4}s ({:.1}% better than best uniform)",
        tuning.predicted,
        tuning.refinement_gain() * 100.0
    );
    Ok(())
}

fn cmd_worker(args: &[String]) -> Result<(), String> {
    let Some(addr) = args.first() else {
        return Err("worker: expected listen address".into());
    };
    let cfg = parse_pairs(&args[1..])?;
    let listener =
        TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    println!(
        "worker listening on {addr} ({} cores, scheme {})",
        cfg.topology.n_cores(),
        cfg.sched.scheme.name()
    );
    let vee = Vee::new(cfg.topology, cfg.sched);
    coord_worker::serve(listener, vee, None).map_err(|e| e.to_string())
}

fn cmd_leader(args: &[String]) -> Result<(), String> {
    let (Some(app), Some(addrs)) = (args.first(), args.get(1)) else {
        return Err("leader: expected app and comma-separated worker addrs".into());
    };
    if app != "cc" {
        return Err("leader currently drives the cc app".into());
    }
    let cfg = parse_pairs(&args[2..])?;
    let addr_list: Vec<&str> = addrs.split(',').collect();
    let nodes = cfg.param_usize("nodes", 10_000);
    let g = amazon_like(&SnapGraph::small(nodes, cfg.sched.seed)).symmetrize();
    println!("leader: {} workers, graph {} nodes / {} edges", addr_list.len(), g.rows, g.nnz());
    let mut leader = Leader::connect(&addr_list).map_err(|e| e.to_string())?;
    let result = leader.cc_distributed(&g, 100).map_err(|e| e.to_string())?;
    leader.shutdown().map_err(|e| e.to_string())?;
    let components = result
        .labels
        .iter()
        .enumerate()
        .filter(|(i, &l)| l == (*i as f32) + 1.0)
        .count();
    println!(
        "distributed cc: {} iterations, {components} components, critical-path \
         scheduled time {:.4}s",
        result.iterations, result.scheduled_time
    );
    Ok(())
}
