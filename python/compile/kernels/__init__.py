"""L1: Pallas kernels for the paper's compute hot-spots.

``ref`` holds the pure-jnp oracles; every kernel here is validated against
them by ``python/tests/``.
"""

from . import cc_propagate, linreg, ref  # noqa: F401
