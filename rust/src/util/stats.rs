//! Descriptive statistics used by metrics, benches and the DES reports.

/// Mean of a sample (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation — the paper's load-imbalance metric
/// (c.o.v. of per-worker finishing times).
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Min of a sample.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a sample.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile by linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// 99.9th percentile — the serving-tail metric (`figure serve`, `serve`).
pub fn p999(xs: &[f64]) -> f64 {
    percentile(xs, 99.9)
}

/// Bounded streaming percentile sketch: Vitter's Algorithm R reservoir
/// over a deterministic seeded [`Rng`](super::Rng) stream.
///
/// The serving loop records one latency per request for an unbounded
/// request stream; the reservoir keeps a fixed-capacity uniform sample so
/// memory stays O(capacity) while p50/p99/p999 remain unbiased estimates.
/// Below capacity the sample is exact (every observation retained), so
/// percentiles agree bit-for-bit with [`percentile`] on the full stream.
/// Same seed + same stream → same sample, keeping reports replayable.
#[derive(Debug, Clone)]
pub struct LatencyReservoir {
    samples: Vec<f64>,
    capacity: usize,
    seen: u64,
    rng: super::Rng,
}

impl LatencyReservoir {
    /// A reservoir holding at most `capacity` samples (capacity ≥ 1).
    pub fn new(capacity: usize, seed: u64) -> Self {
        assert!(capacity > 0, "reservoir capacity must be positive");
        LatencyReservoir {
            samples: Vec::with_capacity(capacity),
            capacity,
            seen: 0,
            rng: super::Rng::new(seed),
        }
    }

    /// Record one observation (Algorithm R replacement above capacity).
    pub fn record(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.capacity {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.capacity {
                self.samples[j as usize] = x;
            }
        }
    }

    /// Total observations recorded (not the retained sample size).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Retained sample size (= min(seen, capacity)).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Retained sample, in arrival order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Percentile of the retained sample (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn p999(&self) -> f64 {
        self.percentile(99.9)
    }
}

/// Jain's fairness index over non-negative allocations:
/// `(Σx)² / (n·Σx²)`, 1.0 = perfectly even, 1/n = one sample holds
/// everything. The multi-tenancy fairness metric of `figure tenancy`
/// (computed over per-tenant slowdowns).
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * sq)
    }
}

/// Load-imbalance as max/mean of per-worker times (1.0 = perfectly even).
pub fn imbalance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        1.0
    } else {
        max(xs) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-12);
        assert!((cov(&xs) - 0.4472135954999579).abs() < 1e-12);
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
        assert!((jain_fairness(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // one tenant hogging everything: index collapses to 1/n
        let skew = jain_fairness(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12);
        let mid = jain_fairness(&[1.0, 3.0]);
        assert!(mid > 0.25 && mid < 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(imbalance(&[]), 1.0);
    }

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert_eq!(imbalance(&[2.0, 2.0, 2.0]), 1.0);
        assert_eq!(imbalance(&[1.0, 3.0]), 1.5);
    }

    #[test]
    fn reservoir_exact_below_capacity() {
        // Under capacity every observation is retained, so reservoir
        // percentiles agree exactly with the batch functions.
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 100) as f64).collect();
        let mut r = LatencyReservoir::new(256, 42);
        for &x in &xs {
            r.record(x);
        }
        assert_eq!(r.len(), xs.len());
        assert_eq!(r.seen(), xs.len() as u64);
        for p in [0.0, 25.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(r.percentile(p), percentile(&xs, p), "p{p}");
        }
        assert_eq!(r.p999(), p999(&xs));
    }

    #[test]
    fn reservoir_deterministic_across_runs() {
        let feed = |seed: u64| {
            let mut r = LatencyReservoir::new(64, seed);
            let mut src = super::super::Rng::new(7);
            for _ in 0..10_000 {
                r.record(src.next_f64() * 1e3);
            }
            r
        };
        let a = feed(42);
        let b = feed(42);
        assert_eq!(a.samples(), b.samples());
        assert_eq!(a.p99(), b.p99());
        // A different reservoir seed keeps a different (but equally
        // sized) sample of the same stream.
        let c = feed(43);
        assert_eq!(c.len(), 64);
        assert!(a.samples() != c.samples());
    }

    #[test]
    fn reservoir_bounded_and_plausible() {
        let mut r = LatencyReservoir::new(32, 1);
        for i in 0..5_000 {
            r.record(i as f64);
        }
        assert_eq!(r.len(), 32);
        assert_eq!(r.seen(), 5_000);
        // Sample values all come from the stream and the median of a
        // uniform ramp lands near the middle.
        assert!(r.samples().iter().all(|&x| (0.0..5_000.0).contains(&x)));
        let med = r.p50();
        assert!((1_000.0..4_000.0).contains(&med), "median={med}");
    }

    #[test]
    fn p999_tracks_extreme_tail() {
        let mut xs = vec![1.0; 999];
        xs.push(100.0);
        // p99 sits on the flat body; p999 reaches into the single outlier.
        assert!(percentile(&xs, 99.0) < 2.0);
        assert!(p999(&xs) > 50.0);
    }
}
