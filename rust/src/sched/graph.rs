//! Task-graph job submission: dependency-aware pipeline dispatch over
//! the persistent [`Executor`].
//!
//! The barrier-per-stage pipeline model wastes the pool whenever two
//! stages are independent: each stage's straggler tail idles every
//! other worker. Canary (Qu et al., 2016) and Trident make the same
//! architectural argument for cloud and heterogeneous pipelines — let
//! the application state only the *true* dependencies and let the
//! runtime dispatch everything else concurrently. This module is that
//! surface for the VEE:
//!
//! - [`GraphSpec`] / [`NodeSpec`] — named nodes with per-node item
//!   counts, optional per-node [`SchedConfig`] overrides, and explicit
//!   [`NodeSpec::after`] dependency edges.
//! - [`Executor::submit_graph`] → [`GraphHandle`] — validates the spec
//!   up front (duplicate names, unknown dependencies, and cycles are
//!   hard [`GraphError`]s: a cyclic spec is *rejected*, never
//!   deadlocked on) and dispatches every in-degree-zero node
//!   immediately.
//! - Dependency-driven dispatch with no coordinator thread: each
//!   node's job carries a completion hook that runs on whichever
//!   worker finalizes the job; the hook decrements the in-edge counts
//!   of the node's dependents and enqueues any that reach zero. A node
//!   therefore starts *the moment* its last in-edge completes, and
//!   independent branches overlap on the same resident workers via the
//!   executor's job-scoped `TaskSource` multiplexing.
//! - Failure propagation: a node whose body panics finishes as
//!   [`NodeStatus::Failed`] and transitively cancels its dependents
//!   ([`NodeStatus::Cancelled`] nodes never dispatch and their bodies
//!   are dropped); independent branches keep running to completion.
//!   [`GraphHandle::wait`] resumes the first node panic on the waiting
//!   thread (mirroring [`JobHandle::wait`](super::JobHandle::wait));
//!   [`GraphHandle::join`] returns the per-node statuses instead.
//!
//! On heterogeneous topologies every node additionally carries a
//! [`Placement`] ([`NodeSpec::on`] / [`NodeSpec::with_placement`]):
//! placements are resolved against the executor's per-class device
//! pools *before* anything dispatches, so an unsatisfiable placement is
//! a [`GraphError::NoSuchPool`] — rejected, never a node that waits on
//! a pool that does not exist. A placed node's job is scoped to its
//! pool (its task source covers only that pool's workers, so it can
//! neither execute on nor steal from a foreign pool), and nodes placed
//! on different pools overlap on disjoint workers the moment their
//! in-edges complete.
//!
//! [`Executor::run_graph`] is the borrowed-body entry point (bodies may
//! borrow the caller's stack data; the call blocks until the whole
//! graph is terminal) — it is what [`crate::vee::Pipeline`] builds on.
//!
//! Graphs are also first-class *tenants*: submitted through a
//! [`Session`](super::Session) they carry tenancy options (priority,
//! weight, tag) that the executor's cross-job pick policy weighs, and
//! [`GraphHandle::cancel`] drops a tenant's undispatched nodes and
//! drains its in-flight jobs so the pool frees for the tenants queued
//! behind it.

use std::collections::HashMap;
use std::fmt;
use std::marker::PhantomData;
use std::panic::resume_unwind;
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::Instant;

use super::executor::{
    cancel_job, enqueue_raw, Body, DoneCallback, Executor, Job, PanicPayload,
    Shared,
};
use super::metrics::SchedReport;
use super::placement::{Placement, ResolveMode};
use super::ranks;
use super::session::Tenancy;
use super::task::TaskRange;
use crate::config::SchedConfig;
use crate::obs::trace::{TraceKind, OBS_CONTROL_WORKER};
use crate::topology::DeviceClass;
use crate::util::ordered::{OrderedCondvar, OrderedMutex};

/// Description of one graph node: a name (unique within its graph), an
/// item count, optional per-node scheduling overrides, a device-pool
/// [`Placement`], and the names of the nodes it must run after.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    pub items: usize,
    /// `None` = the executor's default config.
    pub config: Option<Arc<SchedConfig>>,
    /// Which device pool the node's job is scoped to (`Any` = the
    /// default pool). Resolved — and rejected if unsatisfiable — at
    /// submission, before anything dispatches.
    pub placement: Placement,
    /// Dependency edges by node name (duplicates are deduplicated at
    /// submission).
    pub after: Vec<String>,
}

impl NodeSpec {
    pub fn new(name: &str, items: usize) -> Self {
        NodeSpec {
            name: name.to_string(),
            items,
            config: None,
            placement: Placement::Any,
            after: Vec::new(),
        }
    }

    /// Add one dependency edge: this node dispatches only after `dep`
    /// has completed. Forward references are fine — names resolve at
    /// submission.
    pub fn after(mut self, dep: &str) -> Self {
        self.after.push(dep.to_string());
        self
    }

    /// Add several dependency edges at once.
    pub fn after_all<'d>(mut self, deps: impl IntoIterator<Item = &'d str>) -> Self {
        self.after.extend(deps.into_iter().map(str::to_string));
        self
    }

    /// Override the executor's default scheduling for this node.
    pub fn with_config(mut self, config: SchedConfig) -> Self {
        self.config = Some(Arc::new(config));
        self
    }

    /// Like [`NodeSpec::with_config`] but sharing an existing `Arc`.
    pub fn with_shared_config(mut self, config: Arc<SchedConfig>) -> Self {
        self.config = Some(config);
        self
    }

    /// Pin this node to the pool of a device class (sugar for
    /// [`NodeSpec::with_placement`]). A class the executor's topology
    /// does not provide is a [`GraphError::NoSuchPool`] at submission.
    pub fn on(self, class: DeviceClass) -> Self {
        self.with_placement(Placement::Class(class))
    }

    /// Constrain where this node may execute.
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }
}

type NodeBody<'env> = Box<dyn Fn(usize, TaskRange) + Send + Sync + 'env>;

/// A task graph: named nodes plus their bodies. Submit with
/// [`Executor::submit_graph`] (owned bodies, non-blocking) or
/// [`Executor::run_graph`] (borrowed bodies, blocks until terminal).
pub struct GraphSpec<'env> {
    pub name: String,
    nodes: Vec<(NodeSpec, NodeBody<'env>)>,
}

impl<'env> GraphSpec<'env> {
    pub fn new(name: &str) -> Self {
        GraphSpec { name: name.to_string(), nodes: Vec::new() }
    }

    /// Builder-style [`GraphSpec::add`].
    pub fn node<F>(mut self, spec: NodeSpec, body: F) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'env,
    {
        self.add(spec, body);
        self
    }

    pub fn add<F>(&mut self, spec: NodeSpec, body: F)
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'env,
    {
        self.nodes.push((spec, Box::new(body)));
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node_names(&self) -> impl Iterator<Item = &str> {
        self.nodes.iter().map(|(s, _)| s.name.as_str())
    }
}

impl fmt::Debug for GraphSpec<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("GraphSpec")
            .field("name", &self.name)
            .field("nodes", &self.node_names().collect::<Vec<_>>())
            .finish()
    }
}

/// A graph spec that cannot be scheduled. Returned by
/// [`Executor::submit_graph`] before anything is dispatched.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// Two nodes share a name.
    DuplicateNode(String),
    /// `node` names a dependency that is not in the graph.
    UnknownDependency { node: String, dep: String },
    /// The dependency edges contain a cycle; the named nodes are the
    /// ones that could not be topologically ordered.
    Cycle(Vec<String>),
    /// `node` carries a [`Placement`] no device pool of the executor's
    /// (or modelled machine's) topology satisfies — e.g.
    /// `Placement::Class(Gpu)` on a CPU-only machine. Rejected before
    /// dispatch, never left to deadlock as a forever-pending node.
    /// (`node` is usually a graph-node name; the graph autotuner also
    /// reports unsatisfiable *search-space* placement candidates through
    /// this variant with `node = "search space"`.)
    NoSuchPool { node: String, wanted: String },
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GraphError::DuplicateNode(name) => {
                write!(f, "duplicate node name '{name}'")
            }
            GraphError::UnknownDependency { node, dep } => {
                write!(f, "node '{node}' depends on unknown node '{dep}'")
            }
            GraphError::Cycle(names) => {
                write!(
                    f,
                    "dependency cycle: nodes {names:?} could not be \
                     topologically ordered (on or downstream of a cycle)"
                )
            }
            GraphError::NoSuchPool { node, wanted } => {
                write!(
                    f,
                    "placement '{wanted}' of '{node}' cannot be satisfied \
                     by this topology's device pools"
                )
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// Validated dispatch structure: a topological order plus resolved,
/// deduplicated dependency / dependent index lists (index = position in
/// the input slice).
pub struct TopoOrder {
    pub order: Vec<usize>,
    pub deps: Vec<Vec<usize>>,
    pub dependents: Vec<Vec<usize>>,
}

/// Kahn's algorithm over `(name, after-names)` pairs. Rejects duplicate
/// names, unknown dependencies, and cycles (including self-loops) as
/// [`GraphError`]s. Exposed for callers that serialize a graph
/// themselves — the VEE's `graph=barrier` mode.
pub fn toposort(nodes: &[(String, Vec<String>)]) -> Result<TopoOrder, GraphError> {
    let mut index: HashMap<&str, usize> = HashMap::with_capacity(nodes.len());
    for (i, (name, _)) in nodes.iter().enumerate() {
        if index.insert(name.as_str(), i).is_some() {
            return Err(GraphError::DuplicateNode(name.clone()));
        }
    }
    let n = nodes.len();
    let mut deps: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, (name, after)) in nodes.iter().enumerate() {
        for dep in after {
            let Some(&d) = index.get(dep.as_str()) else {
                return Err(GraphError::UnknownDependency {
                    node: name.clone(),
                    dep: dep.clone(),
                });
            };
            // Dedup repeated edges: each completion decrements the
            // pending count once, so a double edge would never drain.
            if !deps[i].contains(&d) {
                deps[i].push(d);
                dependents[d].push(i);
            }
        }
    }
    let mut indeg: Vec<usize> = deps.iter().map(Vec::len).collect();
    let mut order: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        for &v in &dependents[u] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                order.push(v);
            }
        }
    }
    if order.len() < n {
        let cyclic = (0..n)
            .filter(|&i| indeg[i] > 0)
            .map(|i| nodes[i].0.clone())
            .collect();
        return Err(GraphError::Cycle(cyclic));
    }
    Ok(TopoOrder { order, deps, dependents })
}

/// Terminal state of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    /// Every item executed.
    Completed,
    /// A task body panicked; the job was aborted and drained.
    Failed,
    /// The node never ran to completion: a (transitive) dependency
    /// failed, or the graph was cancelled ([`GraphHandle::cancel`]).
    /// Undispatched nodes never start; a node whose job was cancelled
    /// mid-run kept its partial progress but was drained.
    Cancelled,
}

/// Outcome of one node.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub name: String,
    pub status: NodeStatus,
    /// Device class of the pool the node resolved to (for cancelled
    /// nodes: the pool it *would* have dispatched on).
    pub device: DeviceClass,
    /// Placement-degradation annotation, e.g. a `Class(Gpu)` node
    /// rerouted to the CPU pool because this build has no `pjrt`
    /// feature to drive the device (see
    /// [`super::placement::ResolveMode::Execute`]).
    pub fallback: Option<String>,
    /// Scheduling report; `None` for cancelled nodes that never
    /// dispatched (a node cancelled *mid-run* keeps the report of its
    /// drained job, with a partial item count).
    pub report: Option<SchedReport>,
}

/// Outcome of one graph run, nodes in spec order.
#[derive(Debug, Clone)]
pub struct GraphReport {
    pub graph: String,
    pub nodes: Vec<NodeReport>,
    /// Wall-clock seconds from submission to the last node's terminal
    /// event — *the* pipeline latency once branches overlap.
    pub makespan: f64,
}

impl GraphReport {
    pub fn node(&self, name: &str) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.name == name)
    }

    pub fn report(&self, name: &str) -> Option<&SchedReport> {
        self.node(name).and_then(|n| n.report.as_ref())
    }

    pub fn status(&self, name: &str) -> Option<NodeStatus> {
        self.node(name).map(|n| n.status)
    }

    pub fn all_completed(&self) -> bool {
        self.nodes.iter().all(|n| n.status == NodeStatus::Completed)
    }

    /// Sum of per-node makespans — what a full barrier after every node
    /// would cost end-to-end. `serial_time() / makespan` estimates the
    /// overlap win.
    pub fn serial_time(&self) -> f64 {
        self.nodes
            .iter()
            .filter_map(|n| n.report.as_ref())
            .map(|r| r.makespan)
            .sum()
    }
}

/// Per-node runtime state (immutable after launch except the body).
struct NodeState {
    name: String,
    items: usize,
    config: Arc<SchedConfig>,
    /// Resolved device pool (index into the executor's
    /// [`DevicePools`](super::placement::DevicePools)).
    pool: usize,
    /// Class of that pool, for the report.
    device: DeviceClass,
    /// Placement-degradation annotation (see [`NodeReport::fallback`]).
    fallback: Option<String>,
    /// Taken when the node dispatches; dropped at cancellation for
    /// nodes that never dispatch. Either way it is gone before the
    /// graph's completion is observable (see `run_graph` soundness).
    /// Shares the job-body rank: cancel sweeps drop it *under* the
    /// progress lock, which is exactly why `graph.progress` ranks
    /// below every job lock (see [`ranks`]).
    body: OrderedMutex<Option<Body>>,
    dependents: Vec<usize>,
}

/// Mutable progress, guarded by one mutex.
struct Progress {
    /// Remaining in-edges per node; a node dispatches at zero.
    pending: Vec<usize>,
    status: Vec<Option<NodeStatus>>,
    reports: Vec<Option<SchedReport>>,
    /// Whether each node's job has been (or is being) enqueued. A
    /// cancel sweep may only short-circuit nodes that are not
    /// dispatched; dispatched ones are cancelled through their jobs.
    dispatched: Vec<bool>,
    /// Set by [`GraphHandle::cancel`]: no further node may dispatch.
    cancelled: bool,
    /// Nodes not yet terminal; zero = the graph is done.
    remaining: usize,
    /// First node panic, resumed by `wait`.
    panic: Option<PanicPayload>,
    makespan: f64,
}

pub(super) struct GraphRun {
    graph: String,
    shared: Arc<Shared>,
    completed_jobs: Arc<AtomicUsize>,
    /// Tenancy every node job of this graph is enqueued under.
    tenancy: Tenancy,
    nodes: Vec<NodeState>,
    /// Jobs dispatched so far (cancellation aborts them through here;
    /// entries for finished jobs are harmless — cancelling one is a
    /// no-op).
    jobs: OrderedMutex<Vec<Arc<Job>>>,
    progress: OrderedMutex<Progress>,
    done_cv: OrderedCondvar,
    start: Instant,
}

impl Executor {
    /// Validate and launch a task graph with owned (`'static`) bodies.
    /// Every node whose dependencies are already satisfied is dispatched
    /// before this returns; the rest dispatch as their in-edges
    /// complete. The graph keeps running if the handle is dropped.
    pub fn submit_graph(
        &self,
        spec: GraphSpec<'static>,
    ) -> Result<GraphHandle<'static>, GraphError> {
        let (run, roots) = self.prepare_graph(spec, Tenancy::default())?;
        dispatch(&run, &roots);
        Ok(GraphHandle::from_run(run))
    }

    /// Borrowed-body graph execution: validates, dispatches, and blocks
    /// until every node is terminal. Resumes the first node panic on
    /// this thread (dependents of the panicking node are cancelled;
    /// independent branches still run to completion first). This is the
    /// per-pipeline entry point used by [`crate::vee::Pipeline`].
    pub fn run_graph<'env>(
        &self,
        spec: GraphSpec<'env>,
    ) -> Result<GraphReport, GraphError> {
        // SOUNDNESS: lifetime-only transmute of the node bodies ('env
        // erased to 'static; layout unchanged). `wait` below blocks
        // until the whole graph is terminal, and by then every body is
        // gone: dispatched bodies are dropped by job finalization
        // *before* the node's completion publishes (and a
        // counted-complete job has no call in flight), cancelled bodies
        // are dropped under the progress lock at cancellation, and both
        // happen before the graph-level `remaining` counter can reach
        // zero. Worker threads keep `Arc`s to the run past that point,
        // but only to already-`None` body slots. On the `Err` path
        // nothing was dispatched and the spec (with its bodies) is
        // dropped here, inside 'env.
        let spec: GraphSpec<'static> = unsafe { std::mem::transmute(spec) };
        let (run, roots) = self.prepare_graph(spec, Tenancy::default())?;
        dispatch(&run, &roots);
        Ok(GraphHandle::from_run(run).wait())
    }

    /// Validate `spec` and build its run state *without dispatching
    /// anything*: the caller dispatches the returned root set via
    /// [`dispatch`]. Splitting submission this way is what lets
    /// [`super::Session::submit_all`] validate a whole batch before any
    /// graph's roots enter the run queue (fused submission).
    pub(super) fn prepare_graph(
        &self,
        spec: GraphSpec<'static>,
        tenancy: Tenancy,
    ) -> Result<(Arc<GraphRun>, Vec<usize>), GraphError> {
        let meta: Vec<(String, Vec<String>)> = spec
            .nodes
            .iter()
            .map(|(s, _)| (s.name.clone(), s.after.clone()))
            .collect();
        let topo = toposort(&meta)?;
        // Resolve every node's placement up front: an unsatisfiable
        // placement rejects the whole graph before anything dispatches
        // (a lazily-discovered one would leave dependents pending
        // forever — a deadlock, not an error).
        let pools = &self.shared().pools;
        let resolved: Vec<_> = spec
            .nodes
            .iter()
            .map(|(ns, _)| {
                pools
                    .resolve(&ns.placement, ResolveMode::Execute)
                    .map_err(|e| GraphError::NoSuchPool {
                        node: ns.name.clone(),
                        wanted: e.wanted,
                    })
            })
            .collect::<Result<_, _>>()?;
        let n = spec.nodes.len();
        let mut nodes = Vec::with_capacity(n);
        let mut pending = Vec::with_capacity(n);
        for (i, (ns, body)) in spec.nodes.into_iter().enumerate() {
            pending.push(topo.deps[i].len());
            nodes.push(NodeState {
                name: ns.name,
                items: ns.items,
                config: ns
                    .config
                    .unwrap_or_else(|| Arc::clone(self.default_config())),
                pool: resolved[i].pool,
                device: pools.pool(resolved[i].pool).class,
                fallback: resolved[i].fallback.clone(),
                body: OrderedMutex::new(ranks::JOB_BODY, Some(body)),
                dependents: topo.dependents[i].clone(),
            });
        }
        let roots: Vec<usize> = (0..n).filter(|&i| pending[i] == 0).collect();
        let run = Arc::new(GraphRun {
            graph: spec.name,
            shared: Arc::clone(self.shared()),
            completed_jobs: Arc::clone(self.completed_counter()),
            tenancy,
            nodes,
            jobs: OrderedMutex::new(ranks::GRAPH_JOBS, Vec::new()),
            progress: OrderedMutex::new(ranks::GRAPH_PROGRESS, Progress {
                pending,
                status: vec![None; n],
                reports: vec![None; n],
                dispatched: vec![false; n],
                cancelled: false,
                remaining: n,
                panic: None,
                makespan: 0.0,
            }),
            done_cv: OrderedCondvar::new(),
            start: Instant::now(),
        });
        Ok((run, roots))
    }
}

/// Enqueue the given (ready) nodes as jobs. Call with no locks held.
///
/// Nodes with items complete asynchronously and carry a completion hook
/// ([`node_done`]) that re-enters `dispatch` — at most one hook frame
/// deep, since their completion happens on whichever worker counts the
/// last item, not on this stack. Zero-item nodes complete inline inside
/// [`enqueue_raw`], so their bookkeeping is done *here*, on an explicit
/// worklist: an arbitrarily long chain of zero-item nodes is iterative,
/// not one recursion frame per node.
///
/// Every node is *claimed* under the progress lock before its body is
/// taken: a node of a cancelled graph (or one a concurrent cancel sweep
/// already marked terminal) is short-circuited to `Cancelled` here
/// instead of dispatching, and a job enqueued concurrently with the
/// cancel sweep is caught by the post-enqueue re-check — whichever side
/// runs second cancels it, so no job of a cancelled graph keeps the
/// pool busy.
pub(super) fn dispatch(run: &Arc<GraphRun>, ready: &[usize]) {
    let mut worklist: Vec<usize> = ready.to_vec();
    while let Some(i) = worklist.pop() {
        let node = &run.nodes[i];
        {
            let mut p = run.progress.lock().unwrap();
            if p.status[i].is_some() {
                continue; // a cancel sweep got here first
            }
            if p.cancelled {
                p.status[i] = Some(NodeStatus::Cancelled);
                drop(node.body.lock().unwrap().take());
                p.remaining -= 1;
                if p.remaining == 0 {
                    p.makespan = run.start.elapsed().as_secs_f64();
                }
                drop(p);
                run.done_cv.notify_all();
                continue;
            }
            p.dispatched[i] = true;
        }
        let taken = node.body.lock().unwrap().take();
        let Some(body) = taken else {
            // Unreachable: the claim above (`dispatched[i] = true`
            // under the progress lock) runs at most once per node, and
            // cancel sweeps only drop bodies of *unclaimed* nodes. An
            // unwrap here would panic a worker inside the dispatch
            // hook, so mark the node terminal instead — the graph
            // still drains rather than hanging.
            debug_assert!(false, "node '{}' lost its body", node.name);
            let mut p = run.progress.lock().unwrap();
            if p.status[i].is_none() {
                p.status[i] = Some(NodeStatus::Cancelled);
                p.remaining -= 1;
                if p.remaining == 0 {
                    p.makespan = run.start.elapsed().as_secs_f64();
                }
            }
            drop(p);
            run.done_cv.notify_all();
            continue;
        };
        if node.items == 0 {
            // completes inline (no hook): record the outcome ourselves
            // and push any newly ready dependents onto the worklist
            let job = enqueue_raw(
                &run.shared,
                &run.completed_jobs,
                node.name.clone(),
                0,
                Arc::clone(&node.config),
                node.pool,
                run.tenancy.clone(),
                body,
                None,
            );
            worklist.extend(record_done(run, i, &job));
        } else {
            let run2 = Arc::clone(run);
            let hook: DoneCallback =
                Box::new(move |job| node_done(&run2, i, job));
            let job = enqueue_raw(
                &run.shared,
                &run.completed_jobs,
                node.name.clone(),
                node.items,
                Arc::clone(&node.config),
                node.pool,
                run.tenancy.clone(),
                body,
                Some(hook),
            );
            run.jobs.lock().unwrap().push(Arc::clone(&job));
            // re-check: a cancel sweep that missed this job in the
            // registry has already set the flag, so we cancel it here
            let cancelled = run.progress.lock().unwrap().cancelled;
            if cancelled {
                cancel_job(&job, &run.shared, &run.completed_jobs);
            }
        }
    }
}

/// Completion hook for node `i`: runs on the thread that finalized its
/// job, after the job's own completion published.
fn node_done(run: &Arc<GraphRun>, i: usize, job: &Arc<Job>) {
    let ready = record_done(run, i, job);
    dispatch(run, &ready);
}

/// Record the outcome of node `i`'s finished job — releasing dependents
/// on success, cancelling them transitively on failure — and return the
/// nodes that became ready. Call with no locks held; wakes waiters.
fn record_done(run: &Arc<GraphRun>, i: usize, job: &Arc<Job>) -> Vec<usize> {
    // recorded before dependents release, so a child's Enqueue always
    // trails its parent's NodeComplete in the merged timeline
    job.record_trace(TraceKind::NodeComplete, OBS_CONTROL_WORKER);
    let report = match job.cloned_report() {
        Some(r) => r,
        // Unreachable: completion hooks run only after the report
        // publishes, and the zero-item inline path records after
        // `enqueue_raw` published. An unwrap here would panic the
        // finalizing worker, so degrade to an empty report — the node
        // still goes terminal and the graph cannot hang.
        None => {
            debug_assert!(false, "record_done before the report published");
            SchedReport {
                scheme: String::new(),
                layout: String::new(),
                victim: String::new(),
                makespan: 0.0,
                queue_delay: 0.0,
                per_worker: Vec::new(),
            }
        }
    };
    // A recorded panic payload is the authoritative failure signal —
    // it always surfaces through `wait()`, even if the graph was
    // concurrently cancelled (a crashed tenant must never read as
    // merely cancelled). Absent a panic, a raised cancel flag counts
    // only if it actually cost the node work: a cancel that raced a
    // natural completion (every item executed, nothing drained) leaves
    // the node Completed.
    let payload = job.take_panic();
    let failed = payload.is_some();
    let cancelled =
        !failed && job.was_cancelled() && !job.fully_executed(&report);
    let mut ready = Vec::new();
    {
        let mut p = run.progress.lock().unwrap();
        p.reports[i] = Some(report);
        p.status[i] = Some(if failed {
            NodeStatus::Failed
        } else if cancelled {
            NodeStatus::Cancelled
        } else {
            NodeStatus::Completed
        });
        if failed || cancelled {
            if p.panic.is_none() && payload.is_some() {
                p.panic = payload;
            }
            cancel_dependents(run, &mut p, i);
        } else {
            for &d in &run.nodes[i].dependents {
                p.pending[d] -= 1;
                if p.pending[d] == 0 && p.status[d].is_none() {
                    ready.push(d);
                }
            }
        }
        p.remaining -= 1;
        if p.remaining == 0 {
            p.makespan = run.start.elapsed().as_secs_f64();
        }
    }
    run.done_cv.notify_all();
    ready
}

/// Transitively cancel every not-yet-terminal dependent of `failed`.
/// None of them can have dispatched (each still has a pending in-edge
/// through the failed node), so their bodies are dropped here. Caller
/// holds the progress lock.
fn cancel_dependents(run: &GraphRun, p: &mut Progress, failed: usize) {
    let mut stack: Vec<usize> = run.nodes[failed].dependents.clone();
    while let Some(d) = stack.pop() {
        if p.status[d].is_some() {
            continue; // already terminal (diamond: visited via a sibling)
        }
        p.status[d] = Some(NodeStatus::Cancelled);
        drop(run.nodes[d].body.lock().unwrap().take());
        p.remaining -= 1;
        stack.extend(run.nodes[d].dependents.iter().copied());
    }
}

/// Handle to one submitted task graph.
#[must_use = "a GraphHandle should be waited on (the graph keeps running)"]
pub struct GraphHandle<'env> {
    run: Arc<GraphRun>,
    _env: PhantomData<&'env ()>,
}

impl fmt::Debug for GraphHandle<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("GraphHandle")
            .field("graph", &self.run.graph)
            .field("finished", &self.is_finished())
            .finish()
    }
}

impl GraphHandle<'static> {
    pub(super) fn from_run(run: Arc<GraphRun>) -> Self {
        GraphHandle { run, _env: PhantomData }
    }
}

impl GraphHandle<'_> {
    pub fn name(&self) -> &str {
        &self.run.graph
    }

    pub fn is_finished(&self) -> bool {
        self.run.progress.lock().unwrap().remaining == 0
    }

    /// Cancel the whole graph: nodes that have not dispatched are
    /// marked [`NodeStatus::Cancelled`] and their bodies dropped
    /// without ever entering the run queue; jobs already dispatched are
    /// cancelled ([`cancel_job`]) — their undispatched tasks are
    /// drained and the pool freed for other tenants, while task bodies
    /// already executing finish. [`GraphHandle::wait`] /
    /// [`GraphHandle::join`] then return as soon as the in-flight
    /// bodies settle. Idempotent; a no-op on a finished graph.
    pub fn cancel(&self) {
        let jobs: Vec<Arc<Job>> = {
            let mut p = self.run.progress.lock().unwrap();
            if p.remaining == 0 {
                return;
            }
            p.cancelled = true;
            for i in 0..self.run.nodes.len() {
                if p.status[i].is_none() && !p.dispatched[i] {
                    p.status[i] = Some(NodeStatus::Cancelled);
                    drop(self.run.nodes[i].body.lock().unwrap().take());
                    p.remaining -= 1;
                }
            }
            if p.remaining == 0 {
                p.makespan = self.run.start.elapsed().as_secs_f64();
            }
            self.run.jobs.lock().unwrap().clone()
        };
        self.run.done_cv.notify_all();
        // Cancel the dispatched jobs with no lock held: a job finishing
        // concurrently is already terminal and unaffected, and any job
        // enqueued concurrently with this sweep is caught by dispatch's
        // own post-enqueue re-check of the `cancelled` flag.
        for job in jobs {
            cancel_job(&job, &self.run.shared, &self.run.completed_jobs);
        }
    }

    /// Block until every node is terminal; resumes the first node panic
    /// (if any) on this thread.
    pub fn wait(self) -> GraphReport {
        let (report, panic) = wait_terminal(&self.run);
        if let Some(p) = panic {
            resume_unwind(p);
        }
        report
    }

    /// Like [`GraphHandle::wait`], but a node panic is reported as
    /// `Failed`/`Cancelled` statuses instead of being resumed.
    pub fn join(self) -> GraphReport {
        wait_terminal(&self.run).0
    }
}

/// Collect the terminal state into a report. Drains the per-node
/// reports rather than cloning them — `wait`/`join` consume the only
/// handle (and [`super::Session::run_all`] owns its runs), so this runs
/// at most once per graph.
pub(super) fn wait_terminal(
    run: &GraphRun,
) -> (GraphReport, Option<PanicPayload>) {
    let mut p = run.progress.lock().unwrap();
    while p.remaining > 0 {
        p = run.done_cv.wait(p).unwrap();
    }
    let mut nodes = Vec::with_capacity(run.nodes.len());
    for (i, n) in run.nodes.iter().enumerate() {
        nodes.push(NodeReport {
            name: n.name.clone(),
            status: p.status[i].expect("remaining == 0 means all terminal"),
            device: n.device,
            fallback: n.fallback.clone(),
            report: p.reports[i].take(),
        });
    }
    let report = GraphReport {
        graph: run.graph.clone(),
        nodes,
        makespan: p.makespan,
    };
    let panic = p.panic.take();
    (report, panic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sched::queue::QueueLayout;
    use crate::topology::Topology;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    fn exec() -> Executor {
        Executor::new(
            Arc::new(Topology::symmetric("test4", 2, 2, 1.5, 1.0)),
            Arc::new(SchedConfig::default()),
        )
    }

    #[test]
    fn borrowed_bodies_write_disjoint_ranges_through_the_graph() {
        // Miri-sized: the `run_graph` lifetime transmute + `DisjointMut`
        // unsafe paths together — a writer node fills disjoint halves,
        // a dependent reader sums them after the dependency edge.
        use crate::util::DisjointMut;
        let e = exec();
        let mut out = vec![0usize; 64];
        let sum = AtomicUsize::new(0);
        {
            let d = DisjointMut::new(&mut out);
            let spec = GraphSpec::new("disjoint")
                .node(NodeSpec::new("write", 64), |_w, r| {
                    for (off, x) in
                        d.slice_mut(r.start, r.end).iter_mut().enumerate()
                    {
                        *x = r.start + off;
                    }
                })
                .node(NodeSpec::new("read", 8).after("write"), |_w, _r| {
                    sum.store(d.slice(0, 64).iter().sum::<usize>(), Ordering::SeqCst);
                });
            let report = e.run_graph(spec).unwrap();
            assert!(report.all_completed());
        }
        assert_eq!(sum.load(Ordering::SeqCst), (0..64).sum::<usize>());
        assert!(out.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: four multi-hundred-item nodes")]
    fn diamond_completes_with_dependency_order() {
        let e = exec();
        let a_items = AtomicUsize::new(0);
        let bc_after_a = AtomicUsize::new(1);
        let d_after_bc = AtomicUsize::new(1);
        let b_items = AtomicUsize::new(0);
        let c_items = AtomicUsize::new(0);
        let spec = GraphSpec::new("diamond")
            .node(NodeSpec::new("a", 500), |_w, r| {
                a_items.fetch_add(r.len(), Ordering::SeqCst);
            })
            .node(NodeSpec::new("b", 300).after("a"), |_w, r| {
                if a_items.load(Ordering::SeqCst) != 500 {
                    bc_after_a.store(0, Ordering::SeqCst);
                }
                b_items.fetch_add(r.len(), Ordering::SeqCst);
            })
            .node(NodeSpec::new("c", 200).after("a"), |_w, r| {
                if a_items.load(Ordering::SeqCst) != 500 {
                    bc_after_a.store(0, Ordering::SeqCst);
                }
                c_items.fetch_add(r.len(), Ordering::SeqCst);
            })
            .node(
                NodeSpec::new("d", 100).after("b").after("c"),
                |_w, _r| {
                    if b_items.load(Ordering::SeqCst) != 300
                        || c_items.load(Ordering::SeqCst) != 200
                    {
                        d_after_bc.store(0, Ordering::SeqCst);
                    }
                },
            );
        let report = e.run_graph(spec).unwrap();
        assert!(report.all_completed());
        assert_eq!(bc_after_a.load(Ordering::SeqCst), 1, "b/c saw a complete");
        assert_eq!(d_after_bc.load(Ordering::SeqCst), 1, "d saw b and c done");
        assert_eq!(report.report("a").unwrap().total_items(), 500);
        assert_eq!(report.report("d").unwrap().total_items(), 100);
        assert!(report.makespan > 0.0);
        assert_eq!(e.jobs_completed(), 4, "one job per node");
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let e = exec();
        let two_cycle = GraphSpec::new("cycle")
            .node(NodeSpec::new("a", 10).after("b"), |_w, _r| {})
            .node(NodeSpec::new("b", 10).after("a"), |_w, _r| {});
        match e.submit_graph(two_cycle) {
            Err(GraphError::Cycle(names)) => {
                assert!(names.contains(&"a".to_string()));
                assert!(names.contains(&"b".to_string()));
            }
            other => panic!("expected cycle error, got {other:?}"),
        }

        let self_loop = GraphSpec::new("self")
            .node(NodeSpec::new("a", 10).after("a"), |_w, _r| {});
        assert!(matches!(
            e.submit_graph(self_loop),
            Err(GraphError::Cycle(_))
        ));

        let unknown = GraphSpec::new("unknown")
            .node(NodeSpec::new("a", 10).after("ghost"), |_w, _r| {});
        assert_eq!(
            e.submit_graph(unknown).err(),
            Some(GraphError::UnknownDependency {
                node: "a".into(),
                dep: "ghost".into()
            })
        );

        let dup = GraphSpec::new("dup")
            .node(NodeSpec::new("a", 10), |_w, _r| {})
            .node(NodeSpec::new("a", 10), |_w, _r| {});
        assert_eq!(
            e.submit_graph(dup).err(),
            Some(GraphError::DuplicateNode("a".into()))
        );
        // the pool is untouched by rejected specs
        assert_eq!(e.jobs_completed(), 0);
    }

    #[test]
    fn duplicate_edges_are_deduplicated() {
        // a double edge must not leave the dependent's pending count
        // above zero forever (that would hang the graph).
        let e = exec();
        let spec = GraphSpec::new("dupedge")
            .node(NodeSpec::new("a", 50), |_w, _r| {})
            .node(
                NodeSpec::new("b", 50).after("a").after("a"),
                |_w, _r| {},
            );
        let report = e.run_graph(spec).unwrap();
        assert!(report.all_completed());
    }

    #[test]
    fn zero_item_nodes_chain_through() {
        let e = exec();
        let ran = AtomicUsize::new(0);
        let spec = GraphSpec::new("empty-chain")
            .node(NodeSpec::new("a", 0), |_w, _r| {})
            .node(NodeSpec::new("b", 0).after("a"), |_w, _r| {})
            .node(NodeSpec::new("c", 64).after("b"), |_w, r| {
                ran.fetch_add(r.len(), Ordering::Relaxed);
            });
        let report = e.run_graph(spec).unwrap();
        assert!(report.all_completed());
        assert_eq!(ran.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn empty_graph_completes_immediately() {
        let e = exec();
        let report = e.run_graph(GraphSpec::new("empty")).unwrap();
        assert!(report.nodes.is_empty());
        assert!(report.all_completed());
    }

    #[test]
    fn per_node_config_overrides_apply() {
        let e = exec();
        let spec = GraphSpec::new("cfg")
            .node(NodeSpec::new("default", 100), |_w, _r| {})
            .node(
                NodeSpec::new("gss", 100)
                    .after("default")
                    .with_config(
                        SchedConfig::default()
                            .with_scheme(Scheme::Gss)
                            .with_layout(QueueLayout::PerCore),
                    ),
                |_w, _r| {},
            );
        let report = e.run_graph(spec).unwrap();
        assert_eq!(report.report("default").unwrap().scheme, "STATIC");
        assert_eq!(report.report("gss").unwrap().scheme, "GSS");
        assert_eq!(report.report("gss").unwrap().layout, "PERCORE");
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 1000-item recovery job")]
    fn wait_resumes_node_panic_and_join_reports_statuses() {
        let e = exec();
        let make_spec = || {
            GraphSpec::new("boom")
                .node(NodeSpec::new("ok", 100), |_w, _r| {})
                .node(NodeSpec::new("bad", 100).after("ok"), |_w, _r| {
                    panic!("node body failure")
                })
                .node(NodeSpec::new("child", 100).after("bad"), |_w, _r| {})
        };
        // join: statuses instead of a resumed panic
        let h = e.submit_graph(make_spec()).unwrap();
        let report = h.join();
        assert_eq!(report.status("ok"), Some(NodeStatus::Completed));
        assert_eq!(report.status("bad"), Some(NodeStatus::Failed));
        assert_eq!(report.status("child"), Some(NodeStatus::Cancelled));
        assert!(report.node("child").unwrap().report.is_none());
        // wait: resumes the panic
        let result = catch_unwind(AssertUnwindSafe(|| {
            e.run_graph(make_spec()).unwrap();
        }));
        assert!(result.is_err(), "wait must resume the node panic");
        // pool survives for subsequent work
        let r = e.run(super::super::JobSpec::new(1_000), |_w, _r| {});
        assert_eq!(r.total_items(), 1_000);
    }

    #[test]
    fn absent_class_placement_is_rejected_not_deadlocked() {
        use crate::topology::DeviceClass;
        let e = exec(); // CPU-only test topology
        let spec = GraphSpec::new("placed")
            .node(NodeSpec::new("root", 100), |_w, _r| {})
            .node(
                NodeSpec::new("accel", 100)
                    .after("root")
                    .on(DeviceClass::Fpga),
                |_w, _r| {},
            );
        match e.submit_graph(spec) {
            Err(GraphError::NoSuchPool { node, wanted }) => {
                assert_eq!(node, "accel");
                assert_eq!(wanted, "class:fpga");
            }
            other => panic!("expected NoSuchPool, got {other:?}"),
        }
        // nothing dispatched — not even the satisfiable root
        assert_eq!(e.jobs_completed(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 500-item placed nodes")]
    fn placed_nodes_report_their_device_and_pool() {
        use crate::sched::placement::{Placement, PoolId};
        use crate::topology::DeviceClass;
        let e = Executor::new(
            Arc::new(Topology::heterogeneous(
                "h",
                1,
                2,
                1.0,
                1.0,
                &[(DeviceClass::Gpu, 2, 2.0)],
            )),
            Arc::new(SchedConfig::default()),
        );
        let cpu_seen = Mutex::new(Vec::new());
        let accel_seen = Mutex::new(Vec::new());
        let spec = GraphSpec::new("hetero")
            .node(
                NodeSpec::new("cpu", 500).on(DeviceClass::Cpu),
                |w, _r| cpu_seen.lock().unwrap().push(w),
            )
            .node(
                NodeSpec::new("accel", 500)
                    .with_placement(Placement::Pool(PoolId(1))),
                |w, _r| accel_seen.lock().unwrap().push(w),
            )
            .node(
                NodeSpec::new("join", 10).after("cpu").after("accel"),
                |_w, _r| {},
            );
        let report = e.run_graph(spec).unwrap();
        assert!(report.all_completed());
        assert_eq!(report.node("cpu").unwrap().device, DeviceClass::Cpu);
        assert_eq!(report.node("accel").unwrap().device, DeviceClass::Gpu);
        assert_eq!(report.node("join").unwrap().device, DeviceClass::Cpu);
        assert!(report.node("cpu").unwrap().fallback.is_none());
        // explicit Pool pins stay on the GPU pool; without `pjrt` the
        // unbacked dispatch is annotated rather than silent
        let accel_fallback = &report.node("accel").unwrap().fallback;
        if cfg!(feature = "pjrt") {
            assert!(accel_fallback.is_none());
        } else {
            assert!(accel_fallback.as_ref().unwrap().contains("pjrt"));
        }
        assert!(cpu_seen.lock().unwrap().iter().all(|&w| w < 2));
        assert!(accel_seen.lock().unwrap().iter().all(|&w| w >= 2));
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 2000-item node")]
    fn submit_graph_handle_runs_detached() {
        let e = exec();
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let spec = GraphSpec::new("owned")
            .node(NodeSpec::new("a", 2_000), move |_w, r| {
                c.fetch_add(r.len(), Ordering::Relaxed);
            });
        let h = e.submit_graph(spec).unwrap();
        assert_eq!(h.name(), "owned");
        let report = h.wait();
        assert!(report.all_completed());
        assert_eq!(count.load(Ordering::Relaxed), 2_000);
        assert!(report.serial_time() > 0.0);
    }
}
