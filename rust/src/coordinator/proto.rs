//! Wire protocol between the coordinator (leader) and DaphneSched
//! workers (Fig. 5): length-prefixed binary frames over TCP, std-only.
//!
//! Message kinds mirror the paper's list: *distribute pipeline inputs*
//! (a row-partition of a matrix), *broadcast pipeline inputs* (shared
//! vectors), and *code shipment* (here: DaphneDSL text instead of MLIR —
//! the subset interpreter is the local compiler).

use std::io::{self, Read, Write};

use crate::matrix::CsrMatrix;

/// Protocol messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker -> leader greeting with its advertised parallelism.
    Hello { cores: u32 },
    /// Leader -> worker: a named dense buffer (broadcast input).
    Dense { name: String, rows: u64, cols: u64, data: Vec<f32> },
    /// Leader -> worker: a named sparse row-block (distributed input).
    /// `row_offset` is the block's first global row.
    SparseBlock {
        name: String,
        row_offset: u64,
        rows: u64,
        cols: u64,
        indptr: Vec<u64>,
        indices: Vec<u32>,
    },
    /// Leader -> worker: run a DaphneDSL script against stored inputs.
    RunScript { script: String, params: Vec<(String, String)> },
    /// Leader -> worker: one CC propagate pass over the stored block
    /// (`G` sparse block + broadcast `c`), returning the block's `u`.
    CcIterate,
    /// Worker -> leader: a result buffer plus scheduled time.
    Result { name: String, scheduled_time: f64, data: Vec<f32> },
    /// Worker -> leader: failure.
    Error { message: String },
    /// Acknowledgement.
    Ok,
    /// Leader -> worker: disconnect.
    Shutdown,
}

const TAG_HELLO: u8 = 1;
const TAG_DENSE: u8 = 2;
const TAG_SPARSE: u8 = 3;
const TAG_RUN: u8 = 4;
const TAG_CC_ITER: u8 = 5;
const TAG_RESULT: u8 = 6;
const TAG_ERROR: u8 = 7;
const TAG_OK: u8 = 8;
const TAG_SHUTDOWN: u8 = 9;

/// Hard cap on frame payloads (guards against corrupt length prefixes).
pub const MAX_FRAME: u64 = 8 << 30;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    put_u64(buf, v.len() as u64);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u64s(buf: &mut Vec<u8>, v: &[u64]) {
    put_u64(buf, v.len() as u64);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    put_u64(buf, v.len() as u64);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "truncated frame",
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> io::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> io::Result<String> {
        let n = self.u64()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "bad utf8 in frame")
        })
    }

    fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64s(&mut self) -> io::Result<Vec<u64>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u32s(&mut self) -> io::Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Serialize and frame a message.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut body = Vec::new();
    match msg {
        Msg::Hello { cores } => {
            body.push(TAG_HELLO);
            put_u32(&mut body, *cores);
        }
        Msg::Dense { name, rows, cols, data } => {
            body.push(TAG_DENSE);
            put_str(&mut body, name);
            put_u64(&mut body, *rows);
            put_u64(&mut body, *cols);
            put_f32s(&mut body, data);
        }
        Msg::SparseBlock { name, row_offset, rows, cols, indptr, indices } => {
            body.push(TAG_SPARSE);
            put_str(&mut body, name);
            put_u64(&mut body, *row_offset);
            put_u64(&mut body, *rows);
            put_u64(&mut body, *cols);
            put_u64s(&mut body, indptr);
            put_u32s(&mut body, indices);
        }
        Msg::RunScript { script, params } => {
            body.push(TAG_RUN);
            put_str(&mut body, script);
            put_u64(&mut body, params.len() as u64);
            for (k, v) in params {
                put_str(&mut body, k);
                put_str(&mut body, v);
            }
        }
        Msg::CcIterate => body.push(TAG_CC_ITER),
        Msg::Result { name, scheduled_time, data } => {
            body.push(TAG_RESULT);
            put_str(&mut body, name);
            put_f64(&mut body, *scheduled_time);
            put_f32s(&mut body, data);
        }
        Msg::Error { message } => {
            body.push(TAG_ERROR);
            put_str(&mut body, message);
        }
        Msg::Ok => body.push(TAG_OK),
        Msg::Shutdown => body.push(TAG_SHUTDOWN),
    }
    let mut frame = Vec::with_capacity(body.len() + 8);
    put_u64(&mut frame, body.len() as u64);
    frame.extend_from_slice(&body);
    frame
}

/// Write one framed message.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> io::Result<()> {
    w.write_all(&encode(msg))?;
    w.flush()
}

/// Read one framed message.
pub fn read_msg(r: &mut impl Read) -> io::Result<Msg> {
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len);
    if len == 0 || len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad frame length {len}"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode(&body)
}

fn decode(body: &[u8]) -> io::Result<Msg> {
    let mut c = Cursor { b: body, i: 1 };
    match body.first() {
        Some(&TAG_HELLO) => Ok(Msg::Hello { cores: c.u32()? }),
        Some(&TAG_DENSE) => Ok(Msg::Dense {
            name: c.str()?,
            rows: c.u64()?,
            cols: c.u64()?,
            data: c.f32s()?,
        }),
        Some(&TAG_SPARSE) => Ok(Msg::SparseBlock {
            name: c.str()?,
            row_offset: c.u64()?,
            rows: c.u64()?,
            cols: c.u64()?,
            indptr: c.u64s()?,
            indices: c.u32s()?,
        }),
        Some(&TAG_RUN) => {
            let script = c.str()?;
            let n = c.u64()? as usize;
            let mut params = Vec::with_capacity(n);
            for _ in 0..n {
                params.push((c.str()?, c.str()?));
            }
            Ok(Msg::RunScript { script, params })
        }
        Some(&TAG_CC_ITER) => Ok(Msg::CcIterate),
        Some(&TAG_RESULT) => Ok(Msg::Result {
            name: c.str()?,
            scheduled_time: c.f64()?,
            data: c.f32s()?,
        }),
        Some(&TAG_ERROR) => Ok(Msg::Error { message: c.str()? }),
        Some(&TAG_OK) => Ok(Msg::Ok),
        Some(&TAG_SHUTDOWN) => Ok(Msg::Shutdown),
        other => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("unknown tag {other:?}"),
        )),
    }
}

/// Build the sparse-block message for rows `[start, end)` of `g`.
pub fn sparse_block_msg(
    name: &str,
    g: &CsrMatrix,
    start: usize,
    end: usize,
) -> Msg {
    let base = g.indptr[start];
    let indptr: Vec<u64> = g.indptr[start..=end]
        .iter()
        .map(|&p| (p - base) as u64)
        .collect();
    let indices = g.indices[g.indptr[start]..g.indptr[end]].to_vec();
    Msg::SparseBlock {
        name: name.to_string(),
        row_offset: start as u64,
        rows: (end - start) as u64,
        cols: g.cols as u64,
        indptr,
        indices,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) {
        let bytes = encode(&msg);
        let mut r = &bytes[..];
        let got = read_msg(&mut r).unwrap();
        assert_eq!(got, msg);
        assert!(r.is_empty(), "unconsumed bytes");
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Msg::Hello { cores: 8 });
        roundtrip(Msg::Dense {
            name: "c".into(),
            rows: 2,
            cols: 2,
            data: vec![1.0, -2.5, 3.0, 0.0],
        });
        roundtrip(Msg::SparseBlock {
            name: "G".into(),
            row_offset: 100,
            rows: 2,
            cols: 10,
            indptr: vec![0, 1, 3],
            indices: vec![5, 2, 9],
        });
        roundtrip(Msg::RunScript {
            script: "x = 1;".into(),
            params: vec![("a".into(), "1".into()), ("b".into(), "z".into())],
        });
        roundtrip(Msg::CcIterate);
        roundtrip(Msg::Result {
            name: "u".into(),
            scheduled_time: 0.125,
            data: vec![9.0; 3],
        });
        roundtrip(Msg::Error { message: "boom".into() });
        roundtrip(Msg::Ok);
        roundtrip(Msg::Shutdown);
    }

    #[test]
    fn sparse_block_extracts_window() {
        let g = CsrMatrix::from_edges(
            4,
            4,
            &[(0, 1), (1, 2), (1, 3), (2, 0), (3, 1)],
        );
        let Msg::SparseBlock { row_offset, rows, indptr, indices, .. } =
            sparse_block_msg("G", &g, 1, 3)
        else {
            panic!()
        };
        assert_eq!(row_offset, 1);
        assert_eq!(rows, 2);
        assert_eq!(indptr, vec![0, 2, 3]); // rows 1 (2 nnz) and 2 (1 nnz)
        assert_eq!(indices, vec![2, 3, 0]);
    }

    #[test]
    fn rejects_bad_frames() {
        // zero length
        let z = 0u64.to_le_bytes();
        assert!(read_msg(&mut &z[..]).is_err());
        // unknown tag
        let mut f = Vec::new();
        f.extend_from_slice(&1u64.to_le_bytes());
        f.push(0xFF);
        assert!(read_msg(&mut &f[..]).is_err());
        // truncated body
        let mut f = Vec::new();
        f.extend_from_slice(&100u64.to_le_bytes());
        f.push(TAG_OK);
        assert!(read_msg(&mut &f[..]).is_err());
    }
}
