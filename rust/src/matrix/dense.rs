//! Row-major dense f32 matrix (the DAPHNE `DenseMatrix<double>` analog;
//! f32 to match the PJRT artifacts).

use crate::util::Rng;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        DenseMatrix { rows, cols, data }
    }

    /// DaphneDSL `rand(rows, cols, lo, hi, sparsity?, seed)`.
    pub fn rand(rows: usize, cols: usize, lo: f32, hi: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols)
            .map(|_| lo + (hi - lo) * rng.next_f64() as f32)
            .collect();
        DenseMatrix { rows, cols, data }
    }

    /// DaphneDSL `fill(value, rows, cols)`.
    pub fn fill(value: f32, rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![value; rows * cols] }
    }

    /// DaphneDSL `seq(a, b)` as a column vector (inclusive bounds).
    pub fn seq(a: i64, b: i64) -> Self {
        let data: Vec<f32> = (a..=b).map(|v| v as f32).collect();
        DenseMatrix { rows: data.len(), cols: 1, data }
    }

    /// Identity-diagonal matrix from a column vector (DaphneDSL
    /// `diagMatrix`).
    pub fn diag(v: &DenseMatrix) -> Self {
        assert_eq!(v.cols, 1, "diagMatrix expects a column vector");
        let n = v.rows;
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = v[(i, 0)];
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column slice (copies).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Select a half-open column range into a new matrix (DaphneDSL
    /// `X[, a:b]` right-indexing).
    pub fn cols_range(&self, start: usize, end: usize) -> DenseMatrix {
        assert!(start <= end && end <= self.cols);
        let mut out = DenseMatrix::zeros(self.rows, end - start);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[start..end]);
        }
        out
    }

    /// Select a half-open row range (zero-copy would need lifetimes the
    /// VEE does not require; tasks slice rows themselves).
    pub fn rows_range(&self, start: usize, end: usize) -> DenseMatrix {
        assert!(start <= end && end <= self.rows);
        DenseMatrix {
            rows: end - start,
            cols: self.cols,
            data: self.data[start * self.cols..end * self.cols].to_vec(),
        }
    }

    /// Horizontal concatenation (DaphneDSL `cbind`).
    pub fn cbind(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.rows, other.rows, "cbind row mismatch");
        let mut out = DenseMatrix::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    pub fn transpose(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Frobenius-norm distance (test helper).
    pub fn dist(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for DenseMatrix {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DenseMatrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_round_trips() {
        let mut m = DenseMatrix::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn rand_respects_bounds_and_seed() {
        let a = DenseMatrix::rand(10, 10, -1.0, 1.0, 7);
        let b = DenseMatrix::rand(10, 10, -1.0, 1.0, 7);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|&x| (-1.0..1.0).contains(&x)));
    }

    #[test]
    fn seq_matches_daphnedsl() {
        let s = DenseMatrix::seq(1, 5);
        assert_eq!(s.rows, 5);
        assert_eq!(s.data, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn cbind_and_ranges() {
        let a = DenseMatrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = DenseMatrix::fill(9.0, 2, 1);
        let c = a.cbind(&b);
        assert_eq!(c.cols, 3);
        assert_eq!(c.row(0), &[1.0, 2.0, 9.0]);
        assert_eq!(c.cols_range(2, 3).data, vec![9.0, 9.0]);
        assert_eq!(c.rows_range(1, 2).row(0), &[3.0, 4.0, 9.0]);
    }

    #[test]
    fn diag_and_transpose() {
        let v = DenseMatrix::from_vec(2, 1, vec![2.0, 3.0]);
        let d = DenseMatrix::diag(&v);
        assert_eq!(d[(0, 0)], 2.0);
        assert_eq!(d[(1, 1)], 3.0);
        assert_eq!(d[(0, 1)], 0.0);

        let m = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }
}
