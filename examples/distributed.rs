//! Fig. 5 end-to-end on localhost: spawn worker daemons, connect the
//! coordinator, run distributed connected components, verify against a
//! local run.
//!
//! ```sh
//! cargo run --release --example distributed
//! ```

use std::net::TcpListener;

use daphne_sched::apps::cc;
use daphne_sched::config::SchedConfig;
use daphne_sched::coordinator::{worker, Leader};
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::sched::Scheme;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn main() {
    let n_workers = 4;
    let g = amazon_like(&SnapGraph::small(30_000, 9)).symmetrize();
    println!(
        "graph: {} nodes / {} edges; {} distributed workers",
        g.rows,
        g.nnz(),
        n_workers
    );

    // worker daemons on ephemeral localhost ports
    let mut addrs = Vec::new();
    for i in 0..n_workers {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap());
        let vee = Vee::new(
            Topology::host(),
            SchedConfig::default().with_scheme(Scheme::Gss).with_seed(i),
        );
        std::thread::spawn(move || worker::serve(listener, vee, Some(1)));
    }

    let mut leader = Leader::connect(&addrs).unwrap();
    println!("coordinator connected to {} workers", leader.n_workers());
    let dist = leader.cc_distributed(&g, 100).unwrap();
    leader.shutdown().unwrap();

    let local = cc::run_native(
        &g,
        &Topology::host(),
        &SchedConfig::default(),
        100,
    );
    assert_eq!(dist.labels, local.labels, "distributed != local labels");
    println!(
        "distributed cc: {} iterations, labels match local run, \
         critical-path scheduled time {:.4}s",
        dist.iterations, dist.scheduled_time
    );
}
