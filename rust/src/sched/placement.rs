//! Heterogeneous device pools and graph-node placement.
//!
//! The DAPHNE worker manager "also creates threads that launch kernels
//! on accelerators" (§3) — device classes are first-class in the worker
//! manager even though the paper's evaluation is CPU-only. This module
//! makes the dimension operational, the direction argued by Trident
//! (adaptive scheduling for heterogeneous multimodal pipelines) and the
//! data-aware heterogeneous-execution line of work in PAPERS.md:
//!
//! - [`DevicePools`] partitions a [`Topology`]'s places into one worker
//!   pool per [`DeviceClass`], each with a pool-scoped sub-topology
//!   (dense local worker ids, dense local NUMA domains, the per-class
//!   speed factor folded into `core_speed`). The persistent
//!   [`Executor`](super::Executor) builds this partition once at spawn;
//!   the DES graph replay ([`crate::sim::graph::replay`]) builds the
//!   same partition over the modelled machine.
//! - [`Placement`] is the routing constraint a job or graph node
//!   carries: `Any` (the default pool — CPU when present), `Class`
//!   (pin to a device class), or `Pool` (pin to an explicit pool).
//!   Task sources are pool-scoped, so chunks of a placed node are only
//!   ever pulled — locally or via stealing — by workers of its pool;
//!   victim selection cannot cross a pool boundary by construction.
//! - Placement is *validated before dispatch*: a `Class` naming a
//!   device class the topology does not provide resolves to an error
//!   (surfaced as [`GraphError::NoSuchPool`](super::GraphError) by the
//!   graph layer), never to an idle node that deadlocks the graph.
//!
//! # GPU execution vs GPU modelling
//!
//! Two resolution modes ([`ResolveMode`]) separate what the *build* can
//! execute from what the *machine model* provides:
//!
//! - [`ResolveMode::Execute`] (real executor): `Class(Gpu)` on a
//!   GPU-bearing topology routes to the GPU launcher pool — the
//!   dedicated threads where kernel launches belong. The executor
//!   routes bodies, it does not rewrite them: a GPU node's closure is
//!   expected to drive the device itself through the PJRT
//!   [`DeviceClient`](crate::runtime::DeviceClient) (as the apps'
//!   `run_pjrt` paths do), which requires the `pjrt` feature. Without
//!   the feature (the stub runtime cannot execute kernels) the node
//!   falls back to the CPU pool and the resolution carries a fallback
//!   annotation, which the graph layer surfaces on the
//!   [`NodeReport`](super::NodeReport); if the topology has no CPU
//!   pool to fall back to, the GPU pool is kept and the annotation
//!   records that it runs without PJRT backing.
//! - [`ResolveMode::Model`] (DES replay, autotuning): the modelled
//!   machine's GPU pool is always honoured — simulation does not launch
//!   kernels, so predictions describe the hardware, not this build.

use std::fmt;
use std::sync::Arc;

use crate::topology::{CorePlace, DeviceClass, Topology};

/// Identifier of one device pool of an executor/topology: index into
/// [`DevicePools`], dense in `0..n_pools`, ordered by first appearance
/// of the class in the topology (CPU first for built-in constructors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PoolId(pub usize);

/// Where a job or graph node may execute. Resolved against the
/// executor's (or modelled machine's) [`DevicePools`] before dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// No constraint: the default pool — the CPU pool when the topology
    /// has one (so an unplaced graph on a heterogeneous machine behaves
    /// exactly like today's CPU-only dispatch), otherwise pool 0.
    #[default]
    Any,
    /// Pin to the pool of a device class; an absent class is a hard
    /// resolution error, never a hang.
    Class(DeviceClass),
    /// Pin to an explicit pool.
    Pool(PoolId),
}

impl Placement {
    /// Short human-readable form (`any`, `class:gpu`, `pool:1`) used in
    /// reports, errors and CLI output.
    pub fn describe(&self) -> String {
        match self {
            Placement::Any => "any".to_string(),
            Placement::Class(c) => format!("class:{}", c.name()),
            Placement::Pool(PoolId(i)) => format!("pool:{i}"),
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.describe())
    }
}

/// How a run assigns placements to the heterogeneous app's graph nodes
/// (CLI `placement=any|pinned|auto`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Every node `Placement::Any` — the all-CPU baseline.
    Any,
    /// The app's hand-pinned class assignment.
    Pinned,
    /// Placement chosen per node by graph-level autotuning
    /// ([`super::autotune::tune_graph`]) with replay as the oracle.
    #[default]
    Auto,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Any => "any",
            PlacementPolicy::Pinned => "pinned",
            PlacementPolicy::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "any" => Some(PlacementPolicy::Any),
            "pinned" | "pin" | "class" => Some(PlacementPolicy::Pinned),
            "auto" | "tuned" => Some(PlacementPolicy::Auto),
            _ => None,
        }
    }
}

/// Whether placement resolution models the machine or gates on what
/// this build can actually execute (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResolveMode {
    /// Real execution: GPU placements degrade to the CPU pool (with an
    /// annotation) when the crate is built without the `pjrt` feature.
    Execute,
    /// Virtual-time modelling: every pool of the machine model is
    /// honoured regardless of build features.
    Model,
}

/// A placement that cannot be satisfied by the topology's pools.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlacementError {
    /// The unsatisfiable requirement, in [`Placement::describe`] form.
    pub wanted: String,
}

impl fmt::Display for PlacementError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "no device pool satisfies placement '{}'", self.wanted)
    }
}

impl std::error::Error for PlacementError {}

/// Outcome of resolving one [`Placement`] against a pool set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Resolution {
    /// Index of the pool the job/node dispatches on.
    pub pool: usize,
    /// Set when the placement was degraded (GPU → CPU on a pjrt-stub
    /// build); surfaced as the
    /// [`NodeReport::fallback`](super::NodeReport) annotation.
    pub fallback: Option<String>,
}

/// One per-class worker pool.
#[derive(Debug, Clone)]
pub struct DevicePool {
    pub id: PoolId,
    pub class: DeviceClass,
    /// Global core/worker ids of this pool's members, ascending.
    pub members: Vec<usize>,
    /// Pool-scoped topology: dense local worker ids `0..members.len()`,
    /// the members' NUMA domains remapped dense, and the per-class
    /// speed factor folded into `core_speed` — what task sources,
    /// victim selectors and the DES cost model see for this pool.
    pub topo: Arc<Topology>,
}

/// The partition of a topology's places into per-class pools, plus the
/// global-worker → (pool, local index) maps the executor and the DES
/// replay both dispatch through.
#[derive(Debug, Clone)]
pub struct DevicePools {
    pools: Vec<DevicePool>,
    /// Global worker id → pool index.
    pool_of: Vec<usize>,
    /// Global worker id → dense index within its pool.
    local_of: Vec<usize>,
    default_pool: usize,
}

impl DevicePools {
    /// Partition `topo` into one pool per device class, in order of
    /// first appearance. A homogeneous topology yields a single pool
    /// that *shares* the input `Arc` (no behaviour or allocation drift
    /// vs pre-pool dispatch).
    pub fn new(topo: &Arc<Topology>) -> Self {
        let classes = topo.device_classes();
        if classes.len() <= 1 {
            let n = topo.n_cores();
            return DevicePools {
                pools: vec![DevicePool {
                    id: PoolId(0),
                    class: classes.first().copied().unwrap_or(DeviceClass::Cpu),
                    members: (0..n).collect(),
                    topo: Arc::clone(topo),
                }],
                pool_of: vec![0; n],
                local_of: (0..n).collect(),
                default_pool: 0,
            };
        }

        let mut pools = Vec::with_capacity(classes.len());
        let mut pool_of = vec![0usize; topo.n_cores()];
        let mut local_of = vec![0usize; topo.n_cores()];
        for (pid, &class) in classes.iter().enumerate() {
            let members: Vec<usize> = topo
                .places
                .iter()
                .filter(|p| p.device == class)
                .map(|p| p.core)
                .collect();
            // Remap the members' domains dense, preserving order.
            let mut domains: Vec<usize> = Vec::new();
            let mut places = Vec::with_capacity(members.len());
            for (local, &core) in members.iter().enumerate() {
                pool_of[core] = pid;
                local_of[core] = local;
                let socket = topo.socket_of(core);
                let dense = match domains.iter().position(|&d| d == socket) {
                    Some(i) => i,
                    None => {
                        domains.push(socket);
                        domains.len() - 1
                    }
                };
                places.push(CorePlace {
                    core: local,
                    socket: dense,
                    device: class,
                    // folded into the pool topology's core_speed below
                    speed: 1.0,
                });
            }
            let class_speed = topo.places[members[0]].speed;
            // Hard assert (release builds included): same-class entries
            // merge into ONE pool whose sub-topology carries a single
            // speed factor — silently pricing mixed-speed devices at the
            // first member's speed would skew every placement decision.
            assert!(
                members
                    .iter()
                    .all(|&c| topo.places[c].speed == class_speed),
                "device class {} has places with differing speed factors; \
                 per-class pools require a uniform per-class speed",
                class.name()
            );
            pools.push(DevicePool {
                id: PoolId(pid),
                class,
                members,
                topo: Arc::new(Topology {
                    name: format!("{}:{}", topo.name, class.name()),
                    places,
                    sockets: domains.len(),
                    remote_numa_factor: topo.remote_numa_factor,
                    core_speed: topo.core_speed * class_speed,
                }),
            });
        }
        let default_pool = classes
            .iter()
            .position(|&c| c == DeviceClass::Cpu)
            .unwrap_or(0);
        DevicePools { pools, pool_of, local_of, default_pool }
    }

    /// Like [`DevicePools::new`] for callers holding a borrowed
    /// topology (the DES replay path).
    pub fn from_topology(topo: &Topology) -> Self {
        Self::new(&Arc::new(topo.clone()))
    }

    pub fn n_pools(&self) -> usize {
        self.pools.len()
    }

    /// Total workers across every pool (= the topology's core count).
    pub fn n_workers(&self) -> usize {
        self.pool_of.len()
    }

    pub fn pools(&self) -> &[DevicePool] {
        &self.pools
    }

    pub fn pool(&self, i: usize) -> &DevicePool {
        &self.pools[i]
    }

    /// Pool the given global worker belongs to.
    pub fn pool_of(&self, worker: usize) -> usize {
        self.pool_of[worker]
    }

    /// Dense index of the given global worker within its pool.
    pub fn local_of(&self, worker: usize) -> usize {
        self.local_of[worker]
    }

    pub fn default_pool(&self) -> usize {
        self.default_pool
    }

    /// Pool index of a device class, if the topology provides one.
    pub fn class_pool(&self, class: DeviceClass) -> Option<usize> {
        self.pools.iter().position(|p| p.class == class)
    }

    /// True when the whole machine is one pool (the CPU-only case).
    pub fn is_homogeneous(&self) -> bool {
        self.pools.len() == 1
    }

    /// Whether Execute-mode resolutions must treat GPU pools as
    /// unbacked (no `pjrt` feature to drive kernels through).
    fn gpu_unbacked(mode: ResolveMode) -> bool {
        mode == ResolveMode::Execute && !cfg!(feature = "pjrt")
    }

    /// Wrap a resolved pool, annotating any Execute-mode landing on an
    /// unbacked GPU pool — `Any` defaulting into it and explicit
    /// `Pool(id)` pins included, so unbacked GPU dispatch is *never*
    /// silent regardless of how the pool was addressed.
    fn finish(&self, pool: usize, mode: ResolveMode) -> Resolution {
        let fallback = (Self::gpu_unbacked(mode)
            && self.pools[pool].class == DeviceClass::Gpu)
            .then(|| {
                "gpu pool dispatched without pjrt backing (built without \
                 the `pjrt` feature)"
                    .to_string()
            });
        Resolution { pool, fallback }
    }

    /// Resolve a placement to a pool (see the module docs for the
    /// `Execute` vs `Model` distinction). Absent classes and
    /// out-of-range pools are errors in both modes.
    pub fn resolve(
        &self,
        placement: &Placement,
        mode: ResolveMode,
    ) -> Result<Resolution, PlacementError> {
        match placement {
            Placement::Any => Ok(self.finish(self.default_pool, mode)),
            Placement::Pool(PoolId(i)) => {
                if *i < self.pools.len() {
                    Ok(self.finish(*i, mode))
                } else {
                    Err(PlacementError { wanted: placement.describe() })
                }
            }
            Placement::Class(class) => {
                let Some(pool) = self.class_pool(*class) else {
                    return Err(PlacementError {
                        wanted: placement.describe(),
                    });
                };
                if *class == DeviceClass::Gpu && Self::gpu_unbacked(mode) {
                    // The stub runtime cannot launch kernels; degrade to
                    // the CPU pool (annotated) rather than dispatching
                    // GPU work a pjrt-less build cannot execute. A
                    // GPU-only topology has nowhere to degrade to —
                    // `finish` keeps the pool but still annotates.
                    if let Some(cpu) = self.class_pool(DeviceClass::Cpu) {
                        return Ok(Resolution {
                            pool: cpu,
                            fallback: Some(
                                "gpu placement degraded to the cpu pool: \
                                 built without the `pjrt` feature"
                                    .to_string(),
                            ),
                        });
                    }
                }
                Ok(self.finish(pool, mode))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hetero() -> Arc<Topology> {
        Arc::new(Topology::heterogeneous(
            "h",
            2,
            2,
            1.5,
            1.0,
            &[(DeviceClass::Gpu, 2, 4.0)],
        ))
    }

    #[test]
    fn homogeneous_topology_is_one_shared_pool() {
        let topo = Arc::new(Topology::symmetric("t", 2, 2, 1.5, 1.0));
        let pools = DevicePools::new(&topo);
        assert!(pools.is_homogeneous());
        assert_eq!(pools.n_pools(), 1);
        let p = pools.pool(0);
        assert_eq!(p.class, DeviceClass::Cpu);
        assert_eq!(p.members, vec![0, 1, 2, 3]);
        assert!(
            Arc::ptr_eq(&p.topo, &topo),
            "single pool must share the topology, not clone it"
        );
        for w in 0..4 {
            assert_eq!(pools.pool_of(w), 0);
            assert_eq!(pools.local_of(w), w);
        }
    }

    #[test]
    fn heterogeneous_topology_partitions_by_class() {
        let pools = DevicePools::new(&hetero());
        assert_eq!(pools.n_pools(), 2);
        let cpu = pools.pool(0);
        assert_eq!(cpu.class, DeviceClass::Cpu);
        assert_eq!(cpu.members, vec![0, 1, 2, 3]);
        assert_eq!(cpu.topo.n_cores(), 4);
        assert_eq!(cpu.topo.sockets, 2);
        assert_eq!(cpu.topo.core_speed, 1.0);
        let gpu = pools.pool(1);
        assert_eq!(gpu.class, DeviceClass::Gpu);
        assert_eq!(gpu.members, vec![4, 5]);
        assert_eq!(gpu.topo.n_cores(), 2);
        assert_eq!(gpu.topo.sockets, 1, "one accelerator domain");
        assert_eq!(gpu.topo.core_speed, 4.0, "class speed folded in");
        // global -> (pool, local) maps
        assert_eq!(pools.pool_of(3), 0);
        assert_eq!(pools.local_of(3), 3);
        assert_eq!(pools.pool_of(4), 1);
        assert_eq!(pools.local_of(4), 0);
        assert_eq!(pools.pool_of(5), 1);
        assert_eq!(pools.local_of(5), 1);
        assert_eq!(pools.default_pool(), 0, "CPU pool is the default");
    }

    #[test]
    fn cpu_pool_topology_matches_the_symmetric_machine() {
        // The CPU slice of hetero20 must be byte-for-byte the Broadwell
        // model: placement-aware dispatch on the CPU pool cannot drift
        // from CPU-only dispatch.
        let pools = DevicePools::new(&Arc::new(Topology::hetero20()));
        let cpu = &pools.pool(0).topo;
        let bw = Topology::broadwell20();
        assert_eq!(cpu.n_cores(), bw.n_cores());
        assert_eq!(cpu.sockets, bw.sockets);
        assert_eq!(cpu.core_speed, bw.core_speed);
        for (a, b) in cpu.places.iter().zip(&bw.places) {
            assert_eq!(a.core, b.core);
            assert_eq!(a.socket, b.socket);
        }
    }

    #[test]
    fn resolution_rules() {
        let pools = DevicePools::new(&hetero());
        for mode in [ResolveMode::Execute, ResolveMode::Model] {
            let any = pools.resolve(&Placement::Any, mode).unwrap();
            assert_eq!(any.pool, 0);
            assert!(any.fallback.is_none());
            let cpu = pools
                .resolve(&Placement::Class(DeviceClass::Cpu), mode)
                .unwrap();
            assert_eq!(cpu.pool, 0);
            // Pool(id) pins strictly in both modes; an Execute-mode
            // landing on an unbacked GPU pool is annotated, never
            // rerouted.
            let explicit =
                pools.resolve(&Placement::Pool(PoolId(1)), mode).unwrap();
            assert_eq!(explicit.pool, 1);
            if mode == ResolveMode::Model || cfg!(feature = "pjrt") {
                assert!(explicit.fallback.is_none());
            } else {
                let note = explicit.fallback.expect("unbacked gpu annotated");
                assert!(note.contains("pjrt"), "{note}");
            }
            // absent class and out-of-range pool are hard errors
            assert!(pools
                .resolve(&Placement::Class(DeviceClass::Fpga), mode)
                .is_err());
            assert!(pools
                .resolve(&Placement::Pool(PoolId(9)), mode)
                .is_err());
        }
    }

    #[test]
    fn gpu_resolution_models_always_and_degrades_only_in_execute_stub() {
        let pools = DevicePools::new(&hetero());
        let modelled = pools
            .resolve(&Placement::Class(DeviceClass::Gpu), ResolveMode::Model)
            .unwrap();
        assert_eq!(modelled.pool, 1, "the model always honours the GPU pool");
        assert!(modelled.fallback.is_none());

        let executed = pools
            .resolve(&Placement::Class(DeviceClass::Gpu), ResolveMode::Execute)
            .unwrap();
        if cfg!(feature = "pjrt") {
            assert_eq!(executed.pool, 1);
            assert!(executed.fallback.is_none());
        } else {
            assert_eq!(executed.pool, 0, "stub build degrades GPU to CPU");
            let note = executed.fallback.expect("degradation is annotated");
            assert!(note.contains("pjrt"), "{note}");
        }
    }

    #[test]
    fn gpu_only_topology_never_degrades_silently() {
        // No CPU pool to fall back to: Execute mode keeps the GPU pool
        // but must still annotate on a stub build.
        let topo = Arc::new(Topology::heterogeneous(
            "gpu-only",
            0,
            0,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 4.0)],
        ));
        let pools = DevicePools::new(&topo);
        let res = pools
            .resolve(&Placement::Class(DeviceClass::Gpu), ResolveMode::Execute)
            .unwrap();
        assert_eq!(pools.pool(res.pool).class, DeviceClass::Gpu);
        if cfg!(feature = "pjrt") {
            assert!(res.fallback.is_none());
        } else {
            let note = res.fallback.expect("must be annotated, not silent");
            assert!(note.contains("pjrt"), "{note}");
        }
    }

    #[test]
    fn placement_describe_forms() {
        assert_eq!(Placement::Any.describe(), "any");
        assert_eq!(
            Placement::Class(DeviceClass::Gpu).describe(),
            "class:gpu"
        );
        assert_eq!(Placement::Pool(PoolId(2)).describe(), "pool:2");
        assert_eq!(Placement::default(), Placement::Any);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [
            PlacementPolicy::Any,
            PlacementPolicy::Pinned,
            PlacementPolicy::Auto,
        ] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("bogus"), None);
    }
}
