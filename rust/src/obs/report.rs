//! Real-vs-DES divergence diffing and machine-readable bench reports.
//!
//! Two consumers of the PR 8 trace stream that close the loop the
//! recorder opened:
//!
//! - [`diff_traces`] aligns a real run's drained stream with its
//!   virtual-time DES replay (diffable by design: both engines emit the
//!   same per-node `Enqueue`/`Dispatch`/`NodeComplete` skeleton) and
//!   reports per-node modelled-vs-measured skew ranked by contribution
//!   to the makespan error, plus an ordering-skew count — nodes whose
//!   event-kind sequence differs between the engines, or that appear in
//!   only one stream.
//! - [`BenchReport`] serializes analysis results, figure rows and serve
//!   reports into the stable `BENCH_<name>.json` schema
//!   ([`BENCH_SCHEMA`]) so CI and the perf trajectory get a
//!   machine-readable record of every measured run.
//!
//! [`service_times_from_chrome_trace`] is the calibration bridge: it
//! re-derives per-node service seconds from an exported Chrome trace so
//! `tune graph=<app> calibrate=<trace.json>` can re-tune on measured
//! rather than assumed workloads (see
//! `crate::sim::model::TraceCalibration`).

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::obs::export::label;
use crate::obs::trace::{TraceEvent, TraceKind, NO_JOB};
use crate::util::json::{self, Json};

/// Schema identifier stamped into every report; bump on breaking
/// changes so downstream tooling can dispatch on it.
pub const BENCH_SCHEMA: &str = "daphne-sched/bench/v1";

/// Per-node modelled-vs-measured comparison.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeSkew {
    pub name_hash: u64,
    pub label: String,
    /// Span (first `Enqueue` to last `NodeComplete`, ns) in the DES
    /// stream; `None` when the node never appeared there.
    pub modelled_ns: Option<u64>,
    /// Same span in the measured stream.
    pub measured_ns: Option<u64>,
    /// `modelled - measured` (one-sided nodes count their full span).
    pub skew_ns: i64,
    /// The per-node `Enqueue`/`Dispatch`/`NodeComplete` sequence
    /// differs between the streams, or the node is one-sided.
    pub ordering_mismatch: bool,
}

/// Result of [`diff_traces`], ranked by `|skew_ns|` descending.
#[derive(Debug, Clone, Default)]
pub struct TraceDiff {
    pub nodes: Vec<NodeSkew>,
    /// Count of nodes with an ordering mismatch (zero when the DES
    /// reproduced the real run's per-node event skeleton exactly).
    pub ordering_skew: usize,
    pub modelled_makespan_ns: u64,
    pub measured_makespan_ns: u64,
}

/// Per-node state collected from one stream: span bounds plus the
/// shared-kind sequence as `(ts, rank)` pairs — sorted by `(ts, rank)`
/// before comparison, so same-timestamp ties (a DES burst stamps
/// Enqueue and first Dispatch at the same virtual instant, and lane
/// merge order on ties is arbitrary) collapse to the canonical
/// Enqueue < Dispatch < NodeComplete order instead of registering as
/// skew. Genuinely reordered kinds still differ: their *timestamps*
/// order them the wrong way on one side.
#[derive(Default)]
struct SideSpan {
    enqueue_ns: Option<u64>,
    complete_ns: Option<u64>,
    seq: Vec<(u64, u8)>,
}

impl SideSpan {
    fn kinds(&self) -> Vec<u8> {
        self.seq.iter().map(|&(_, r)| r).collect()
    }
}

fn kind_rank(k: TraceKind) -> u8 {
    match k {
        TraceKind::Enqueue => 0,
        TraceKind::Dispatch => 1,
        _ => 2, // NodeComplete (the only other kind collected)
    }
}

fn side_spans(events: &[TraceEvent]) -> BTreeMap<u64, SideSpan> {
    let mut out: BTreeMap<u64, SideSpan> = BTreeMap::new();
    for e in events {
        if e.name_hash == 0 || e.job == NO_JOB {
            continue;
        }
        match e.kind {
            TraceKind::Enqueue
            | TraceKind::Dispatch
            | TraceKind::NodeComplete => {
                let s = out.entry(e.name_hash).or_default();
                s.seq.push((e.ts_ns, kind_rank(e.kind)));
                match e.kind {
                    TraceKind::Enqueue => {
                        s.enqueue_ns.get_or_insert(e.ts_ns);
                    }
                    TraceKind::NodeComplete => {
                        s.complete_ns = Some(e.ts_ns);
                    }
                    _ => {}
                }
            }
            _ => {}
        }
    }
    for s in out.values_mut() {
        s.seq.sort_unstable();
    }
    out
}

fn stream_makespan(spans: &BTreeMap<u64, SideSpan>) -> u64 {
    let start = spans.values().filter_map(|s| s.enqueue_ns).min();
    let end = spans.values().filter_map(|s| s.complete_ns).max();
    match (start, end) {
        (Some(a), Some(b)) => b.saturating_sub(a),
        _ => 0,
    }
}

/// Diff a DES replay's stream (`modelled`) against the real run's
/// stream (`measured`). Both must be drained, timestamp-sorted streams
/// of the *same* workload; node identity is `name_hash` (job ids differ
/// between the engines by construction).
pub fn diff_traces(
    modelled: &[TraceEvent],
    measured: &[TraceEvent],
) -> TraceDiff {
    let m = side_spans(modelled);
    let r = side_spans(measured);
    let mut diff = TraceDiff {
        modelled_makespan_ns: stream_makespan(&m),
        measured_makespan_ns: stream_makespan(&r),
        ..TraceDiff::default()
    };
    let span = |s: &SideSpan| -> Option<u64> {
        match (s.enqueue_ns, s.complete_ns) {
            (Some(e), Some(c)) => Some(c.saturating_sub(e)),
            _ => None,
        }
    };
    let hashes: std::collections::BTreeSet<u64> =
        m.keys().chain(r.keys()).copied().collect();
    for h in hashes {
        let (ms, rs) = (m.get(&h), r.get(&h));
        let modelled_ns = ms.and_then(span);
        let measured_ns = rs.and_then(span);
        let ordering_mismatch = match (ms, rs) {
            (Some(a), Some(b)) => a.kinds() != b.kinds(),
            _ => true,
        };
        if ordering_mismatch {
            diff.ordering_skew += 1;
        }
        diff.nodes.push(NodeSkew {
            name_hash: h,
            label: label(h),
            modelled_ns,
            measured_ns,
            skew_ns: modelled_ns.unwrap_or(0) as i64
                - measured_ns.unwrap_or(0) as i64,
            ordering_mismatch,
        });
    }
    diff.nodes
        .sort_by(|a, b| b.skew_ns.abs().cmp(&a.skew_ns.abs()));
    diff
}

impl TraceDiff {
    /// `modelled - measured` end-to-end, ns.
    pub fn makespan_error_ns(&self) -> i64 {
        self.modelled_makespan_ns as i64 - self.measured_makespan_ns as i64
    }

    /// Human-readable digest: headline plus the top skew contributors.
    pub fn render(&self, top: usize) -> String {
        use std::fmt::Write as _;
        let ms = |ns: f64| ns / 1e6;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "real-vs-DES diff: {} node(s), ordering skew {}, makespan \
             modelled {:.3} ms / measured {:.3} ms (error {:+.3} ms)",
            self.nodes.len(),
            self.ordering_skew,
            ms(self.modelled_makespan_ns as f64),
            ms(self.measured_makespan_ns as f64),
            ms(self.makespan_error_ns() as f64)
        );
        for n in self.nodes.iter().take(top) {
            let fmt_side = |v: Option<u64>| match v {
                Some(ns) => format!("{:.3}ms", ms(ns as f64)),
                None => "absent".to_string(),
            };
            let _ = writeln!(
                out,
                "  {:<16} modelled={:<10} measured={:<10} \
                 skew={:+.3}ms{}",
                n.label,
                fmt_side(n.modelled_ns),
                fmt_side(n.measured_ns),
                ms(n.skew_ns as f64),
                if n.ordering_mismatch { " ORDER" } else { "" }
            );
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let node = |n: &NodeSkew| {
            let side = |v: Option<u64>| match v {
                Some(ns) => Json::Num(ns as f64),
                None => Json::Null,
            };
            Json::Obj(
                [
                    ("name".to_string(), Json::Str(n.label.clone())),
                    ("modelled_ns".to_string(), side(n.modelled_ns)),
                    ("measured_ns".to_string(), side(n.measured_ns)),
                    (
                        "skew_ns".to_string(),
                        Json::Num(n.skew_ns as f64),
                    ),
                    (
                        "ordering_mismatch".to_string(),
                        Json::Bool(n.ordering_mismatch),
                    ),
                ]
                .into_iter()
                .collect(),
            )
        };
        Json::Obj(
            [
                (
                    "ordering_skew".to_string(),
                    Json::Num(self.ordering_skew as f64),
                ),
                (
                    "modelled_makespan_ns".to_string(),
                    Json::Num(self.modelled_makespan_ns as f64),
                ),
                (
                    "measured_makespan_ns".to_string(),
                    Json::Num(self.measured_makespan_ns as f64),
                ),
                (
                    "nodes".to_string(),
                    Json::Arr(self.nodes.iter().map(node).collect()),
                ),
            ]
            .into_iter()
            .collect(),
        )
    }
}

/// A named bundle of JSON sections written as `BENCH_<name>.json` —
/// the machine-readable perf record of one CLI invocation. `schema`
/// and `name` are reserved top-level keys; every section lands beside
/// them.
#[derive(Debug, Clone)]
pub struct BenchReport {
    name: String,
    sections: BTreeMap<String, Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), sections: BTreeMap::new() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Add (or replace) one section. `schema` / `name` are reserved.
    pub fn section(&mut self, key: &str, value: Json) {
        debug_assert!(
            key != "schema" && key != "name",
            "reserved report key: {key}"
        );
        self.sections.insert(key.to_string(), value);
    }

    pub fn has_section(&self, key: &str) -> bool {
        self.sections.contains_key(key)
    }

    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = self.sections.clone();
        obj.insert(
            "schema".to_string(),
            Json::Str(BENCH_SCHEMA.to_string()),
        );
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        Json::Obj(obj)
    }

    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.name)
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path written.
    pub fn write_to(&self, dir: &Path) -> io::Result<PathBuf> {
        let path = dir.join(self.file_name());
        fs::write(&path, json::to_string(&self.to_json()))?;
        Ok(path)
    }
}

/// Re-derive per-node service seconds from an exported Chrome trace
/// document: paired `B`/`E` slices named `run <label>` are summed per
/// label (`ts` is microseconds). The inverse of
/// [`crate::obs::export::chrome_trace_json`]'s task slices, and the
/// file-based entry point of trace calibration.
pub fn service_times_from_chrome_trace(
    doc: &Json,
) -> BTreeMap<String, f64> {
    let mut out: BTreeMap<String, f64> = BTreeMap::new();
    let events = match doc.get("traceEvents").and_then(|v| v.as_arr()) {
        Some(evs) => evs,
        None => return out,
    };
    // per-tid stack of open B slices: (label, ts_us)
    let mut open: BTreeMap<i64, Vec<(String, f64)>> = BTreeMap::new();
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        let tid = e
            .get("tid")
            .and_then(|t| t.as_f64())
            .map(|t| t as i64)
            .unwrap_or(-1);
        match ph {
            "B" => {
                let name =
                    e.get("name").and_then(|n| n.as_str()).unwrap_or("");
                if let Some(label) = name.strip_prefix("run ") {
                    let ts = e
                        .get("ts")
                        .and_then(|t| t.as_f64())
                        .unwrap_or(0.0);
                    open.entry(tid)
                        .or_default()
                        .push((label.to_string(), ts));
                }
            }
            "E" => {
                if let Some((label, ts0)) =
                    open.entry(tid).or_default().pop()
                {
                    let ts = e
                        .get("ts")
                        .and_then(|t| t.as_f64())
                        .unwrap_or(ts0);
                    *out.entry(label).or_insert(0.0) +=
                        (ts - ts0).max(0.0) * 1e-6;
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::chrome_trace_json;
    use crate::obs::trace::fnv1a;

    fn ev(
        ts_ns: u64,
        worker: u32,
        kind: TraceKind,
        job: u64,
        name: &str,
    ) -> TraceEvent {
        TraceEvent {
            ts_ns,
            worker,
            kind,
            job,
            name_hash: fnv1a(name),
            tag_hash: 0,
        }
    }

    fn node_stream(scale: u64) -> Vec<TraceEvent> {
        vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(10 * scale, 0, TraceKind::Dispatch, 0, "a"),
            ev(100 * scale, 9, TraceKind::NodeComplete, 0, "a"),
            ev(100 * scale, 9, TraceKind::Enqueue, 1, "b"),
            ev(110 * scale, 1, TraceKind::Dispatch, 1, "b"),
            ev(300 * scale, 9, TraceKind::NodeComplete, 1, "b"),
        ]
    }

    #[test]
    fn identical_streams_diff_to_zero_skew() {
        let s = node_stream(1);
        let d = diff_traces(&s, &s);
        assert_eq!(d.ordering_skew, 0);
        assert_eq!(d.makespan_error_ns(), 0);
        assert!(d.nodes.iter().all(|n| n.skew_ns == 0));
        assert!(d.nodes.iter().all(|n| !n.ordering_mismatch));
    }

    #[test]
    fn skew_is_ranked_and_ordering_mismatches_counted() {
        let modelled = node_stream(1);
        // measured: node b takes 3x longer, and an extra node c appears
        // only on the measured side
        let mut measured = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(10, 0, TraceKind::Dispatch, 0, "a"),
            ev(100, 9, TraceKind::NodeComplete, 0, "a"),
            ev(100, 9, TraceKind::Enqueue, 1, "b"),
            ev(110, 1, TraceKind::Dispatch, 1, "b"),
            ev(700, 9, TraceKind::NodeComplete, 1, "b"),
        ];
        measured.push(ev(700, 9, TraceKind::Enqueue, 2, "c"));
        measured.push(ev(750, 9, TraceKind::NodeComplete, 2, "c"));
        let d = diff_traces(&modelled, &measured);
        assert_eq!(d.ordering_skew, 1, "only the one-sided node c");
        assert_eq!(
            d.nodes[0].name_hash,
            fnv1a("b"),
            "largest |skew| first"
        );
        assert_eq!(d.nodes[0].skew_ns, 200 - 600);
        let c = d
            .nodes
            .iter()
            .find(|n| n.name_hash == fnv1a("c"))
            .expect("c");
        assert!(c.ordering_mismatch);
        assert_eq!(c.modelled_ns, None);
        assert!(d.makespan_error_ns() < 0);
        let rendered = d.render(10);
        assert!(rendered.contains("ordering skew 1"));
        assert!(rendered.contains("ORDER"));
        let j = d.to_json();
        assert_eq!(
            j.get("ordering_skew").and_then(|v| v.as_f64()),
            Some(1.0)
        );
    }

    #[test]
    fn reordered_kinds_are_ordering_skew() {
        let a = node_stream(1);
        let mut b = node_stream(1);
        // swap node a's Enqueue/Dispatch kinds in place
        b[0].kind = TraceKind::Dispatch;
        b[1].kind = TraceKind::Enqueue;
        let d = diff_traces(&a, &b);
        assert_eq!(d.ordering_skew, 1);
    }

    #[test]
    fn same_timestamp_tie_order_is_not_skew() {
        // a DES burst stamps Enqueue and first Dispatch at the same
        // virtual instant; lane merge order on the tie must not read
        // as ordering skew
        let a = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(0, 0, TraceKind::Dispatch, 0, "a"),
            ev(100, 9, TraceKind::NodeComplete, 0, "a"),
        ];
        let b = vec![
            ev(0, 0, TraceKind::Dispatch, 0, "a"),
            ev(0, 9, TraceKind::Enqueue, 0, "a"),
            ev(100, 9, TraceKind::NodeComplete, 0, "a"),
        ];
        let d = diff_traces(&a, &b);
        assert_eq!(d.ordering_skew, 0);
        assert!(d.nodes.iter().all(|n| !n.ordering_mismatch));
    }

    #[test]
    fn bench_report_schema_and_write() {
        let mut rep = BenchReport::new("unit");
        rep.section("figures", Json::Arr(vec![]));
        rep.section(
            "obs_summary",
            Json::Obj(BTreeMap::from([(
                "events".to_string(),
                Json::Num(3.0),
            )])),
        );
        assert!(rep.has_section("figures"));
        let j = rep.to_json();
        assert_eq!(
            j.get("schema").and_then(|v| v.as_str()),
            Some(BENCH_SCHEMA)
        );
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("unit"));
        assert!(j.get("figures").is_some());
        assert_eq!(rep.file_name(), "BENCH_unit.json");
        let dir = std::env::temp_dir()
            .join(format!("bench-report-test-{}", std::process::id()));
        fs::create_dir_all(&dir).expect("temp dir");
        let path = rep.write_to(&dir).expect("write");
        let round = json::parse(
            &fs::read_to_string(&path).expect("read back"),
        )
        .expect("valid json");
        assert_eq!(
            round.get("schema").and_then(|v| v.as_str()),
            Some(BENCH_SCHEMA)
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn chrome_trace_service_times_round_trip() {
        let events = vec![
            ev(0, 9, TraceKind::Enqueue, 0, "node-a"),
            ev(1_000, 0, TraceKind::Dispatch, 0, "node-a"),
            ev(1_000, 0, TraceKind::TaskStart, 0, "node-a"),
            ev(2_000_000, 0, TraceKind::TaskEnd, 0, "node-a"),
            ev(2_000_000, 1, TraceKind::TaskStart, 0, "node-a"),
            ev(3_000_000, 1, TraceKind::TaskEnd, 0, "node-a"),
            ev(3_000_000, 9, TraceKind::NodeComplete, 0, "node-a"),
        ];
        let doc = chrome_trace_json(&events);
        let times = service_times_from_chrome_trace(&doc);
        // labels are the export's: hex of the un-interned name hash
        assert_eq!(times.len(), 1);
        let (_, secs) = times.iter().next().expect("one label");
        // 1.999 ms + 1 ms of B/E slices
        assert!(
            (secs - 2.999e-3).abs() < 1e-9,
            "summed service {secs}"
        );
    }
}
