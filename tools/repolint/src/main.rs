//! repolint — std-only static checks for this repository's concurrency
//! and layering invariants. `tools/repolint/README.md` has the rule
//! catalogue and rationale; `rust/src/sched/ranks.rs` declares the lock
//! order that this tool cross-checks syntactically (the same order the
//! `OrderedMutex` wrappers enforce dynamically in debug builds).
//!
//! The checker is line/token based, not a full parser: it first strips
//! comments and string/char literals (structure preserving), then
//! pattern-matches on the stripped "code view". That makes it heuristic
//! by design — the rules are tuned so the current tree is clean and
//! every seeded violation class is caught (see the unit tests).
//! `prototype.py` next to this file is a 1:1 Python mirror runnable
//! without a Rust toolchain; keep the two in sync.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files audited for (and therefore allowed to contain) `unsafe` and
/// `transmute`. Everything else must stay safe Rust.
const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/util/disjoint.rs",
    "rust/src/sched/executor.rs",
    "rust/src/sched/graph.rs",
    "rust/src/sched/session.rs",
];

/// Receiver field name -> rank const declared in
/// `rust/src/sched/ranks.rs`. A `.lock()` whose receiver's last path
/// segment is not in this table is ignored (unknown, unranked lock).
const RANK_FIELDS: &[(&str, &str)] = &[
    ("progress", "GRAPH_PROGRESS"),
    ("jobs", "GRAPH_JOBS"),
    ("pending", "SCOPE_PENDING"),
    ("lease", "ELASTIC_LEASE"),
    ("queue", "RUN_QUEUE"),
    ("body", "JOB_BODY"),
    ("panic", "JOB_PANIC"),
    ("stats", "JOB_STATS"),
    ("done", "JOB_DONE"),
    ("on_done", "JOB_ON_DONE"),
];

/// Functions on the worker dispatch path. A panic in one of these
/// unwinds a worker thread (and can poison the run queue for every
/// later submitter), so `.unwrap()` / `.expect(` are banned there
/// outside the poisoned-lock idiom (`.lock().unwrap()` /
/// `.wait(g).unwrap()`). The list is exhaustive on purpose: a missing
/// function is itself an error, so renames keep the lint honest.
const DISPATCH_PATH_FNS: &[(&str, &[&str])] = &[
    (
        "rust/src/sched/executor.rs",
        &[
            "worker_main",
            "pick_job",
            "run_job_stint",
            "flush_stats",
            "complete_items",
            "finalize",
            "make_report",
            "publish_completion",
            "abort_job",
            "drain_source",
            "cancel_job",
            "enqueue_raw",
        ],
    ),
    (
        "rust/src/sched/graph.rs",
        &["dispatch", "node_done", "record_done", "cancel_dependents"],
    ),
];

/// Crate-internal roots `sim` may import from (plus itself): the DES
/// consumes the scheduler's public surface (and since PR 8 emits the
/// shared `obs::trace` event stream), never `bench`/`apps`.
const SIM_ALLOWED: &[&str] = &["sched", "config", "topology", "util", "sim", "obs"];

/// Crate-internal roots `serve` may import from (plus itself): the
/// serving loop drives the scheduler's session surface and shares the
/// arrival/reservoir machinery with its DES mirror (`sim::serve`), but
/// never reaches into `bench`/`apps`/`vee`. The reverse direction is
/// also closed: only `bench/` and `main.rs` may import `crate::serve`
/// (`layering-serve-consumers`), so the serving layer stays a leaf.
const SERVE_ALLOWED: &[&str] =
    &["sched", "sim", "config", "topology", "util", "serve", "obs"];

/// Crate-internal roots `obs` may import from (plus itself). The trace
/// and metrics layer is recorded into from the scheduler's hottest
/// paths, so it must stay a near-leaf: shared utilities, topology, and
/// the config knob that gates it — never `sched`/`sim`/`serve` (which
/// all import *it*) and never `bench`/`apps`.
const OBS_ALLOWED: &[&str] = &["util", "topology", "config", "obs"];

/// Crate-internal roots `sched/elastic.rs` may import (plus `sched`
/// itself). The lease overlay is consulted from the dispatch hot path,
/// so it must stay a near-leaf: never `obs`/`sim`/`serve` (width
/// changes are published by the executor/session, not by the overlay)
/// and never `bench`/`apps`.
const ELASTIC_ALLOWED: &[&str] = &["sched", "util", "topology", "config"];

/// The obs *analysis* modules (critical-path attribution, trace
/// diffing, bench reports) consume replay outcomes, so they may
/// additionally read `sim` public types — but never `sched` internals:
/// the recorder/analysis split keeps the hot-path modules a strict
/// near-leaf while the offline consumers see the DES surface.
const OBS_ANALYSIS_FILES: &[&str] =
    &["rust/src/obs/analyze.rs", "rust/src/obs/report.rs"];
const OBS_ANALYSIS_ALLOWED: &[&str] =
    &["util", "topology", "config", "obs", "sim"];

/// How many lines above an `unsafe`/`transmute` the justifying comment
/// may sit. Multi-line `let` bindings put statement fragments between
/// the comment block and the keyword, so strict adjacency is too rigid.
const COMMENT_WINDOW: usize = 14;

struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

/// Per-line views of a source file: `code` has comments and
/// string/char-literal *bodies* blanked out (structure preserved, and
/// non-ASCII replaced by spaces so byte offsets equal char offsets);
/// `comment` collects the comment text of each line.
struct Stripped {
    code: Vec<String>,
    comment: Vec<String>,
}

fn strip(src: &str) -> Stripped {
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut block_depth = 0usize;
    let mut raw_hashes: Option<usize> = None;
    let mut in_str = false;
    for line in src.lines() {
        let b: Vec<char> = line.chars().collect();
        let n = b.len();
        let mut cl = String::new();
        let mut cm = String::new();
        let mut i = 0;
        while i < n {
            let c = b[i];
            if block_depth > 0 {
                if c == '*' && b.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    cl.push_str("  ");
                    i += 2;
                } else if c == '/' && b.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    cl.push_str("  ");
                    i += 2;
                } else {
                    cm.push(c);
                    cl.push(' ');
                    i += 1;
                }
                continue;
            }
            if let Some(h) = raw_hashes {
                let closes = c == '"'
                    && i + h < n
                    && b[i + 1..i + 1 + h].iter().all(|&x| x == '#');
                if closes {
                    cl.push('"');
                    for _ in 0..h {
                        cl.push(' ');
                    }
                    i += 1 + h;
                    raw_hashes = None;
                } else {
                    cl.push(' ');
                    i += 1;
                }
                continue;
            }
            if in_str {
                if c == '\\' && i + 1 < n {
                    cl.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    in_str = false;
                    cl.push('"');
                    i += 1;
                } else {
                    cl.push(' ');
                    i += 1;
                }
                continue;
            }
            if c == '/' && b.get(i + 1) == Some(&'/') {
                for &x in &b[i..] {
                    cm.push(x);
                }
                break;
            }
            if c == '/' && b.get(i + 1) == Some(&'*') {
                block_depth = 1;
                cl.push_str("  ");
                i += 2;
                continue;
            }
            if c == '"' {
                in_str = true;
                cl.push('"');
                i += 1;
                continue;
            }
            let prev_word = i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == '_');
            if c == 'r' && !prev_word {
                let mut j = i + 1;
                let mut h = 0;
                while j < n && b[j] == '#' {
                    h += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    raw_hashes = Some(h);
                    for _ in i..=j {
                        cl.push(' ');
                    }
                    i = j + 1;
                    continue;
                }
            }
            if c == '\'' {
                if b.get(i + 1) == Some(&'\\') {
                    // Escaped char literal: '\n', '\'', '\u{1F600}'.
                    let mut j = i + 2;
                    if j < n {
                        j += 1;
                    }
                    while j < n && b[j] != '\'' {
                        j += 1;
                    }
                    cl.push('\'');
                    for _ in 0..j.saturating_sub(i + 1) {
                        cl.push(' ');
                    }
                    cl.push('\'');
                    i = j + 1;
                    continue;
                }
                // 'x' is a char literal; 'static / 'a / 'outer are not.
                if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                    cl.push_str("' '");
                    i += 3;
                    continue;
                }
                cl.push('\'');
                i += 1;
                continue;
            }
            cl.push(if c.is_ascii() { c } else { ' ' });
            i += 1;
        }
        code.push(cl);
        comment.push(cm);
    }
    Stripped { code, comment }
}

fn is_word(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of word-boundary-delimited occurrences of `word`.
fn find_word(line: &str, word: &str) -> Vec<usize> {
    let lb = line.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let end = at + word.len();
        let before_ok = at == 0 || !is_word(lb[at - 1]);
        let after_ok = end >= lb.len() || !is_word(lb[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = end;
    }
    out
}

/// Byte offsets of every occurrence of literal substring `pat`.
fn find_all(line: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find(pat) {
        out.push(from + p);
        from += p + pat.len();
    }
    out
}

/// Identifier starting at byte offset `at`.
fn ident_at(line: &str, at: usize) -> &str {
    let b = line.as_bytes();
    let mut e = at;
    while e < b.len() && is_word(b[e]) {
        e += 1;
    }
    &line[at..e]
}

/// Last identifier of the receiver chain before a `.lock()` at byte
/// offset `lock_pos`, skipping one trailing `[...]` index — so
/// `job.stats[lw].lock()` yields `stats`, `queues[q].lock()` `queues`.
fn recv_ident(line: &str, lock_pos: usize) -> &str {
    let b = line.as_bytes();
    let mut i = lock_pos;
    if i > 0 && b[i - 1] == b']' {
        let mut depth = 1;
        i -= 1;
        while i > 0 && depth > 0 {
            i -= 1;
            match b[i] {
                b']' => depth += 1,
                b'[' => depth -= 1,
                _ => {}
            }
        }
    }
    let end = i;
    while i > 0 && is_word(b[i - 1]) {
        i -= 1;
    }
    &line[i..end]
}

/// `let [mut] NAME = <recv>.lock().unwrap();` -> Some(NAME). Only this
/// exact shape binds a tracked guard; every other `.lock()` is treated
/// as transient (checked against held ranks but not recorded).
fn guard_let_name(line: &str) -> Option<&str> {
    let t = line.trim();
    if !t.ends_with(".lock().unwrap();") {
        return None;
    }
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let b = rest.as_bytes();
    let mut e = 0;
    while e < b.len() && is_word(b[e]) {
        e += 1;
    }
    if e == 0 {
        return None;
    }
    if !rest[e..].trim_start().starts_with('=') {
        return None;
    }
    Some(&rest[..e])
}

/// `drop(NAME)` with a plain identifier -> Some(NAME).
fn drop_name(line: &str) -> Option<String> {
    for at in find_word(line, "drop") {
        let rest = line[at + 4..].trim_start();
        let Some(inner) = rest.strip_prefix('(') else {
            continue;
        };
        let Some(close) = inner.find(')') else {
            continue;
        };
        let name = inner[..close].trim();
        if !name.is_empty() && name.bytes().all(is_word) {
            return Some(name.to_string());
        }
    }
    None
}

/// Does `before` end with a `.wait(...)` call (no nested parens)?
fn ends_with_wait_call(before: &str) -> bool {
    let Some(stripped) = before.strip_suffix(')') else {
        return false;
    };
    let Some(open) = stripped.rfind('(') else {
        return false;
    };
    stripped[..open].ends_with(".wait")
}

/// Last line of the brace-delimited item opening at/after `start`.
fn item_end(code: &[String], start: usize) -> usize {
    let mut depth = 0i32;
    let mut started = false;
    let mut j = start;
    while j < code.len() {
        for c in code[j].bytes() {
            match c {
                b'{' => {
                    depth += 1;
                    started = true;
                }
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return j;
        }
        j += 1;
    }
    code.len().saturating_sub(1)
}

/// Line spans of `#[cfg(test)]` items (attribute line to closing brace).
fn test_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < code.len() {
        if code[i].trim_start().starts_with("#[cfg(test)") {
            let j = item_end(code, i);
            spans.push((i, j));
            i = j + 1;
        } else {
            i += 1;
        }
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], lnum: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= lnum && lnum <= b)
}

/// Body span of `fn name` (definition line to its closing brace).
fn fn_span(code: &[String], name: &str) -> Option<(usize, usize)> {
    for (i, line) in code.iter().enumerate() {
        let hit = find_word(line, "fn").iter().any(|&p| {
            let rest = line[p + 2..].trim_start();
            rest.starts_with(name) && !is_word(*rest.as_bytes().get(name.len()).unwrap_or(&b' '))
        });
        if hit {
            return Some((i, item_end(code, i)));
        }
    }
    None
}

/// Any comment line within `COMMENT_WINDOW` lines above `lnum`
/// containing `needle`.
fn comment_above(comment: &[String], lnum: usize, needle: &str) -> bool {
    let lo = lnum.saturating_sub(COMMENT_WINDOW);
    comment[lo..lnum].iter().any(|c| c.contains(needle))
}

/// Parse `pub const NAME: LockRank = LockRank::new(N, ...)` pairs out
/// of `ranks.rs` source, in declaration order.
fn parse_ranks(src: &str) -> Vec<(String, u32)> {
    let s = strip(src);
    let mut out = Vec::new();
    for line in &s.code {
        let Some(cpos) = line.find("const ") else {
            continue;
        };
        let Some(npos) = line.find("LockRank::new(") else {
            continue;
        };
        let name = ident_at(line, cpos + 6);
        let digits: String = line[npos + 14..]
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if let (false, Ok(v)) = (name.is_empty(), digits.parse::<u32>()) {
            out.push((name.to_string(), v));
        }
    }
    out
}

fn lint_file(rel: &str, src: &str, ranks: &[(String, u32)], out: &mut Vec<Finding>) {
    let s = strip(src);
    let tspans = test_regions(&s.code);
    let allowlisted = UNSAFE_ALLOWLIST.contains(&rel);

    // -- unsafe / transmute: allowlist + justifying comment --
    for (i, line) in s.code.iter().enumerate() {
        if !find_word(line, "unsafe").is_empty() {
            if !allowlisted {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "unsafe-allowlist",
                    msg: "`unsafe` outside the audited allowlist".to_string(),
                });
            } else if !comment_above(&s.comment, i, "SAFETY:")
                && !comment_above(&s.comment, i, "SOUNDNESS:")
            {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "unsafe-comment",
                    msg: "`unsafe` without a SAFETY:/SOUNDNESS: comment".to_string(),
                });
            }
        }
        if !find_word(line, "transmute").is_empty() {
            if !allowlisted {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "transmute-allowlist",
                    msg: "`transmute` outside the audited allowlist".to_string(),
                });
            } else if !comment_above(&s.comment, i, "SOUNDNESS:") {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "transmute-comment",
                    msg: "`transmute` without a SOUNDNESS: comment".to_string(),
                });
            }
        }
    }

    // -- lock-rank ordering (whole tree; unknown receivers ignored) --
    let rank_of = |ident: &str| -> Option<(&'static str, u32)> {
        let (_, cname) = RANK_FIELDS.iter().find(|(f, _)| *f == ident)?;
        let (_, v) = ranks.iter().find(|(n, _)| n == cname)?;
        Some((*cname, *v))
    };
    let mut depth = 0i32;
    let mut held: Vec<(u32, String, i32)> = Vec::new();
    for (i, line) in s.code.iter().enumerate() {
        if !find_word(line, "fn").is_empty() && depth <= 1 {
            held.clear();
        }
        if let Some(name) = drop_name(line) {
            held.retain(|h| h.1 != name);
        }
        for lp in find_all(line, ".lock()") {
            let ident = recv_ident(line, lp);
            let Some((cname, rank)) = rank_of(ident) else {
                continue;
            };
            for (hrank, hname, _) in &held {
                if rank <= *hrank {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "lock-rank",
                        msg: format!(
                            "acquiring {cname}({rank}) via `{ident}` while \
                             holding `{hname}` rank {hrank} inverts the \
                             declared order"
                        ),
                    });
                }
            }
            if let Some(g) = guard_let_name(line) {
                held.push((rank, g.to_string(), depth));
            }
        }
        let opens = line.bytes().filter(|&c| c == b'{').count() as i32;
        let closes = line.bytes().filter(|&c| c == b'}').count() as i32;
        depth += opens - closes;
        held.retain(|h| h.2 <= depth);
    }

    // -- Condvar::wait must sit inside a predicate loop --
    // (ordered.rs is the wrapper implementation, hence exempt.)
    if rel != "rust/src/util/ordered.rs" {
        let mut stack: Vec<&'static str> = Vec::new();
        for (i, line) in s.code.iter().enumerate() {
            let has_arg_wait = find_all(line, ".wait(").iter().any(|&p| {
                matches!(
                    line[p + 6..].trim_start().bytes().next(),
                    Some(c) if c != b')'
                )
            });
            if has_arg_wait {
                let mut ok = false;
                for kw in stack.iter().rev() {
                    match *kw {
                        "fn" => break,
                        "while" | "loop" => {
                            ok = true;
                            break;
                        }
                        _ => {}
                    }
                }
                if !ok {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "condvar-predicate",
                        msg: "`Condvar::wait` outside a predicate loop".to_string(),
                    });
                }
            }
            let t = line.trim();
            let mut first = true;
            for c in line.bytes() {
                match c {
                    b'{' => {
                        let kw = if first {
                            first = false;
                            if !find_word(t, "fn").is_empty() {
                                "fn"
                            } else if !find_word(t, "while").is_empty() {
                                "while"
                            } else if !find_word(t, "loop").is_empty() {
                                "loop"
                            } else {
                                "block"
                            }
                        } else {
                            "block"
                        };
                        stack.push(kw);
                    }
                    b'}' => {
                        stack.pop();
                    }
                    _ => {}
                }
            }
        }
    }

    // -- module layering --
    if rel.starts_with("rust/src/util/") {
        for (i, line) in s.code.iter().enumerate() {
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if !seg.is_empty() && seg != "util" {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-util",
                        msg: format!("util must not import crate::{seg}"),
                    });
                }
            }
        }
    }
    if rel.starts_with("rust/src/sched/") {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if seg == "bench" || seg == "apps" {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-sched",
                        msg: format!("sched must not import crate::{seg}"),
                    });
                }
            }
        }
    }
    if rel.starts_with("rust/src/sim/") {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if !seg.is_empty() && !SIM_ALLOWED.contains(&seg) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-sim",
                        msg: format!(
                            "sim may only use {SIM_ALLOWED:?}, found crate::{seg}"
                        ),
                    });
                }
            }
        }
    }

    if rel.starts_with("rust/src/serve/") {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if !seg.is_empty() && !SERVE_ALLOWED.contains(&seg) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-serve",
                        msg: format!(
                            "serve may only use {SERVE_ALLOWED:?}, found crate::{seg}"
                        ),
                    });
                }
            }
        }
    }
    let serve_consumer = rel.starts_with("rust/src/serve/")
        || rel.starts_with("rust/src/bench/")
        || rel == "rust/src/main.rs";
    if rel.starts_with("rust/src/") && !serve_consumer {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                if ident_at(line, p + 7) == "serve" {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-serve-consumers",
                        msg: "only bench/ and main.rs may import crate::serve"
                            .to_string(),
                    });
                }
            }
        }
    }

    if rel.starts_with("rust/src/obs/") {
        let analysis = OBS_ANALYSIS_FILES.contains(&rel);
        let allowed: &[&str] =
            if analysis { OBS_ANALYSIS_ALLOWED } else { OBS_ALLOWED };
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if !seg.is_empty() && !allowed.contains(&seg) {
                    let msg = if analysis {
                        format!(
                            "obs analysis modules may only use \
                             {OBS_ANALYSIS_ALLOWED:?} (sim public types, \
                             never sched internals), found crate::{seg}"
                        )
                    } else {
                        format!(
                            "obs may only use {OBS_ALLOWED:?}, found crate::{seg}"
                        )
                    };
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-obs",
                        msg,
                    });
                }
            }
        }
    }

    // -- elastic overlay layering --
    // The lease overlay itself is a near-leaf (the dispatch path reads
    // it between queue-lock acquisitions), and its module path is API
    // only for the scheduler, the DES mirror and the serving loop:
    // everything else goes through the `crate::sched` re-exports.
    if rel == "rust/src/sched/elastic.rs" {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            for p in find_all(line, "crate::") {
                let seg = ident_at(line, p + 7);
                if !seg.is_empty() && !ELASTIC_ALLOWED.contains(&seg) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "layering-elastic",
                        msg: format!(
                            "sched/elastic.rs may only use \
                             {ELASTIC_ALLOWED:?}, found crate::{seg}"
                        ),
                    });
                }
            }
        }
    }
    let elastic_consumer = rel.starts_with("rust/src/sched/")
        || rel.starts_with("rust/src/sim/")
        || rel.starts_with("rust/src/serve/");
    if rel.starts_with("rust/src/") && !elastic_consumer {
        for (i, line) in s.code.iter().enumerate() {
            if in_spans(&tspans, i) {
                continue;
            }
            if !find_all(line, "sched::elastic").is_empty() {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "layering-elastic",
                    msg: "only sched/, sim/ and serve/ may name \
                          sched::elastic directly (use the crate::sched \
                          re-exports)"
                        .to_string(),
                });
            }
        }
    }

    // -- no unwrap/expect on the worker dispatch path --
    for (file, fns) in DISPATCH_PATH_FNS {
        if *file != rel {
            continue;
        }
        for fname in *fns {
            let Some((a, b)) = fn_span(&s.code, fname) else {
                out.push(Finding {
                    file: rel.to_string(),
                    line: 1,
                    rule: "dispatch-unwrap",
                    msg: format!(
                        "dispatch-path fn `{fname}` not found (update repolint)"
                    ),
                });
                continue;
            };
            for i in a..=b {
                let line = &s.code[i];
                for p in find_all(line, ".unwrap()") {
                    let before = line[..p].trim_end();
                    if before.ends_with(".lock()") || ends_with_wait_call(before)
                    {
                        continue;
                    }
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "dispatch-unwrap",
                        msg: format!(
                            "`.unwrap()` in dispatch-path fn `{fname}` \
                             outside the poisoned-lock idiom"
                        ),
                    });
                }
                if !find_all(line, ".expect(").is_empty() {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "dispatch-unwrap",
                        msg: format!(
                            "`.expect(...)` in dispatch-path fn `{fname}`"
                        ),
                    });
                }
            }

            // -- obs recording on the dispatch path is lock-free --
            // A trace/metrics call must never acquire a lock: the
            // statement containing a record call (hit line extended
            // forward to the terminating `;`) may not contain
            // `.lock(`. Holding a lock *around* a record is fine —
            // the obs API itself acquires nothing.
            let mut i = a;
            while i <= b {
                let line = &s.code[i];
                let hit = line.contains("obs::")
                    || line.contains("trace::record")
                    || line.contains("record_trace");
                if !hit {
                    i += 1;
                    continue;
                }
                let mut j = i;
                while j < b && !s.code[j].trim_end().ends_with(';') {
                    j += 1;
                }
                if (i..=j).any(|k| s.code[k].contains(".lock(")) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: "obs-lockfree",
                        msg: format!(
                            "obs record in dispatch-path fn `{fname}` \
                             shares a statement with `.lock(` -- trace \
                             and metrics calls must stay lock-free"
                        ),
                    });
                }
                i = j + 1;
            }
        }
    }
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            let name = entry.file_name();
            if name == "vendor" || name == "target" {
                continue;
            }
            collect(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn main() -> ExitCode {
    // tools/repolint -> tools -> repo root. The lint always runs via
    // `cargo run -p repolint` on the machine that compiled it, so the
    // compile-time manifest path is the right anchor.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("manifest dir has a grandparent")
        .to_path_buf();
    let ranks_src = match fs::read_to_string(root.join("rust/src/sched/ranks.rs")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("repolint: cannot read rust/src/sched/ranks.rs: {e}");
            return ExitCode::FAILURE;
        }
    };
    let ranks = parse_ranks(&ranks_src);
    for (_, cname) in RANK_FIELDS {
        if !ranks.iter().any(|(n, _)| n == cname) {
            eprintln!("repolint: rank const `{cname}` missing from ranks.rs");
            return ExitCode::FAILURE;
        }
    }

    let mut files = Vec::new();
    for top in [
        "rust/src",
        "rust/tests",
        "rust/benches",
        "examples",
        "tools/repolint/src",
    ] {
        collect(&root.join(top), &mut files);
    }
    files.sort();

    let mut findings = Vec::new();
    for p in &files {
        let rel = p
            .strip_prefix(&root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        match fs::read_to_string(p) {
            Ok(src) => lint_file(&rel, &src, &ranks, &mut findings),
            Err(e) => eprintln!("repolint: skipping {rel}: {e}"),
        }
    }

    for f in &findings {
        println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
    }
    if findings.is_empty() {
        println!("repolint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        println!("repolint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_ranks() -> Vec<(String, u32)> {
        RANK_FIELDS
            .iter()
            .enumerate()
            .map(|(i, (_, c))| (c.to_string(), (i as u32 + 1) * 10))
            .collect()
    }

    fn run(rel: &str, src: &str) -> Vec<Finding> {
        let mut out = Vec::new();
        lint_file(rel, src, &test_ranks(), &mut out);
        out
    }

    fn rules(f: &[Finding]) -> Vec<&'static str> {
        f.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn strip_blanks_string_and_char_literals() {
        let s = strip(
            "let c = '\"'; let s = \"unsafe .lock()\"; // SAFETY: note",
        );
        assert!(!s.code[0].contains("unsafe"));
        assert!(!s.code[0].contains(".lock()"));
        assert!(s.code[0].contains("let s ="));
        assert!(s.comment[0].contains("SAFETY:"));
    }

    #[test]
    fn strip_keeps_lifetimes_and_blanks_raw_strings() {
        let s = strip("fn f<'a>(x: &'a str) { let r = r#\"transmute\"#; }");
        assert!(s.code[0].contains("<'a>"));
        assert!(!s.code[0].contains("transmute"));
    }

    #[test]
    fn strip_tracks_block_comments_across_lines() {
        let s = strip("/* unsafe\n   transmute */ fn ok() {}");
        assert!(!s.code[0].contains("unsafe"));
        assert!(!s.code[1].contains("transmute"));
        assert!(s.code[1].contains("fn ok()"));
        assert!(s.comment[0].contains("unsafe"));
    }

    #[test]
    fn unsafe_and_transmute_outside_allowlist_are_flagged() {
        let src = r#"
pub fn f(p: *const u32) -> u32 {
    unsafe { *p }
}
pub fn g(x: u64) -> f64 {
    unsafe { std::mem::transmute(x) }
}
"#;
        let f = run("rust/src/apps/x.rs", src);
        assert_eq!(
            rules(&f),
            vec!["unsafe-allowlist", "unsafe-allowlist", "transmute-allowlist"]
        );
    }

    #[test]
    fn transmute_in_identifier_is_not_flagged() {
        let f = run("rust/src/apps/x.rs", "fn do_not_transmute_me() {}\n");
        assert!(f.is_empty(), "{:?}", rules(&f));
    }

    #[test]
    fn allowlisted_unsafe_needs_a_justifying_comment() {
        let bad = "pub fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        let f = run("rust/src/sched/session.rs", bad);
        assert_eq!(rules(&f), vec!["unsafe-comment"]);

        let good = "// SAFETY: caller guarantees p is live.\n\
                    pub fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        assert!(run("rust/src/sched/session.rs", good).is_empty());
    }

    #[test]
    fn transmute_needs_soundness_not_just_safety() {
        let src = "// SAFETY: fine.\n\
                   fn g(x: u64) -> f64 { unsafe { std::mem::transmute(x) } }\n";
        let f = run("rust/src/sched/session.rs", src);
        assert_eq!(rules(&f), vec!["transmute-comment"]);
    }

    #[test]
    fn lock_rank_inversion_is_flagged() {
        let src = r#"
fn inverted(job: &Job, run: &GraphRun) {
    let b = job.body.lock().unwrap();
    let p = run.progress.lock().unwrap();
    drop(p);
    drop(b);
}
"#;
        let f = run("rust/src/sched/queue.rs", src);
        assert_eq!(rules(&f), vec!["lock-rank"]);
        assert!(f[0].msg.contains("GRAPH_PROGRESS"));
    }

    #[test]
    fn declared_order_nesting_is_clean() {
        let src = r#"
fn fine(run: &GraphRun, job: &Job) {
    let p = run.progress.lock().unwrap();
    let b = job.body.lock().unwrap();
    drop(b);
    drop(p);
}
"#;
        assert!(run("rust/src/sched/queue.rs", src).is_empty());
    }

    #[test]
    fn dropped_guard_frees_its_rank() {
        let src = r#"
fn sequential(job: &Job, run: &GraphRun) {
    let b = job.body.lock().unwrap();
    drop(b);
    let p = run.progress.lock().unwrap();
    drop(p);
}
"#;
        assert!(run("rust/src/sched/queue.rs", src).is_empty());
    }

    #[test]
    fn block_scoped_guard_is_released_at_block_end() {
        let src = r#"
fn scoped(job: &Job, run: &GraphRun) {
    {
        let b = job.body.lock().unwrap();
        b.take();
    }
    let p = run.progress.lock().unwrap();
    drop(p);
}
"#;
        assert!(run("rust/src/sched/queue.rs", src).is_empty());
    }

    #[test]
    fn unknown_lock_receivers_are_ignored() {
        let src = r#"
fn other(queues: &[Mutex<u32>], q: usize) {
    let a = queues[q].lock().unwrap();
    let b = self.inner.lock().unwrap();
}
"#;
        assert!(run("rust/src/sched/queue.rs", src).is_empty());
    }

    #[test]
    fn condvar_wait_requires_a_predicate_loop() {
        let bad = r#"
fn waits(m: &Mutex<bool>, cv: &Condvar) {
    let g = m.lock().unwrap();
    let _g = cv.wait(g).unwrap();
}
"#;
        let f = run("rust/src/apps/x.rs", bad);
        assert_eq!(rules(&f), vec!["condvar-predicate"]);

        let good = r#"
fn waits(m: &Mutex<Option<u32>>, cv: &Condvar) {
    let mut g = m.lock().unwrap();
    while g.is_none() {
        g = cv.wait(g).unwrap();
    }
}
"#;
        assert!(run("rust/src/apps/x.rs", good).is_empty());
    }

    #[test]
    fn zero_arg_wait_is_not_a_condvar_wait() {
        let src = "fn f(h: JobHandle) { let _r = h.wait(); }\n";
        assert!(run("rust/src/apps/x.rs", src).is_empty());
    }

    #[test]
    fn util_may_not_import_other_crate_modules() {
        let src = "use crate::sched::Executor;\nuse crate::util::rng::Rng;\n";
        let f = run("rust/src/util/x.rs", src);
        assert_eq!(rules(&f), vec!["layering-util"]);
    }

    #[test]
    fn sched_may_use_bench_only_under_cfg_test() {
        let src = r#"
use crate::config::SchedConfig;

#[cfg(test)]
mod tests {
    use crate::bench::harness;
}
"#;
        assert!(run("rust/src/sched/autotune.rs", src).is_empty());

        let bad = "use crate::bench::harness;\n";
        let f = run("rust/src/sched/autotune.rs", bad);
        assert_eq!(rules(&f), vec!["layering-sched"]);
    }

    #[test]
    fn sim_is_limited_to_the_scheduler_surface() {
        let src = "use crate::sched::Executor;\nuse crate::bench::harness;\n";
        let f = run("rust/src/sim/x.rs", src);
        assert_eq!(rules(&f), vec!["layering-sim"]);
    }

    #[test]
    fn serve_is_limited_to_sched_sim_and_shared_surface() {
        let src = "use crate::sim::serve::SERVE_TAG;\n\
                   use crate::sched::SubmitOpts;\n\
                   use crate::apps::cc;\n";
        let f = run("rust/src/serve/mod.rs", src);
        assert_eq!(rules(&f), vec!["layering-serve"]);
        assert!(f[0].msg.contains("crate::apps"));
    }

    #[test]
    fn only_bench_and_main_may_import_serve() {
        let src = "use crate::serve::ServeSpec;\n";
        let f = run("rust/src/vee/mod.rs", src);
        assert_eq!(rules(&f), vec!["layering-serve-consumers"]);
        assert!(run("rust/src/bench/figures.rs", src).is_empty());
        assert!(run("rust/src/main.rs", src).is_empty());
        assert!(run("rust/src/serve/report.rs", src).is_empty());
    }

    #[test]
    fn serve_import_under_cfg_test_is_allowed() {
        let src = "use crate::matrix::Dense;\n\
                   \n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       use crate::serve::ServeSpec;\n\
                   }\n";
        assert!(run("rust/src/vee/mod.rs", src).is_empty());
    }

    #[test]
    fn obs_is_limited_to_util_topology_config() {
        let src = "use crate::util::json::Json;\n\
                   use crate::config::TraceMode;\n\
                   use crate::sched::Executor;\n";
        let f = run("rust/src/obs/export.rs", src);
        assert_eq!(rules(&f), vec!["layering-obs"]);
        assert!(f[0].msg.contains("crate::sched"));
    }

    #[test]
    fn sim_and_serve_may_use_obs() {
        let src = "use crate::obs::trace::{self, TraceKind};\n";
        assert!(run("rust/src/sim/graph.rs", src).is_empty());
        assert!(run("rust/src/serve/mod.rs", src).is_empty());
    }

    #[test]
    fn obs_analyze_may_read_sim_but_not_sched() {
        // the analysis modules get the wider allowlist...
        let sim_src = "use crate::sim::GraphSimOutcome;\n";
        assert!(run("rust/src/obs/analyze.rs", sim_src).is_empty());
        assert!(run("rust/src/obs/report.rs", sim_src).is_empty());
        // ...the recorder modules do not...
        let f = run("rust/src/obs/export.rs", sim_src);
        assert_eq!(rules(&f), vec!["layering-obs"]);
        assert!(f[0].msg.contains("crate::sim"));
        // ...and sched stays off-limits even for analysis
        let sched_src = "use crate::sched::Executor;\n";
        let f = run("rust/src/obs/analyze.rs", sched_src);
        assert_eq!(rules(&f), vec!["layering-obs"]);
        assert!(f[0].msg.contains("never sched internals"));
        assert!(f[0].msg.contains("crate::sched"));
    }

    #[test]
    fn elastic_overlay_is_a_near_leaf() {
        let ok = "use crate::util::ordered::OrderedMutex;\n\
                  use crate::topology::Topology;\n\
                  use crate::sched::ranks::ELASTIC_LEASE;\n";
        assert!(run("rust/src/sched/elastic.rs", ok).is_empty());
        let bad = "use crate::obs::trace;\nuse crate::sim::replay;\n";
        let f = run("rust/src/sched/elastic.rs", bad);
        assert_eq!(rules(&f), vec!["layering-elastic", "layering-elastic"]);
        assert!(f[0].msg.contains("crate::obs"));
        // the same imports are fine in any other sched module
        assert!(run("rust/src/sched/executor.rs", bad).is_empty());
    }

    #[test]
    fn elastic_module_path_is_private_to_sched_sim_and_serve() {
        let src = "use crate::sched::elastic::ElasticPools;\n";
        let f = run("rust/src/bench/figures.rs", src);
        assert_eq!(rules(&f), vec!["layering-elastic"]);
        let f = run("rust/src/main.rs", src);
        assert_eq!(rules(&f), vec!["layering-elastic"]);
        // the session, the DES mirror and the serving loop own the path
        assert!(run("rust/src/sched/session.rs", src).is_empty());
        assert!(run("rust/src/sim/elastic.rs", src).is_empty());
        assert!(run("rust/src/serve/mod.rs", src).is_empty());
        // and a test-only reference is exempt, as everywhere else
        let test_only = "#[cfg(test)]\n\
                         mod tests {\n\
                             use crate::sched::elastic::ControllerCfg;\n\
                         }\n";
        assert!(run("rust/src/bench/figures.rs", test_only).is_empty());
    }

    #[test]
    fn obs_record_sharing_a_statement_with_a_lock_is_flagged() {
        let src = r#"
fn dispatch(job: &Job) {
    trace::record(TraceKind::Dispatch, job.stats.lock().unwrap().w, 0, 0, 0);
}
fn node_done() {}
fn record_done() {}
fn cancel_dependents() {}
"#;
        let f = run("rust/src/sched/graph.rs", src);
        assert_eq!(rules(&f), vec!["obs-lockfree"]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn multiline_obs_record_statement_is_scanned_to_its_semicolon() {
        let src = r#"
fn dispatch(job: &Job, run: &GraphRun) {
    job.record_trace(TraceKind::NodeComplete,
        run.progress.lock().unwrap().worker);
}
fn node_done() {}
fn record_done() {}
fn cancel_dependents() {}
"#;
        let f = run("rust/src/sched/graph.rs", src);
        assert_eq!(rules(&f), vec!["obs-lockfree"]);
    }

    #[test]
    fn obs_record_near_but_not_inside_a_lock_statement_is_clean() {
        let src = r#"
fn dispatch(job: &Job) {
    let g = job.stats.lock().unwrap();
    trace::record(TraceKind::Dispatch, g.w, 0, 0, 0);
    drop(g);
}
fn node_done() {}
fn record_done() {}
fn cancel_dependents() {}
"#;
        assert!(run("rust/src/sched/graph.rs", src).is_empty());
    }

    #[test]
    fn dispatch_path_bans_unwrap_and_expect() {
        let src = r#"
fn dispatch(items: &[u32]) {
    let v = items.first().unwrap();
    let g = run.progress.lock().unwrap();
    let r = report.clone().expect("published");
}
fn node_done() {}
fn record_done() {}
fn cancel_dependents() {}
"#;
        let f = run("rust/src/sched/graph.rs", src);
        assert_eq!(rules(&f), vec!["dispatch-unwrap", "dispatch-unwrap"]);
    }

    #[test]
    fn poisoned_lock_idiom_is_allowed_on_the_dispatch_path() {
        let src = r#"
fn dispatch(shared: &Shared) {
    loop {
        let mut q = shared.queue.lock().unwrap();
        q = shared.work_cv.wait(q).unwrap();
        drop(q);
    }
}
fn node_done() {}
fn record_done() {}
fn cancel_dependents() {}
"#;
        assert!(run("rust/src/sched/graph.rs", src).is_empty());
    }

    #[test]
    fn missing_dispatch_fn_is_itself_an_error() {
        let src = "fn dispatch() {}\nfn node_done() {}\nfn record_done() {}\n";
        let f = run("rust/src/sched/graph.rs", src);
        assert_eq!(rules(&f), vec!["dispatch-unwrap"]);
        assert!(f[0].msg.contains("cancel_dependents"));
    }

    #[test]
    fn parse_ranks_reads_declaration_order() {
        let src = "pub const A_LOCK: LockRank = LockRank::new(10, \"a\");\n\
                   pub const B_LOCK: LockRank = LockRank::new(20, \"b\");\n";
        assert_eq!(
            parse_ranks(src),
            vec![("A_LOCK".to_string(), 10), ("B_LOCK".to_string(), 20)]
        );
    }
}
