//! Quickstart: schedule a data-parallel operator with DaphneSched.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use daphne_sched::apps::cc;
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, GraphSpec};
use daphne_sched::sched::{QueueLayout, Scheme, VictimStrategy};
use daphne_sched::topology::Topology;

fn main() {
    // 1. a workload: connected components over a co-purchase-like graph
    let graph = amazon_like(&GraphSpec::small(20_000, 7)).symmetrize();
    println!(
        "graph: {} nodes, {} edges ({:.4}% dense)",
        graph.rows,
        graph.nnz(),
        graph.density() * 100.0
    );

    // 2. a machine: this host
    let topo = Topology::host();

    // 3. scheduling configurations to compare
    let configs = [
        ("DAPHNE default", SchedConfig::default()), // STATIC, central
        (
            "MFSC central",
            SchedConfig::default().with_scheme(Scheme::Mfsc),
        ),
        (
            "TFSS + work-stealing (RNDPRI)",
            SchedConfig::default()
                .with_scheme(Scheme::Tfss)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::RndPri),
        ),
    ];

    for (label, config) in configs {
        let result = cc::run_native(&graph, &topo, &config, 100);
        println!(
            "{label:<32} {} components in {} iterations, {:.4}s scheduled, \
             {} steals",
            result.components,
            result.iterations,
            result.total_time(),
            result.reports.iter().map(|r| r.total_steals()).sum::<usize>(),
        );
    }
}
