//! Virtual-time mirror of [`crate::sched::elastic`]: stepped-capacity
//! replay of elastic device pools under the *same*
//! [`ScalingController`] the real `serve` soak runs.
//!
//! The replay drives a light cost model (uniform-speed workers, fixed
//! per-item virtual cost per job — this mirror predicts *controller
//! behaviour and pool shape*, not host-calibrated makespans) over the
//! real overlay arithmetic: worker↔pool assignment goes through an
//! actual [`ElasticPools`] instance, so lend/reclaim/width semantics
//! cannot drift from the executor's. Eligibility is the executor's
//! rule verbatim — a borrowed worker serves only moldable jobs; home
//! workers serve their pool's pinned tenants first — and a pinned
//! arrival on a lending pool snaps borrowed workers home immediately
//! ([`ElasticPools::reclaim_if_lent`]), exactly like the executor's
//! enqueue hook.
//!
//! Two entry points:
//!
//! - [`replay_elastic`]: a full workload replay (static pools when
//!   [`ElasticSimSpec::controller`] is `None`), the oracle behind
//!   `figure elastic`;
//! - [`replay_steps`]: a scripted lend/reclaim/resize schedule, used by
//!   the DES-vs-real parity test to compare `Resize` trace-event
//!   ordering against a real [`crate::sched::Session`] applying the
//!   same schedule.

use std::collections::BinaryHeap;
use std::sync::Arc;

use super::engine::Ev;
use crate::obs::trace::{self, TraceKind, NO_JOB, OBS_CONTROL_WORKER};
use crate::sched::elastic::{ElasticPools, ScaleDecision, ScalingController, Signals};
pub use crate::sched::elastic::ControllerCfg;
use crate::sched::placement::DevicePools;
use crate::topology::Topology;
use crate::util::stats::LatencyReservoir;

/// Virtual seconds → integer nanoseconds for the shared trace stream
/// (same convention as [`super::graph`]).
fn vns(t: f64) -> u64 {
    (t * 1e9).round() as u64
}

/// Chunks per job: items are claimed in `items / CHUNKS_PER_JOB`-sized
/// chunks (floor 1), the granularity at which a re-homed worker lets go
/// of a job mid-flight — the DES analogue of the executor's per-chunk
/// yield check.
const CHUNKS_PER_JOB: usize = 64;

/// Reservoir capacity for the interactive-latency digest.
const ELASTIC_RESERVOIR: usize = 4096;

/// One cost-described job in the elastic replay.
#[derive(Debug, Clone)]
pub struct ElasticJob {
    pub name: String,
    /// Virtual arrival offset, seconds.
    pub arrival: f64,
    /// Parallel items.
    pub items: usize,
    /// Virtual seconds per item (uniform-speed workers).
    pub per_item: f64,
    /// Device pool the job is placed on.
    pub pool: usize,
    /// Moldable jobs may run on workers borrowed into their pool;
    /// pinned (`false`) jobs only ever run on home residents.
    pub moldable: bool,
    /// Counted in the interactive-latency reservoir ([`interactive_p99`](ElasticSimOutcome::interactive_p99)).
    pub interactive: bool,
}

impl ElasticJob {
    pub fn new(name: &str, arrival: f64, items: usize, per_item: f64) -> Self {
        ElasticJob {
            name: name.to_string(),
            arrival,
            items,
            per_item,
            pool: 0,
            moldable: false,
            interactive: false,
        }
    }

    pub fn pool(mut self, pool: usize) -> Self {
        self.pool = pool;
        self
    }

    pub fn moldable(mut self) -> Self {
        self.moldable = true;
        self
    }

    pub fn interactive(mut self) -> Self {
        self.interactive = true;
        self
    }
}

/// One elastic replay: the workload, the control cadence, and the
/// controller configuration (`None` = static pools — the baseline leg
/// of `figure elastic`).
#[derive(Debug, Clone)]
pub struct ElasticSimSpec {
    pub jobs: Vec<ElasticJob>,
    /// Seconds between controller evaluations.
    pub check_interval: f64,
    /// Reservoir seed (determinism, not randomness of outcome).
    pub seed: u64,
    /// `Some` runs the [`ScalingController`] at every check;
    /// `None` keeps the base pool assignment throughout.
    pub controller: Option<ControllerCfg>,
}

impl Default for ElasticSimSpec {
    fn default() -> Self {
        ElasticSimSpec {
            jobs: Vec::new(),
            check_interval: 0.01,
            seed: 42,
            controller: None,
        }
    }
}

/// What one [`replay_elastic`] run produced.
#[derive(Debug, Clone)]
pub struct ElasticSimOutcome {
    /// Virtual completion time of the last chunk.
    pub makespan: f64,
    /// Total busy time / (workers × makespan) — the figure's pool
    /// utilization metric.
    pub utilization: f64,
    /// Busy time per *placement* pool over (base width × makespan);
    /// a borrowing pool can exceed 1.0.
    pub per_pool_util: Vec<f64>,
    /// p99 latency (arrival → completion) over interactive jobs.
    pub interactive_p99: f64,
    /// Non-`Hold` controller decisions that moved workers, in order.
    pub decisions: Vec<ScaleDecision>,
    /// `(t, widths)` after every assignment change, starting at the
    /// base assignment.
    pub widths: Vec<(f64, Vec<usize>)>,
    /// Eager reclaims triggered by arrivals on a lending pool.
    pub snapbacks: usize,
    /// Jobs run to completion.
    pub completed: usize,
    /// No pinned chunk ever executed on a borrowed worker.
    pub invariant_ok: bool,
}

/// Replay `spec` on a modelled `topo`.
pub fn replay_elastic(topo: &Arc<Topology>, spec: &ElasticSimSpec) -> ElasticSimOutcome {
    let pools = DevicePools::new(topo);
    let el = ElasticPools::new(&pools);
    let nw = el.n_workers();
    let np = el.n_pools();
    let n = spec.jobs.len();
    let base_widths = el.widths();

    // arrival cursor over jobs sorted by (arrival, index)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        spec.jobs[a]
            .arrival
            .total_cmp(&spec.jobs[b].arrival)
            .then(a.cmp(&b))
    });
    let mut next_arr = 0usize;
    let mut arrived = vec![false; n];
    // monotonic high-water of the interactive (pinned pool-0) backlog —
    // the signal the real soak reads from `backlog_high_water`
    let mut backlog_hi: u64 = 0;

    // `remaining` = unclaimed items; `inflight` = claimed, not retired
    let mut remaining: Vec<usize> = spec.jobs.iter().map(|j| j.items).collect();
    let mut inflight = vec![0usize; n];
    let chunk: Vec<usize> =
        spec.jobs.iter().map(|j| (j.items / CHUNKS_PER_JOB).max(1)).collect();
    let mut done = vec![false; n];

    let mut controller = spec.controller.map(ScalingController::new);
    let mut next_check = spec.check_interval;

    let mut latencies = LatencyReservoir::new(ELASTIC_RESERVOIR, spec.seed ^ 0xE1A5);
    let mut decisions: Vec<ScaleDecision> = Vec::new();
    let mut widths_log: Vec<(f64, Vec<usize>)> = vec![(0.0, base_widths.clone())];
    let mut snapbacks = 0usize;
    let mut invariant_ok = true;
    let (mut scan_rounds, mut idle_scans) = (0u64, 0u64);
    let mut busy_total = 0.0f64;
    let mut pool_busy = vec![0.0f64; np];
    let mut makespan = 0.0f64;
    let mut completed = 0usize;

    fn record_widths(el: &ElasticPools, t: f64) {
        for (p, wd) in el.widths().iter().enumerate() {
            trace::record_at(
                vns(t),
                TraceKind::Resize,
                OBS_CONTROL_WORKER,
                NO_JOB,
                p as u64,
                *wd as u64,
            );
        }
    }

    // current chunk per worker: (job, claimed items)
    let mut cur: Vec<Option<(usize, usize)>> = vec![None; nw];
    let mut heap: BinaryHeap<Ev> = (0..nw).map(|w| Ev { t: 0.0, w }).collect();

    while let Some(Ev { t, w }) = heap.pop() {
        // 1) admit arrivals up to t; an arrival on a lending pool snaps
        //    borrowed workers home (the executor's enqueue hook)
        while next_arr < n && spec.jobs[order[next_arr]].arrival <= t {
            let j = order[next_arr];
            next_arr += 1;
            arrived[j] = true;
            if !spec.jobs[j].moldable && spec.jobs[j].pool == 0 {
                let now_backlog = (0..n)
                    .filter(|&k| {
                        arrived[k]
                            && !done[k]
                            && !spec.jobs[k].moldable
                            && spec.jobs[k].pool == 0
                    })
                    .count() as u64;
                backlog_hi = backlog_hi.max(now_backlog);
            }
            let at = spec.jobs[j].arrival;
            if el.reclaim_if_lent(spec.jobs[j].pool) > 0 {
                snapbacks += 1;
                record_widths(&el, at);
                widths_log.push((at, el.widths()));
            }
        }

        // 2) controller checks due at or before t
        while controller.is_some() && next_check <= t {
            let ct = next_check;
            next_check += spec.check_interval;
            let donor_busy = (0..n).any(|j| {
                arrived[j] && !done[j] && !spec.jobs[j].moldable && spec.jobs[j].pool == 1
            });
            let sig = Signals {
                p99: latencies.p99(),
                backlog: backlog_hi,
                failed_steal_ratio: if scan_rounds > 0 {
                    idle_scans as f64 / scan_rounds as f64
                } else {
                    0.0
                },
                donor_busy,
                width: el.width(0),
            };
            scan_rounds = 0;
            idle_scans = 0;
            let decision = controller.as_mut().unwrap().decide(&sig);
            let moved = match decision {
                ScaleDecision::Hold => 0,
                // a busy donor refuses the lease — Session::lend's
                // pool-backlog guard
                ScaleDecision::Lend(_) if donor_busy => 0,
                ScaleDecision::Lend(k) => el.lend(1, 0, k),
                ScaleDecision::Reclaim => el.reclaim(1),
            };
            if moved > 0 {
                decisions.push(decision);
                record_widths(&el, ct);
                widths_log.push((ct, el.widths()));
            }
        }

        // 3) retire the chunk this event marks the end of
        if let Some((j, len)) = cur[w].take() {
            inflight[j] -= len;
            makespan = makespan.max(t);
            if remaining[j] == 0 && inflight[j] == 0 && !done[j] {
                done[j] = true;
                completed += 1;
                if spec.jobs[j].interactive {
                    latencies.record(t - spec.jobs[j].arrival);
                }
            }
        }

        // 4) pick the next chunk: the executor's eligibility rule, with
        //    pinned tenants ahead of moldable batch in scan order
        let my_pool = el.assignment_of(w);
        let home = el.home_of(w);
        let mut pick: Option<usize> = None;
        if el.is_active(w) {
            scan_rounds += 1;
            for j in 0..n {
                if !arrived[j] || remaining[j] == 0 {
                    continue;
                }
                let jb = &spec.jobs[j];
                if jb.pool != my_pool || (my_pool != home && !jb.moldable) {
                    continue;
                }
                let better = pick.map_or(true, |b| {
                    let bb = &spec.jobs[b];
                    jb.moldable
                        .cmp(&bb.moldable)
                        .then(jb.arrival.total_cmp(&bb.arrival))
                        .then(j.cmp(&b))
                        .is_lt()
                });
                if better {
                    pick = Some(j);
                }
            }
            if pick.is_none() {
                idle_scans += 1;
            }
        }

        if let Some(j) = pick {
            let jb = &spec.jobs[j];
            if !jb.moldable && home != jb.pool {
                invariant_ok = false;
            }
            let len = chunk[j].min(remaining[j]);
            remaining[j] -= len;
            inflight[j] += len;
            let dur = len as f64 * jb.per_item;
            busy_total += dur;
            pool_busy[jb.pool] += dur;
            cur[w] = Some((j, len));
            heap.push(Ev { t: t + dur, w });
            continue;
        }

        // idle: re-fire when eligibility can change — the next arrival
        // or the next controller check — else retire this worker
        let work_left = remaining.iter().any(|&r| r > 0) || next_arr < n;
        if !work_left {
            continue;
        }
        let mut wake = f64::INFINITY;
        if next_arr < n {
            wake = wake.min(spec.jobs[order[next_arr]].arrival.max(t));
        }
        if controller.is_some() {
            wake = wake.min(next_check);
        }
        if wake.is_finite() {
            heap.push(Ev { t: wake, w });
        }
    }

    let span = makespan.max(f64::MIN_POSITIVE);
    ElasticSimOutcome {
        makespan,
        utilization: busy_total / (nw as f64 * span),
        per_pool_util: base_widths
            .iter()
            .zip(&pool_busy)
            .map(|(&bw, &b)| b / (bw.max(1) as f64 * span))
            .collect(),
        interactive_p99: latencies.p99(),
        decisions,
        widths: widths_log,
        snapbacks,
        completed,
        invariant_ok,
    }
}

/// One scripted resize step for [`replay_steps`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ElasticStep {
    /// Lend `n` workers `from` → `to` at virtual time `t`.
    Lend { t: f64, from: usize, to: usize, n: usize },
    /// Return every borrowed `pool`-homed worker at `t`.
    Reclaim { t: f64, pool: usize },
    /// Park/unpark `pool` residents to `width` at `t`.
    Resize { t: f64, pool: usize, width: usize },
}

/// Apply a scripted schedule through the real overlay arithmetic,
/// recording the same per-pool `Resize` trace events a
/// [`crate::sched::Session`] publishes (only when workers actually
/// moved). Returns the widths after each step — the parity test
/// compares both this and the drained event stream against a real
/// session applying the identical schedule.
pub fn replay_steps(topo: &Arc<Topology>, steps: &[ElasticStep]) -> Vec<Vec<usize>> {
    let pools = DevicePools::new(topo);
    let el = ElasticPools::new(&pools);
    let mut out = Vec::with_capacity(steps.len());
    for s in steps {
        let (t, moved) = match *s {
            ElasticStep::Lend { t, from, to, n } => (t, el.lend(from, to, n)),
            ElasticStep::Reclaim { t, pool } => (t, el.reclaim(pool)),
            ElasticStep::Resize { t, pool, width } => {
                let before = el.epoch();
                el.set_width(pool, width);
                (t, (el.epoch() != before) as usize)
            }
        };
        if moved > 0 {
            for (p, wd) in el.widths().iter().enumerate() {
                trace::record_at(
                    vns(t),
                    TraceKind::Resize,
                    OBS_CONTROL_WORKER,
                    NO_JOB,
                    p as u64,
                    *wd as u64,
                );
            }
        }
        out.push(el.widths());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::DeviceClass;

    fn hetero() -> Arc<Topology> {
        Arc::new(Topology::heterogeneous(
            "h",
            1,
            4,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        ))
    }

    /// A tight objective the static 4-worker pool cannot hold once a
    /// burst queues behind the batch.
    fn test_cfg() -> ControllerCfg {
        ControllerCfg {
            slo: 0.0005,
            min_workers: 4,
            max_workers: 6,
            patience: 1,
            ..ControllerCfg::default()
        }
    }

    /// Bursty interactive tenants + a moldable batch on pool 0, idle
    /// GPU pool — the miniature of the `figure elastic` workload. The
    /// batch is many *small* moldable jobs (0.2 ms chunks), so home
    /// workers are never stuck behind a coarse chunk: elastic latencies
    /// dominate static ones sample for sample, because borrowed workers
    /// only ever drain the batch and home-worker timelines stay
    /// identical until the batch runs dry (earlier under lending).
    fn bursty_mix() -> Vec<ElasticJob> {
        let mut jobs: Vec<ElasticJob> = (0..20)
            .map(|b| {
                ElasticJob::new(&format!("batch{b}"), 0.0, 128, 1e-4)
                    .moldable()
            })
            .collect();
        for i in 0..20 {
            let t = 0.02 + 0.015 * (i / 4) as f64 + 0.002 * (i % 4) as f64;
            jobs.push(
                ElasticJob::new(&format!("rq{i}"), t, 64, 1e-4).interactive(),
            );
        }
        jobs
    }

    #[test]
    fn elastic_beats_static_on_the_bursty_mix() {
        let topo = hetero();
        let mix = bursty_mix();
        let stat = replay_elastic(
            &topo,
            &ElasticSimSpec { jobs: mix.clone(), ..ElasticSimSpec::default() },
        );
        let elas = replay_elastic(
            &topo,
            &ElasticSimSpec {
                jobs: mix,
                controller: Some(test_cfg()),
                ..ElasticSimSpec::default()
            },
        );
        assert!(stat.invariant_ok && elas.invariant_ok);
        assert_eq!(stat.decisions, Vec::new());
        assert!(!elas.decisions.is_empty(), "controller acted: {:?}", elas.decisions);
        assert!(
            elas.utilization >= stat.utilization,
            "elastic util {} < static {}",
            elas.utilization,
            stat.utilization
        );
        assert!(
            elas.interactive_p99 <= stat.interactive_p99,
            "elastic p99 {} > static {}",
            elas.interactive_p99,
            stat.interactive_p99
        );
        assert!(elas.makespan <= stat.makespan);
        assert_eq!(elas.completed, 40);
    }

    #[test]
    fn pinned_arrival_on_donor_pool_snaps_lent_workers_back() {
        let topo = hetero();
        // interactive pressure makes the controller lend the GPU pool
        // away; the pinned GPU arrival at t=0.08 must snap it back
        let mut jobs = bursty_mix();
        jobs.push(ElasticJob::new("gpu", 0.08, 64, 1e-4).pool(1));
        let out = replay_elastic(
            &topo,
            &ElasticSimSpec {
                jobs,
                controller: Some(test_cfg()),
                ..ElasticSimSpec::default()
            },
        );
        assert!(out.invariant_ok, "pinned work stayed on its pool");
        assert_eq!(out.completed, 41);
        assert!(
            out.decisions.iter().any(|d| matches!(d, ScaleDecision::Lend(_))),
            "controller lent before the pinned arrival: {:?}",
            out.decisions
        );
        assert!(out.snapbacks >= 1, "pinned arrival forced a snap-back");
        // the snap-back restored the base assignment (4/2) mid-replay
        // (the controller may lend again afterwards)
        assert!(
            out.widths[1..].iter().any(|(_, w)| w == &vec![4, 2]),
            "no snap-back to the base widths in {:?}",
            out.widths
        );
    }

    #[test]
    fn scripted_steps_report_widths_like_the_overlay() {
        let topo = hetero();
        let widths = replay_steps(
            &topo,
            &[
                ElasticStep::Lend { t: 0.01, from: 1, to: 0, n: 2 },
                ElasticStep::Resize { t: 0.02, pool: 0, width: 3 },
                ElasticStep::Reclaim { t: 0.03, pool: 1 },
            ],
        );
        assert_eq!(widths, vec![vec![6, 0], vec![5, 0], vec![3, 2]]);
    }
}
