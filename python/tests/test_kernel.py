"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (and the CC id distribution); fixed-seed numpy
cases cover the exact artifact shapes used by the rust runtime.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis drives the shape sweeps; degrade to a module skip (instead
# of a collection error) on environments that lack it
hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; shape sweeps skipped"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model
from compile.kernels import cc_propagate as cc_k
from compile.kernels import linreg as lr_k
from compile.kernels import ref

RNG = np.random.default_rng(0xDA9)


def rand_adj(rows, cols, density=0.05):
    g = (RNG.random((rows, cols)) < density).astype(np.float32)
    return jnp.asarray(g)


def rand_ids(n, hi):
    return jnp.asarray(RNG.integers(1, hi + 1, n).astype(np.float32))


# ---------------------------------------------------------------------------
# cc_propagate
# ---------------------------------------------------------------------------


class TestCcPropagate:
    def test_artifact_shape(self):
        """Exact block shape the rust runtime executes."""
        g = rand_adj(model.CC_ROWS, model.CC_COLS)
        c = rand_ids(model.CC_COLS, 10_000)
        c_row = rand_ids(model.CC_ROWS, 10_000)
        got = cc_k.cc_propagate(g, c, c_row)
        want = ref.cc_propagate(g, c, c_row)
        np.testing.assert_array_equal(got, want)

    def test_no_edges_keeps_own_id(self):
        g = jnp.zeros((128, 128), jnp.float32)
        c = rand_ids(128, 50)
        c_row = rand_ids(128, 50)
        np.testing.assert_array_equal(
            cc_k.cc_propagate(g, c, c_row), c_row
        )

    def test_full_graph_propagates_global_max(self):
        g = jnp.ones((128, 256), jnp.float32)
        c = rand_ids(256, 999)
        c_row = rand_ids(128, 999)
        got = cc_k.cc_propagate(g, c, c_row)
        want = jnp.maximum(jnp.max(c), c_row)
        np.testing.assert_array_equal(got, want)

    def test_zero_padding_is_inert(self):
        """Zero-padded columns must not change the result (ids >= 1)."""
        g = rand_adj(128, 256)
        c = rand_ids(256, 100)
        c_row = rand_ids(128, 100)
        base = cc_k.cc_propagate(g, c, c_row)
        g_pad = jnp.pad(g, ((0, 0), (0, 128)))
        c_pad = jnp.pad(c, (0, 128))
        padded = cc_k.cc_propagate(g_pad, c_pad, c_row)
        np.testing.assert_array_equal(base, padded)

    @settings(max_examples=25, deadline=None)
    @given(
        rt=st.sampled_from([8, 32, 128]),
        row_blocks=st.integers(1, 3),
        col_blocks=st.integers(1, 4),
        density=st.floats(0.0, 0.5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(
        self, rt, row_blocks, col_blocks, density, seed
    ):
        rng = np.random.default_rng(seed)
        rows, cols = rt * row_blocks, rt * col_blocks
        g = jnp.asarray((rng.random((rows, cols)) < density).astype(np.float32))
        c = jnp.asarray(rng.integers(1, 1000, cols).astype(np.float32))
        c_row = jnp.asarray(rng.integers(1, 1000, rows).astype(np.float32))
        got = cc_k.cc_propagate(g, c, c_row, row_tile=rt, col_tile=rt)
        want = ref.cc_propagate(g, c, c_row)
        np.testing.assert_array_equal(got, want)

    def test_fixpoint_of_converged_labels(self):
        """Once labels equal the component max, propagate is the identity."""
        # two cliques: {0..63} and {64..127}
        g = np.zeros((128, 128), np.float32)
        g[:64, :64] = 1.0
        g[64:, 64:] = 1.0
        c = np.zeros(128, np.float32)
        c[:64] = 64.0
        c[64:] = 128.0
        g, c = jnp.asarray(g), jnp.asarray(c)
        got = cc_k.cc_propagate(g, c, c)
        np.testing.assert_array_equal(got, c)


# ---------------------------------------------------------------------------
# linear-regression kernels
# ---------------------------------------------------------------------------


class TestColstats:
    def test_artifact_shape(self):
        x = jnp.asarray(RNG.random((model.LR_ROWS, model.LR_COLS)), jnp.float32)
        s, sq = lr_k.colstats(x)
        rs, rsq = ref.colstats(x)
        np.testing.assert_allclose(s, rs, rtol=1e-5)
        np.testing.assert_allclose(sq, rsq, rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        cols=st.sampled_from([8, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, blocks, cols, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((128 * blocks, cols)), jnp.float32)
        s, sq = lr_k.colstats(x)
        rs, rsq = ref.colstats(x)
        np.testing.assert_allclose(s, rs, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(sq, rsq, rtol=1e-4, atol=1e-4)


class TestStandardize:
    def test_artifact_shape(self):
        x = jnp.asarray(RNG.random((model.LR_ROWS, model.LR_COLS)), jnp.float32)
        mean = jnp.asarray(RNG.random(model.LR_COLS), jnp.float32)
        std = jnp.asarray(RNG.random(model.LR_COLS) + 0.5, jnp.float32)
        got = lr_k.standardize(x, mean, std)
        np.testing.assert_allclose(
            got, ref.standardize(x, mean, std), rtol=1e-6
        )

    def test_roundtrip(self):
        """standardize(x, 0, 1) == x."""
        x = jnp.asarray(RNG.random((128, 64)), jnp.float32)
        got = lr_k.standardize(
            x, jnp.zeros(64, jnp.float32), jnp.ones(64, jnp.float32)
        )
        np.testing.assert_array_equal(got, x)


class TestSyrk:
    def test_artifact_shape(self):
        x = jnp.asarray(
            RNG.standard_normal((model.LR_ROWS, model.LR_COLS)), jnp.float32
        )
        np.testing.assert_allclose(
            lr_k.syrk(x), ref.syrk(x), rtol=1e-4, atol=1e-4
        )

    def test_symmetry(self):
        x = jnp.asarray(RNG.standard_normal((256, 64)), jnp.float32)
        a = np.asarray(lr_k.syrk(x))
        np.testing.assert_allclose(a, a.T, rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        cols=st.sampled_from([8, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, blocks, cols, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((128 * blocks, cols)), jnp.float32)
        np.testing.assert_allclose(
            lr_k.syrk(x), ref.syrk(x), rtol=1e-3, atol=1e-3
        )

    def test_block_accumulation(self):
        """syrk(top) + syrk(bottom) == syrk(whole) — the VEE contract."""
        x = jnp.asarray(RNG.standard_normal((512, 32)), jnp.float32)
        whole = lr_k.syrk(x)
        parts = lr_k.syrk(x[:256]) + lr_k.syrk(x[256:])
        np.testing.assert_allclose(whole, parts, rtol=1e-4, atol=1e-4)


class TestGemv:
    def test_artifact_shape(self):
        x = jnp.asarray(
            RNG.standard_normal((model.LR_ROWS, model.LR_COLS)), jnp.float32
        )
        y = jnp.asarray(RNG.standard_normal(model.LR_ROWS), jnp.float32)
        np.testing.assert_allclose(
            lr_k.gemv(x, y), ref.gemv(x, y), rtol=1e-4, atol=1e-4
        )

    def test_block_accumulation(self):
        x = jnp.asarray(RNG.standard_normal((512, 32)), jnp.float32)
        y = jnp.asarray(RNG.standard_normal(512), jnp.float32)
        whole = lr_k.gemv(x, y)
        parts = lr_k.gemv(x[:256], y[:256]) + lr_k.gemv(x[256:], y[256:])
        np.testing.assert_allclose(whole, parts, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        blocks=st.integers(1, 3),
        cols=st.sampled_from([8, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, blocks, cols, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((128 * blocks, cols)), jnp.float32)
        y = jnp.asarray(rng.standard_normal(128 * blocks), jnp.float32)
        np.testing.assert_allclose(
            lr_k.gemv(x, y), ref.gemv(x, y), rtol=1e-3, atol=1e-3
        )
