//! Regenerates Figures 7a and 7b: connected components with the
//! centralized work queue on the modelled Broadwell (2×10) and Cascade
//! Lake (2×28), one bar per partitioning scheme.
//!
//! ```sh
//! cargo bench --bench fig7_cc_centralized
//! # full paper scale (20.17M nodes):
//! DAPHNE_FIG_SCALE=50 cargo bench --bench fig7_cc_centralized
//! ```

use daphne_sched::bench::{figures, FigureId, FigureParams};

fn params() -> FigureParams {
    let scale = std::env::var("DAPHNE_FIG_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    FigureParams { scale, ..Default::default() }
}

fn main() {
    let params = params();
    println!(
        "workload: synthetic amazon x{} ({} nodes source), 3 repetitions\n",
        params.scale, params.nodes
    );
    let rows_a = figures::print_figure(FigureId::Fig7a, &params);
    // The 56-core machine needs the paper's compute/overhead ratio:
    // below ~3M rows the central queue dominates and every dynamic
    // scheme drowns in contention (EXPERIMENTS.md §Deviations). The
    // paper ran 20.17M rows; scale >= 8 restores the regime.
    let params_b =
        FigureParams { scale: params.scale.max(8), ..params.clone() };
    println!(
        "(Fig 7b runs at scale x{} for the paper's compute/overhead ratio)",
        params_b.scale
    );
    let rows_b = figures::print_figure(FigureId::Fig7b, &params_b);

    // paper-shape summary
    let gain = |rows: &[figures::Row]| {
        let mfsc = rows.iter().find(|r| r.scheme == "MFSC").unwrap();
        (1.0 - mfsc.vs_static) * 100.0
    };
    println!("\npaper vs measured (MFSC gain over STATIC):");
    println!("  Fig 7a: paper 13.2%  measured {:+.1}%", gain(&rows_a));
    println!("  Fig 7b: paper  8.3%  measured {:+.1}%", gain(&rows_b));
}
