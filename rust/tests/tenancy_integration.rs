//! Multi-tenant session acceptance tests: the cross-job pick policies
//! on the REAL executor agree with the DES prediction on policy
//! ordering (Fair and Priority beat FIFO on interactive tail latency
//! under bursty arrivals), cancellation mid-graph frees capacity for
//! queued tenants deterministically, and dropped handles neither
//! deadlock the pool nor leak the job slot.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use daphne_sched::config::SchedConfig;
use daphne_sched::sched::{
    Executor, GraphSpec, JobSpec, NodeSpec, NodeStatus, SubmitOpts,
    TenancyPolicy,
};
use daphne_sched::sim::{self, GraphShape, NodeModel, TenantSpec};
use daphne_sched::topology::Topology;

/// Fine-grained config: per-item chunks on the atomic central queue,
/// so the preemption quantum is one item and the pick policies can act
/// inside a node (the same config the DES tenancy figure uses).
fn fine_cfg() -> SchedConfig {
    SchedConfig::fine_grained()
}

fn executor(policy: TenancyPolicy) -> Executor {
    Executor::new_with_policy(
        Arc::new(Topology::symmetric("t4", 1, 4, 1.0, 1.0)),
        Arc::new(fine_cfg()),
        policy,
    )
}

/// ~tens of microseconds of real work per item (absolute speed is
/// irrelevant — only latency *ratios* between policies are asserted).
fn spin_item() {
    let mut x = 0u64;
    for i in 0..20_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(x);
}

const HEAVY_NODE_ITEMS: usize = 2_000;
const SHORT_ITEMS: usize = 80;
const N_SHORTS: usize = 4;

/// Run the bursty scenario on a real 4-worker pool: one heavy 2-node
/// batch chain submitted first, then a burst of short interactive
/// tenants through the same session. Returns the worst
/// submission-to-completion latency among the shorts, in seconds.
fn real_worst_short_latency(policy: TenancyPolicy) -> f64 {
    let exec = executor(policy);
    let session = exec.session();
    let t0 = Instant::now();

    let heavy = GraphSpec::new("batch")
        .node(NodeSpec::new("p1", HEAVY_NODE_ITEMS), |_w, r| {
            for _ in r.iter() {
                spin_item();
            }
        })
        .node(
            NodeSpec::new("p2", HEAVY_NODE_ITEMS).after("p1"),
            |_w, r| {
                for _ in r.iter() {
                    spin_item();
                }
            },
        );
    let hh = session
        .submit_graph(heavy, SubmitOpts::new().tag("batch"))
        .unwrap();

    let mut shorts = Vec::new();
    for i in 0..N_SHORTS {
        let done: Arc<Mutex<Option<Instant>>> = Arc::new(Mutex::new(None));
        let d = Arc::clone(&done);
        let spec = GraphSpec::new("interactive").node(
            NodeSpec::new("q", SHORT_ITEMS),
            move |_w, r| {
                for _ in r.iter() {
                    spin_item();
                }
                // the last task's write is the completion timestamp
                *d.lock().unwrap() = Some(Instant::now());
            },
        );
        let h = session
            .submit_graph(
                spec,
                SubmitOpts::new()
                    .tag("interactive")
                    .priority(2)
                    .weight(4),
            )
            .unwrap();
        shorts.push((done, h, i));
    }

    let mut worst = 0f64;
    for (done, h, i) in shorts {
        let report = h.wait();
        assert!(report.all_completed(), "short {i} did not complete");
        let at = done.lock().unwrap().expect("short ran");
        worst = worst.max(at.duration_since(t0).as_secs_f64());
    }
    let hr = hh.wait();
    assert!(hr.all_completed(), "batch tenant must still complete");
    worst
}

/// The same scenario in virtual time: worst short-tenant latency under
/// `policy` as the DES predicts it.
fn modelled_worst_short_latency(policy: TenancyPolicy) -> f64 {
    let per_item = 2e-5;
    let heavy = GraphShape::new("batch")
        .node(NodeModel::uniform("p1", HEAVY_NODE_ITEMS, per_item))
        .node(
            NodeModel::uniform("p2", HEAVY_NODE_ITEMS, per_item).after("p1"),
        );
    let mut tenants = vec![TenantSpec::new("batch", heavy, 0.0).tag("batch")];
    for i in 0..N_SHORTS {
        tenants.push(
            TenantSpec::new(
                &format!("short{i}"),
                GraphShape::new("interactive")
                    .node(NodeModel::uniform("q", SHORT_ITEMS, per_item)),
                1e-4 * (i + 1) as f64,
            )
            .tag("interactive")
            .priority(2)
            .weight(4),
        );
    }
    let out = sim::replay_tenants(
        &tenants,
        &Topology::symmetric("t4", 1, 4, 1.0, 1.0),
        &fine_cfg(),
        &sim::CostModel::recorded(),
        policy,
    )
    .unwrap();
    out.tenants
        .iter()
        .filter(|t| t.tag == "interactive")
        .map(|t| t.latency())
        .fold(0.0, f64::max)
}

#[test]
fn policy_ordering_agrees_between_des_and_real_executor() {
    // DES prediction: FIFO parks the interactive burst behind the
    // batch backlog; Fair and Priority do not.
    let des_fifo = modelled_worst_short_latency(TenancyPolicy::Fifo);
    let des_fair = modelled_worst_short_latency(TenancyPolicy::Fair);
    let des_prio = modelled_worst_short_latency(TenancyPolicy::Priority);
    assert!(
        des_fair < des_fifo,
        "DES: fair {des_fair} must beat fifo {des_fifo}"
    );
    assert!(
        des_prio < des_fifo,
        "DES: priority {des_prio} must beat fifo {des_fifo}"
    );

    // Real executor: the same policy ordering on wall-clock latencies.
    // Only the ordering is asserted (with margin) — absolute latencies
    // depend on the host.
    let real_fifo = real_worst_short_latency(TenancyPolicy::Fifo);
    let real_fair = real_worst_short_latency(TenancyPolicy::Fair);
    let real_prio = real_worst_short_latency(TenancyPolicy::Priority);
    assert!(
        real_fair < real_fifo,
        "executor: fair {real_fair}s must beat fifo {real_fifo}s, \
         as the DES predicted ({des_fair} vs {des_fifo})"
    );
    assert!(
        real_prio < real_fifo,
        "executor: priority {real_prio}s must beat fifo {real_fifo}s, \
         as the DES predicted ({des_prio} vs {des_fifo})"
    );
}

#[test]
fn cancelling_a_job_mid_run_frees_capacity_for_the_queued_tenant() {
    // Two workers, both parked inside the victim job's first two items
    // (the gate holds them); every remaining item of the victim is
    // undispatched, so the queued tenant can only run if cancellation
    // actually frees the pool. Fully deterministic: no worker is free
    // to pull more victim items while the gate is closed.
    let exec = Executor::new_with_policy(
        Arc::new(Topology::symmetric("t2", 1, 2, 1.0, 1.0)),
        Arc::new(fine_cfg()),
        TenancyPolicy::Fifo,
    );
    let gate = Arc::new(AtomicBool::new(false));
    let entered = Arc::new(AtomicUsize::new(0));
    let (g, n) = (Arc::clone(&gate), Arc::clone(&entered));
    let victim = exec.submit(JobSpec::new(20_000).named("victim"), move |_w, _r| {
        n.fetch_add(1, Ordering::SeqCst);
        while !g.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
    });
    while entered.load(Ordering::SeqCst) < 2 {
        std::thread::yield_now();
    }
    // queued tenant, submitted while both workers are held
    let covered = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&covered);
    let tenant = exec.submit(JobSpec::new(5_000).named("tenant"), move |_w, r| {
        c.fetch_add(r.len(), Ordering::Relaxed);
    });
    victim.cancel();
    gate.store(true, Ordering::Release);
    // exactly the two in-flight items ran; the other 19,998 were
    // drained by the cancel, never executed
    let vr = victim.wait();
    assert!(victim.was_cancelled());
    assert_eq!(vr.total_items(), 2, "only the held items may run");
    assert_eq!(entered.load(Ordering::SeqCst), 2);
    // the queued tenant's makespan no longer includes the victim's
    // 19,998-item backlog — it completes in full
    let tr = tenant.wait();
    assert_eq!(tr.total_items(), 5_000);
    assert_eq!(covered.load(Ordering::Relaxed), 5_000);
}

#[test]
fn cancelling_a_graph_mid_run_cancels_undispatched_nodes() {
    let exec = Executor::new_with_policy(
        Arc::new(Topology::symmetric("t2", 1, 2, 1.0, 1.0)),
        Arc::new(fine_cfg()),
        TenancyPolicy::Fifo,
    );
    let session = exec.session();
    let gate = Arc::new(AtomicBool::new(false));
    let entered = Arc::new(AtomicUsize::new(0));
    let (g, n) = (Arc::clone(&gate), Arc::clone(&entered));
    let rest_ran = Arc::new(AtomicUsize::new(0));
    let r2 = Arc::clone(&rest_ran);
    let spec = GraphSpec::new("cancel-mid")
        .node(NodeSpec::new("hold", 2), move |_w, _r| {
            n.fetch_add(1, Ordering::SeqCst);
            while !g.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
        })
        .node(
            NodeSpec::new("rest", 10_000).after("hold"),
            move |_w, r| {
                r2.fetch_add(r.len(), Ordering::Relaxed);
            },
        );
    let h = session.submit_graph(spec, SubmitOpts::default()).unwrap();
    while entered.load(Ordering::SeqCst) < 2 {
        std::thread::yield_now();
    }
    h.cancel();
    gate.store(true, Ordering::Release);
    let report = h.join();
    // both held items ran to completion, so cancellation cost the
    // "hold" node nothing — it is Completed; only the undispatched
    // dependent is Cancelled
    assert_eq!(report.status("hold"), Some(NodeStatus::Completed));
    assert_eq!(report.status("rest"), Some(NodeStatus::Cancelled));
    assert_eq!(
        rest_ran.load(Ordering::Relaxed),
        0,
        "the dependent node never dispatched"
    );
    // the freed pool still serves the next tenant on every worker
    all_workers_barrier(&exec, 2);
}

/// A job with one item per worker whose body spins until *every*
/// worker has entered it: completes only if the whole pool is free and
/// serving — the "subsequent job completes on all workers" assertion
/// (a leaked slot or deadlocked worker hangs this job, failing the
/// test by timeout).
fn all_workers_barrier(exec: &Executor, workers: usize) {
    let entered = Arc::new(AtomicUsize::new(0));
    let seen: Arc<Mutex<HashSet<usize>>> = Arc::new(Mutex::new(HashSet::new()));
    let (n, s) = (Arc::clone(&entered), Arc::clone(&seen));
    let h = exec.submit(
        JobSpec::new(workers).named("barrier").with_config(
            // one STATIC chunk per worker
            SchedConfig::default(),
        ),
        move |w, _r| {
            s.lock().unwrap().insert(w);
            n.fetch_add(1, Ordering::SeqCst);
            while n.load(Ordering::SeqCst) < workers {
                std::thread::yield_now();
            }
        },
    );
    let report = h.wait();
    assert_eq!(report.total_items(), workers);
    assert_eq!(
        seen.lock().unwrap().len(),
        workers,
        "every worker participated"
    );
}

#[test]
fn dropped_job_handle_neither_deadlocks_nor_leaks_the_slot() {
    let exec = executor(TenancyPolicy::Fifo);
    let before = exec.jobs_completed();
    {
        // dropped without wait(): the job keeps running detached
        let _ = exec.submit(JobSpec::new(50_000).named("dropped"), |_w, _r| {});
    }
    // the pool still serves a full-width job afterwards
    all_workers_barrier(&exec, 4);
    // and the dropped job's slot was finalized, not leaked
    while exec.jobs_completed() < before + 2 {
        std::thread::yield_now();
    }
    assert_eq!(exec.jobs_completed(), before + 2);
}

#[test]
fn dropped_graph_handle_neither_deadlocks_nor_leaks_the_slot() {
    let exec = executor(TenancyPolicy::Fair);
    let session = exec.session();
    let count = Arc::new(AtomicUsize::new(0));
    let c = Arc::clone(&count);
    {
        let spec = GraphSpec::new("dropped")
            .node(NodeSpec::new("a", 3_000), |_w, _r| {})
            .node(
                NodeSpec::new("b", 3_000).after("a"),
                move |_w, r| {
                    c.fetch_add(r.len(), Ordering::Relaxed);
                },
            );
        let _ = session.submit_graph(spec, SubmitOpts::new().tag("x"));
        // handle dropped here, graph still in flight
    }
    all_workers_barrier(&exec, 4);
    // the detached graph still ran to completion on the same pool
    while count.load(Ordering::Relaxed) < 3_000 {
        std::thread::yield_now();
    }
    assert_eq!(count.load(Ordering::Relaxed), 3_000);
}
