//! Microbenchmarks of the scheduler hot paths (the §Perf targets):
//!
//! - partitioner `next_chunk` per scheme (the `getNextChunk` cost),
//! - locked vs atomic central-queue pull,
//! - multi-queue pull + steal round,
//! - spawn-per-stage vs persistent-executor job dispatch (thread churn),
//! - barrier vs dag dispatch of a diamond task graph (branch overlap),
//! - DES event throughput,
//! - native CC propagate kernel throughput.
//!
//! ```sh
//! cargo bench --bench micro
//! ```

use std::sync::Arc;
use std::time::Instant;

use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::matrix::ops;
use daphne_sched::sched::executor::{Executor, JobSpec};
use daphne_sched::sched::graph::{GraphSpec, NodeSpec};
use daphne_sched::sched::TaskRange;
use daphne_sched::sched::partitioner::{Partitioner, PartitionerOptions};
use daphne_sched::sched::queue::{
    build_source, CentralAtomic, CentralLocked, QueueLayout, TaskSource,
};
use daphne_sched::config::GraphMode;
use daphne_sched::sched::{Scheme, VictimStrategy};
use daphne_sched::sim::{replay, simulate, CostModel, GraphShape, Workload};
use daphne_sched::topology::Topology;
use daphne_sched::util::fmt_duration;

/// The seed's behaviour: spawn + join a fresh pool for every stage
/// (construct executor → run one job → drop — `executor=oneshot`).
fn spawn_per_stage(topo: &Topology, cfg: &SchedConfig, items: usize) {
    Executor::new(Arc::new(topo.clone()), Arc::new(cfg.clone()))
        .run(JobSpec::new(items), |_w, r| {
            std::hint::black_box(r.len());
        });
}

fn bench<F: FnMut() -> usize>(label: &str, mut f: F) {
    // warmup
    let mut ops = f();
    let t0 = Instant::now();
    let reps = 5;
    for _ in 0..reps {
        ops = f();
    }
    let per_op = t0.elapsed().as_secs_f64() / (reps * ops) as f64;
    println!("  {label:<44} {:>12}/op  ({ops} ops/rep)", fmt_duration(per_op));
}

fn main() {
    let opts = PartitionerOptions::default();

    println!("== partitioner next_chunk (N=1M, P=20) ==");
    for scheme in Scheme::ALL {
        bench(scheme.name(), || {
            let p = Partitioner::new(scheme, 0, 1_000_000, 20, &opts);
            let mut n = 0;
            while p.next_chunk().is_some() {
                n += 1;
            }
            n
        });
    }

    println!("\n== central queue pull (SS chunks, N=1M) ==");
    bench("locked (mutex + getNextChunk)", || {
        let src = CentralLocked::new(Scheme::Ss, 1_000_000, 20, &opts);
        let mut n = 0;
        while src.pull_local(0).is_some() {
            n += 1;
        }
        n
    });
    bench("atomic (precomputed + fetch_add)", || {
        let src = CentralAtomic::new(Scheme::Ss, 1_000_000, 20, &opts);
        let mut n = 0;
        while src.pull_local(0).is_some() {
            n += 1;
        }
        n
    });

    println!("\n== multi-queue pull+steal (FAC2, N=1M, broadwell20) ==");
    let topo = Topology::broadwell20();
    bench("percore drain via pull_from", || {
        let src = build_source(
            QueueLayout::PerCore,
            Scheme::Fac2,
            1_000_000,
            &topo,
            &opts,
        );
        let mut n = 0;
        for q in 0..src.n_queues() {
            while src.pull_from(q, 0).is_some() {
                n += 1;
            }
        }
        n
    });

    println!("\n== executor dispatch: spawn-per-stage vs persistent ==");
    // 100 small 1-stage jobs: the repeated-pipeline pattern (CC
    // iterations, linreg epochs). The persistent pool pays thread spawn
    // once; the legacy path pays it per job.
    let exec_topo = Topology::host();
    let exec_cfg = SchedConfig::default().with_scheme(Scheme::Gss);
    bench("spawn-per-stage (oneshot x 100 jobs)", || {
        for _ in 0..100 {
            spawn_per_stage(&exec_topo, &exec_cfg, 10_000);
        }
        100
    });
    let exec = Executor::new(
        Arc::new(exec_topo.clone()),
        Arc::new(exec_cfg.clone()),
    );
    bench("persistent executor (submit x 100 jobs)", || {
        for _ in 0..100 {
            exec.run(JobSpec::new(10_000), |_w, r| {
                std::hint::black_box(r.len());
            });
        }
        100
    });

    println!("\n== dag vs barrier: diamond A -> {{B, C}} -> D ==");
    // Unbalanced branches that each use only half the pool: under a
    // full barrier B and C run back-to-back with half the workers idle
    // each time; dag dispatch launches both the moment A completes, so
    // the light branch hides inside the heavy one.
    let half = (exec.n_workers() / 2).max(1);
    let spin = |iters: usize| {
        move |_w: usize, r: TaskRange| {
            for _ in r.iter() {
                let mut acc = 0u64;
                for k in 0..iters {
                    acc = acc.wrapping_add(
                        std::hint::black_box(k as u64).wrapping_mul(0x9E37_79B9),
                    );
                }
                std::hint::black_box(acc);
            }
        }
    };
    let (heavy, light, tiny) = (4_000_000usize, 1_000_000, 10_000);
    bench("barrier (4 sequential jobs)", || {
        exec.run(JobSpec::new(half).named("a"), spin(tiny));
        exec.run(JobSpec::new(half).named("b"), spin(heavy));
        exec.run(JobSpec::new(half).named("c"), spin(light));
        exec.run(JobSpec::new(half).named("d"), spin(tiny));
        1
    });
    bench("dag (submit_graph, B and C overlap)", || {
        let diamond = GraphSpec::new("diamond")
            .node(NodeSpec::new("a", half), spin(tiny))
            .node(NodeSpec::new("b", half).after("a"), spin(heavy))
            .node(NodeSpec::new("c", half).after("a"), spin(light))
            .node(
                NodeSpec::new("d", half).after("b").after("c"),
                spin(tiny),
            );
        exec.run_graph(diamond).expect("diamond is acyclic");
        1
    });
    drop(exec);

    println!("\n== DES event throughput ==");
    let w = Workload::uniform("u", 200_000, 1e-7);
    let costs = CostModel::recorded();
    bench("simulate(ss, central, broadwell20)", || {
        let cfg = SchedConfig::default().with_scheme(Scheme::Ss);
        let out = simulate(&topo, &cfg, &w, &costs);
        out.acquisitions
    });
    let _ = VictimStrategy::ALL;

    println!("\n== DES graph replay (autotune oracle cost) ==");
    // One oracle evaluation of graph-level autotuning: the virtual-time
    // diamond replayed dag vs barrier on the modelled 56-core machine
    // (branches half the pool wide, as in the figure and tests).
    let cl56 = Topology::cascadelake56();
    let shape = GraphShape::unbalanced_diamond(cl56.n_cores() / 2);
    let sim_cfg = SchedConfig::default();
    bench("replay(diamond, cascadelake56, dag)", || {
        let out = replay(&shape, &cl56, &sim_cfg, &costs, GraphMode::Dag)
            .expect("diamond is acyclic");
        out.nodes.len()
    });
    bench("replay(diamond, cascadelake56, barrier)", || {
        let out =
            replay(&shape, &cl56, &sim_cfg, &costs, GraphMode::Barrier)
                .expect("diamond is acyclic");
        out.nodes.len()
    });

    println!("\n== native CC propagate kernel ==");
    let g = amazon_like(&SnapGraph::small(200_000, 1)).symmetrize();
    let ids: Vec<f32> = (0..g.rows).map(|i| (i + 1) as f32).collect();
    let mut out = vec![0f32; g.rows];
    let nnz = g.nnz();
    bench("cc_propagate_rows (per nnz)", || {
        ops::cc_propagate_rows(&g, &ids, &mut out, 0, g.rows);
        nnz
    });
}
