//! Minimal JSON parser — just enough for `artifacts/manifest.json` and the
//! coordinator's config payloads. No external deps (serde is not in the
//! vendored crate set).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let n = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(n)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Serialize a JSON value (used by the coordinator protocol tests).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "block_shapes": {"cc": [128, 1024], "lr": [256, 128]},
          "stages": {
            "lr_syrk": {"file": "lr_syrk.hlo.txt", "args": [[256,128]],
                        "outputs": 1, "dtype": "f32"}
          }
        }"#;
        let v = parse(doc).unwrap();
        let cc = v.get("block_shapes").unwrap().get("cc").unwrap();
        assert_eq!(cc.as_arr().unwrap()[1].as_usize(), Some(1024));
        let stage = v.get("stages").unwrap().get("lr_syrk").unwrap();
        assert_eq!(stage.get("file").unwrap().as_str(), Some("lr_syrk.hlo.txt"));
        assert_eq!(stage.get("outputs").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    // The Chrome-trace writer (`obs::export`) leans on exactly these
    // paths: escaped event names, nested event objects, and large
    // fractional microsecond timestamps.

    #[test]
    fn escapes_control_chars_and_round_trips() {
        let s = "tab\tnl\nquote\"back\\slash bell\u{7}";
        let v = Json::Str(s.into());
        let enc = to_string(&v);
        assert!(enc.contains("\\t") && enc.contains("\\n"), "{enc}");
        assert!(enc.contains("\\\"") && enc.contains("\\\\"), "{enc}");
        assert!(enc.contains("\\u0007"), "{enc}");
        assert_eq!(parse(&enc).unwrap(), v);
    }

    #[test]
    fn unicode_passes_through_unescaped() {
        let v = Json::Str("wörker λ → ✓".into());
        let enc = to_string(&v);
        assert!(enc.contains("wörker λ → ✓"), "{enc}");
        assert_eq!(parse(&enc).unwrap(), v);
        // and the escaped spelling decodes to the same string
        assert_eq!(
            parse(r#""w\u00f6rker""#).unwrap(),
            Json::Str("wörker".into())
        );
    }

    #[test]
    fn large_f64_timestamps_round_trip() {
        // trace timestamps are ts_ns / 1e3 microseconds: fractional,
        // and up to u64::MAX / 1e3 for the latest representable event
        let stamps = [
            0.001f64,
            1.5,
            123_456_789.25,
            1e15 + 0.5,
            u64::MAX as f64 / 1e3,
        ];
        for &ts in &stamps {
            let v = Json::Num(ts);
            match parse(&to_string(&v)).unwrap() {
                Json::Num(back) => assert_eq!(back, ts, "ts {ts}"),
                other => panic!("expected number, got {other:?}"),
            }
        }
    }

    #[test]
    fn nested_trace_shape_round_trips() {
        // the writer's document shape: {"traceEvents": [{...}, ...]}
        // with a per-event args object holding nested values
        let mut ev = BTreeMap::new();
        ev.insert("name".to_string(), Json::Str("run q1\t\"x\"".into()));
        ev.insert("ph".to_string(), Json::Str("B".into()));
        ev.insert("ts".to_string(), Json::Num(1_234_567.891));
        ev.insert(
            "args".to_string(),
            Json::Obj(BTreeMap::from([(
                "stack".to_string(),
                Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]),
            )])),
        );
        let mut top = BTreeMap::new();
        top.insert(
            "traceEvents".to_string(),
            Json::Arr(vec![Json::Obj(ev.clone()), Json::Obj(ev)]),
        );
        let doc = Json::Obj(top);
        let back = parse(&to_string(&doc)).unwrap();
        assert_eq!(back, doc);
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(
            evs[0].get("name").unwrap().as_str(),
            Some("run q1\t\"x\"")
        );
    }
}
