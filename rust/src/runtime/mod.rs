//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and
//! executes them from the rust hot path. Python never runs at runtime.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits `HloModuleProto`s with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py`).
//!
//! The PJRT execution path needs the external `xla` crate, which the
//! build environment may not provide; it is gated behind the `pjrt`
//! cargo feature (add the `xla` dependency when enabling it). Without
//! the feature, [`Runtime::load`] returns a descriptive error at
//! runtime and everything else in the crate works normally — the PJRT
//! integration tests skip when no artifacts are present.

pub mod artifact;
pub mod service;

use std::path::PathBuf;

pub use artifact::{Manifest, StageSpec};
pub use service::{DeviceClient, DeviceService};

#[cfg(feature = "pjrt")]
pub use pjrt_enabled::{Runtime, Stage};
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{Runtime, Stage};

/// Default artifact location (`artifacts/` at the repo root, or
/// `$DAPHNE_ARTIFACTS`).
fn default_artifact_dir() -> PathBuf {
    std::env::var_os("DAPHNE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_enabled {

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{Manifest, StageSpec};

/// A compiled pipeline stage.
pub struct Stage {
    pub spec: StageSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Stage {
    /// Execute with f32 buffers; each input must match the manifest
    /// shape. Returns one flattened f32 vec per output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.args.len() {
            bail!(
                "stage {}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.args.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (buf, shape)) in
            inputs.iter().zip(&self.spec.args).enumerate()
        {
            let expect: usize = shape.iter().product();
            if buf.len() != expect {
                bail!(
                    "stage {}: input {i} has {} elements, shape {shape:?} \
                     needs {expect}",
                    self.spec.name,
                    buf.len()
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.spec.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.spec.name))?;
        // aot.py lowers with return_tuple=True: unpack n outputs
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", self.spec.name))?;
        if parts.len() != self.spec.outputs {
            bail!(
                "stage {}: got {} outputs, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs
            );
        }
        parts
            .iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .map_err(|e| anyhow!("read output: {e:?}"))
            })
            .collect()
    }
}

/// The artifact registry: a PJRT CPU client plus every compiled stage.
pub struct Runtime {
    pub dir: PathBuf,
    pub platform: String,
    stages: BTreeMap<String, Stage>,
}

impl Runtime {
    /// Load and compile every stage in `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("create PJRT CPU client: {e:?}"))?;
        let platform = client.platform_name();
        let mut stages = BTreeMap::new();
        for spec in manifest.stages {
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", spec.name))?;
            stages.insert(spec.name.clone(), Stage { spec, exe });
        }
        Ok(Runtime { dir: dir.to_path_buf(), platform, stages })
    }

    /// Default artifact location (`artifacts/` at the repo root, or
    /// `$DAPHNE_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    pub fn stage(&self, name: &str) -> Result<&Stage> {
        self.stages
            .get(name)
            .ok_or_else(|| anyhow!("no stage '{name}' in {}", self.dir.display()))
    }

    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.keys().map(|s| s.as_str()).collect()
    }
}

}

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::StageSpec;

/// Stub of the compiled-stage handle, present when the crate is built
/// without the `pjrt` feature (no `xla` dependency available).
pub struct Stage {
    pub spec: StageSpec,
}

impl Stage {
    pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!(
            "stage {}: built without the `pjrt` feature — rebuild with \
             `--features pjrt` and the `xla` crate to execute artifacts",
            self.spec.name
        )
    }
}

/// Stub runtime: [`Runtime::load`] always errors, so callers (the
/// device service, the `pjrt=1` CLI path) fail with a clear message at
/// runtime instead of at compile time.
pub struct Runtime {
    pub dir: PathBuf,
    pub platform: String,
}

impl Runtime {
    pub fn load(dir: &Path) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable for {}: this build has no `pjrt` \
             feature (the `xla` crate is not vendored); native execution \
             paths are unaffected",
            dir.display()
        )
    }

    /// Default artifact location (`artifacts/` at the repo root, or
    /// `$DAPHNE_ARTIFACTS`).
    pub fn default_dir() -> PathBuf {
        super::default_artifact_dir()
    }

    pub fn stage(&self, name: &str) -> Result<&Stage> {
        bail!("no stage '{name}': PJRT runtime built without `pjrt` feature")
    }

    pub fn stage_names(&self) -> Vec<&str> {
        Vec::new()
    }
}

}
