//! DaphneDSL abstract syntax tree.

/// Binary operators in precedence groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Gt,
    Lt,
    Ge,
    Le,
    Eq,
    Ne,
    And,
    Or,
}

/// Expressions.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Num(f64),
    Str(String),
    /// `$name` CLI parameter.
    Param(String),
    Var(String),
    /// `f(a, b, ...)` builtin call.
    Call(String, Vec<Expr>),
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Unary minus.
    Neg(Box<Expr>),
    /// `X[, cols]` right (column) indexing — the only indexing form the
    /// listings use.
    ColIndex(Box<Expr>, Box<Expr>),
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// `name = expr;`
    Assign(String, Expr),
    /// `while (cond) { body }`
    While(Expr, Vec<Stmt>),
    /// bare expression statement (e.g. `print(x);`)
    Expr(Expr),
}

/// A parsed program.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    pub stmts: Vec<Stmt>,
}
