//! Shared-slice writer for data-parallel tasks.
//!
//! The scheduler guarantees every work-item index is handed out exactly
//! once (see `sched::queue` property tests), so tasks write disjoint
//! ranges of the output. `DisjointMut` exposes that contract with
//! `unsafe` confined to one audited place.

use std::marker::PhantomData;

/// A slice whose disjoint ranges may be written concurrently.
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: `&DisjointMut<T>` only hands out views of disjoint ranges
// (mutable views must not overlap anything, shared views require
// `T: Sync`), so sharing the handle across threads moves each `T` to
// at most one writer at a time — exactly the `T: Send` contract.
// Concurrent access is restricted to disjoint ranges by the
// scheduler's partitioning invariant; `slice_mut` documents the
// requirement.
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}
// SAFETY: the handle owns no `T` storage (it borrows the caller's
// slice), so sending it to another thread transfers only the right to
// write `T` values there, which `T: Send` permits.
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        DisjointMut {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            _marker: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `[start, end)`.
    ///
    /// # Safety contract
    /// Callers must ensure no two concurrently-live views overlap. The
    /// scheduler's exactly-once partitioning provides this for task
    /// ranges.
    #[allow(clippy::mut_from_ref)]
    pub fn slice_mut(&self, start: usize, end: usize) -> &mut [T] {
        assert!(start <= end && end <= self.len, "range out of bounds");
        // SAFETY: bounds checked above; the backing allocation outlives
        // 'a; disjointness per the documented contract.
        unsafe {
            std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
        }
    }

    /// Shared (read-only) view of `[start, end)`.
    ///
    /// Requires `T: Sync` because overlapping shared views may be read
    /// from several threads at once (a `T` with interior mutability
    /// that is `Send` but not `Sync`, like `Cell`, would make that a
    /// data race).
    ///
    /// # Safety contract
    /// Callers must ensure no concurrently-live *mutable* view overlaps
    /// this range; shared views may overlap each other freely. Task
    /// graphs get this from dependency ordering — a node that wrote
    /// through [`DisjointMut::slice_mut`] completes before its
    /// dependent readers dispatch, so e.g. two independent reduction
    /// nodes can both read the rows a predecessor standardized.
    pub fn slice(&self, start: usize, end: usize) -> &[T]
    where
        T: Sync,
    {
        assert!(start <= end && end <= self.len, "range out of bounds");
        // SAFETY: bounds checked above; the backing allocation outlives
        // 'a; no overlapping mutable view per the documented contract.
        unsafe { std::slice::from_raw_parts(self.ptr.add(start), end - start) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_threaded_writes_land() {
        let mut v = vec![0usize; 1000];
        {
            let d = DisjointMut::new(&mut v);
            std::thread::scope(|s| {
                for t in 0..4 {
                    let d = &d;
                    s.spawn(move || {
                        let lo = t * 250;
                        for (i, x) in
                            d.slice_mut(lo, lo + 250).iter_mut().enumerate()
                        {
                            *x = lo + i;
                        }
                    });
                }
            });
        }
        assert!(v.iter().enumerate().all(|(i, &x)| x == i));
    }

    #[test]
    fn concurrent_shared_reads_after_writes() {
        let mut v: Vec<usize> = (0..1000).collect();
        let d = DisjointMut::new(&mut v);
        let sums: Vec<usize> = std::thread::scope(|s| {
            // overlapping shared views from several threads are fine
            let handles: Vec<_> = (0..4)
                .map(|_| s.spawn(|| d.slice(0, 1000).iter().sum::<usize>()))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for sum in sums {
            assert_eq!(sum, 499_500);
        }
    }

    #[test]
    fn len_reports() {
        let mut v = vec![0u8; 7];
        let d = DisjointMut::new(&mut v);
        assert_eq!(d.len(), 7);
        assert!(!d.is_empty());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn bounds_checked() {
        let mut v = vec![0u8; 4];
        let d = DisjointMut::new(&mut v);
        d.slice_mut(2, 8);
    }
}
