//! The stealing protocol shared by the real-thread executor and the DES.
//!
//! Contribution C.2: a thief does not take a fixed number of tasks — it
//! asks the victim queue's partitioner for *its next chunk*, so the
//! stolen amount follows the configured self-scheduling technique
//! (decreasing under GSS/TSS/FAC2, fixed under MFSC, growing under
//! FISS/VISS...). This resolves "how much should a thief steal" by reusing
//! the work-partitioning answer.

use super::queue::{Pull, TaskSource};
use super::victim::VictimSelector;

/// Outcome of one steal round.
#[derive(Debug, Clone, Copy)]
pub struct StealOutcome {
    pub pull: Option<Pull>,
    /// Queues probed before success / giving up (contention accounting).
    pub attempts: usize,
}

/// Try one full round of victims; stop at the first queue that yields a
/// task. An empty round (no victims or all empty) returns `pull: None`,
/// which — because partitioners never refill — means global work is
/// exhausted for this thief.
pub fn steal_round(
    source: &dyn TaskSource,
    selector: &mut VictimSelector,
    worker: usize,
) -> StealOutcome {
    let mut attempts = 0;
    for victim in selector.round() {
        attempts += 1;
        if let Some(pull) = source.pull_from(victim, worker) {
            debug_assert!(pull.stolen || victim == source.queue_of(worker));
            return StealOutcome { pull: Some(pull), attempts };
        }
    }
    StealOutcome { pull: None, attempts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::{PartitionerOptions, Scheme};
    use crate::sched::queue::{MultiQueue, QueueLayout};
    use crate::sched::victim::VictimStrategy;
    use crate::topology::Topology;

    fn selector(strategy: VictimStrategy, own: usize, topo: &Topology) -> VictimSelector {
        let qs: Vec<usize> = (0..topo.n_cores()).map(|c| topo.socket_of(c)).collect();
        VictimSelector::new(strategy, own, topo.socket_of(own), qs, 42)
    }

    #[test]
    fn thief_gets_chunk_from_victim_block() {
        let topo = Topology::broadwell20();
        let mq = MultiQueue::new(
            QueueLayout::PerCore,
            Scheme::Gss,
            2000,
            &topo,
            &PartitionerOptions::default(),
        );
        // Drain worker 0's own queue.
        while mq.pull_local(0).is_some() {}
        let mut sel = selector(VictimStrategy::Seq, 0, &topo);
        let out = steal_round(&mq, &mut sel, 0);
        let pull = out.pull.expect("other queues have work");
        assert!(pull.stolen);
        assert_ne!(pull.queue, 0);
        // PERCORE deals the *global* GSS sequence round-robin; queue 1
        // holds the 2nd global chunk: ceil((2000 - 100)/20) = 95.
        assert_eq!(pull.task.len(), 95);
    }

    #[test]
    fn stolen_chunks_follow_scheme_sequence() {
        // C.2: successive steals from one victim follow the victim
        // partitioner's GSS sequence (decaying), not a fixed constant.
        let topo = Topology::symmetric("t2", 1, 2, 1.0, 1.0);
        let mq = MultiQueue::new(
            QueueLayout::PerCore,
            Scheme::Gss,
            2048,
            &topo,
            &PartitionerOptions::default(),
        );
        while mq.pull_local(0).is_some() {}
        let mut sel = selector(VictimStrategy::Seq, 0, &topo);
        let mut sizes = Vec::new();
        for _ in 0..4 {
            let out = steal_round(&mq, &mut sel, 0);
            sizes.push(out.pull.unwrap().task.len());
        }
        // global GSS sequence on 2048/P=2: 1024, 512, 256, 128, 64, 32,
        // 16, 8...; odd-indexed chunks land in queue 1, so the thief
        // sees 512, 128, 32, 8 — still the scheme's (dealt) sequence,
        // not a fixed steal amount (C.2).
        assert_eq!(sizes, vec![512, 128, 32, 8]);
    }

    #[test]
    fn steal_round_reports_attempts_when_all_empty() {
        let topo = Topology::broadwell20();
        let mq = MultiQueue::new(
            QueueLayout::PerCore,
            Scheme::Static,
            20,
            &topo,
            &PartitionerOptions::default(),
        );
        for q in 0..20 {
            while mq.pull_from(q, q).is_some() {}
        }
        let mut sel = selector(VictimStrategy::Rnd, 3, &topo);
        let out = steal_round(&mq, &mut sel, 3);
        assert!(out.pull.is_none());
        assert_eq!(out.attempts, 19, "must have probed every other queue");
    }
}
