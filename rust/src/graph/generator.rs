//! Synthetic co-purchase graph generator (data substitution, DESIGN.md §3).
//!
//! Amazon's co-purchase network is well modelled by a *copying/
//! preferential-attachment* process \[Leskovec, Adamic & Huberman, ACM
//! TWEB'07\]: each new product links to a handful of others, copying some
//! of an existing product's links (yielding the heavy-tailed in-degree)
//! and picking some uniformly (keeping the long tail populated). The
//! scheduling-relevant property — the per-row nnz skew that drives task
//! cost variance — matches the real data's shape; `EXPERIMENTS.md`
//! records the generated distributions.

use crate::matrix::CsrMatrix;
use crate::util::Rng;

/// Parameters of the synthetic co-purchase graph.
#[derive(Debug, Clone)]
pub struct SnapGraph {
    pub nodes: usize,
    /// Outgoing edges per new node (SNAP Amazon0601 averages ~8.4 per
    /// node; the paper's source set 403,394 nodes / 3,387,388 edges).
    pub out_degree: usize,
    /// Probability an edge copies a neighbour of an existing node
    /// (preferential attachment) vs a uniform pick.
    pub copy_prob: f64,
    pub seed: u64,
}

impl SnapGraph {
    /// The SNAP Amazon co-purchase graph at 1/k of its original size
    /// (`amazon_snap_spec(1)` = full 403k-node source set).
    pub fn amazon(scale_down: usize) -> Self {
        SnapGraph {
            nodes: 403_394 / scale_down.max(1),
            out_degree: 8,
            copy_prob: 0.7,
            seed: 0xA9A2_0601,
        }
    }

    /// A small spec for tests and quickstarts.
    pub fn small(nodes: usize, seed: u64) -> Self {
        SnapGraph { nodes, out_degree: 8, copy_prob: 0.7, seed }
    }
}

/// Generate a directed co-purchase-like graph as CSR.
pub fn amazon_like(spec: &SnapGraph) -> CsrMatrix {
    let n = spec.nodes.max(2);
    let mut rng = Rng::new(spec.seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * spec.out_degree);
    // flat targets list doubles as the preferential-attachment urn:
    // picking a uniform element of `targets` selects nodes ∝ in-degree.
    let mut urn: Vec<u32> = vec![0, 1];
    edges.push((0, 1));
    edges.push((1, 0));

    for v in 2..n as u32 {
        let d = spec.out_degree.min(v as usize);
        let mut picked = Vec::with_capacity(d);
        while picked.len() < d {
            let t = if rng.next_f64() < spec.copy_prob {
                *rng.choose(&urn)
            } else {
                rng.below(v as u64) as u32
            };
            if t != v && !picked.contains(&t) {
                picked.push(t);
            }
        }
        for t in picked {
            edges.push((v, t));
            urn.push(t);
            urn.push(v);
        }
    }

    // Relabel nodes with a seeded *bucketed* permutation. The copying
    // process concentrates hubs at low ids; real SNAP ids are neither
    // degree-sorted (a full identity would make STATIC's first block
    // carry most of the mass) nor fully random (co-purchase communities
    // give consecutive product ids correlated degrees). Shuffling
    // contiguous buckets keeps community-level cost clustering while
    // dispersing the global degree gradient — the block-level cost
    // variance that drives the paper's STATIC-vs-dynamic margins.
    let bucket = (n / 256).max(1);
    let n_buckets = n.div_ceil(bucket);
    let mut order: Vec<usize> = (0..n_buckets).collect();
    rng.shuffle(&mut order);
    let mut perm = vec![0u32; n];
    let mut next = 0u32;
    for &b in &order {
        for old in (b * bucket)..((b + 1) * bucket).min(n) {
            perm[old] = next;
            next += 1;
        }
    }
    for e in &mut edges {
        *e = (perm[e.0 as usize], perm[e.1 as usize]);
    }
    CsrMatrix::from_edges(n, n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn deterministic_per_seed() {
        let a = amazon_like(&SnapGraph::small(500, 7));
        let b = amazon_like(&SnapGraph::small(500, 7));
        assert_eq!(a, b);
        let c = amazon_like(&SnapGraph::small(500, 8));
        assert_ne!(a, c);
    }

    #[test]
    fn edge_count_close_to_degree_times_nodes() {
        let g = amazon_like(&SnapGraph::small(2000, 1));
        let expect = 2000 * 8;
        assert!(
            g.nnz() > expect * 8 / 10 && g.nnz() <= expect,
            "nnz={} expect~{expect}",
            g.nnz()
        );
    }

    #[test]
    fn in_degree_is_heavy_tailed() {
        // The scheduling-relevant property: reverse-edge (in-degree)
        // distribution must be skewed — max ≫ mean, like real
        // co-purchase data.
        let g = amazon_like(&SnapGraph::small(5000, 3)).symmetrize();
        let costs = g.row_costs();
        let mean = stats::mean(&costs);
        let max = stats::max(&costs);
        assert!(
            max > 10.0 * mean,
            "degree distribution not heavy-tailed: max={max} mean={mean}"
        );
        // and the c.o.v. should be substantial (>1 for power-law-ish)
        assert!(stats::cov(&costs) > 0.8, "cov={}", stats::cov(&costs));
    }

    #[test]
    fn no_self_loops() {
        let g = amazon_like(&SnapGraph::small(1000, 5));
        for r in 0..g.rows {
            assert!(!g.row(r).contains(&(r as u32)), "self loop at {r}");
        }
    }

    #[test]
    fn single_connected_component_when_symmetrized() {
        // The copying process always attaches to existing nodes, so the
        // undirected version is connected — matching the dominant giant
        // component of the real data.
        let g = amazon_like(&SnapGraph::small(800, 11)).symmetrize();
        let mut seen = vec![false; g.rows];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &c in g.row(v) {
                if !seen[c as usize] {
                    seen[c as usize] = true;
                    stack.push(c as usize);
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "graph not connected");
    }
}
