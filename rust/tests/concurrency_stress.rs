//! Seeded stress test for the cross-job re-pick boundary under a
//! cancellation storm, across all three tenancy policies.
//!
//! A worker re-evaluates its cross-job pick every
//! [`POLICY_REPICK_STRIDE`] items, so the storm submits bursts of
//! single-node graphs sized one item short of / exactly at / just past
//! the stride (plus multiples), while a second thread cancels a seeded
//! third of the handles mid-flight. The invariant under test is
//! exactly-once execution: no item ever runs twice, a Completed node
//! covered every item, and the pool keeps serving full-width jobs after
//! every round. The schedule itself is nondeterministic — the *seeds*
//! are fixed so the submitted workload and the cancel subset are
//! reproducible.
//!
//! This suite is one of the two run under ThreadSanitizer in CI (see
//! `.github/workflows/ci.yml`): the bodies are pure atomic traffic, so
//! a data race in the executor's queue/pick/cancel paths is the only
//! thing TSan can find here.

#![cfg(not(miri))]

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use daphne_sched::config::SchedConfig;
use daphne_sched::sched::{
    Executor, GraphSpec, JobSpec, NodeSpec, NodeStatus, SubmitOpts,
    TenancyPolicy, POLICY_REPICK_STRIDE,
};
use daphne_sched::topology::Topology;

const ROUNDS: usize = 6;
const JOBS_PER_ROUND: usize = 18;
const WORKERS: usize = 4;

/// xorshift64 — deterministic workload/cancel seeding without any
/// wall-clock or OS entropy.
struct XorShift(u64);

impl XorShift {
    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

/// A full-width job that completes only once every worker has entered
/// it: hangs (failing by timeout) if a round leaked a slot or wedged a
/// worker.
fn all_workers_barrier(exec: &Executor) {
    let entered = Arc::new(AtomicUsize::new(0));
    let seen: Arc<Mutex<HashSet<usize>>> = Arc::new(Mutex::new(HashSet::new()));
    let (n, s) = (Arc::clone(&entered), Arc::clone(&seen));
    let h = exec.submit(
        JobSpec::new(WORKERS)
            .named("barrier")
            .with_config(SchedConfig::default()),
        move |w, _r| {
            s.lock().unwrap().insert(w);
            n.fetch_add(1, Ordering::SeqCst);
            while n.load(Ordering::SeqCst) < WORKERS {
                std::thread::yield_now();
            }
        },
    );
    let report = h.wait();
    assert_eq!(report.total_items(), WORKERS);
    assert_eq!(seen.lock().unwrap().len(), WORKERS, "every worker served");
}

fn stress_policy(policy: TenancyPolicy, policy_idx: u64) {
    let exec = Executor::new_with_policy(
        Arc::new(Topology::symmetric("t4", 1, WORKERS, 1.0, 1.0)),
        // per-item chunks on the central atomic queue: the preemption
        // quantum is one item, so re-picks happen at the stride exactly
        Arc::new(SchedConfig::fine_grained()),
        policy,
    );
    let session = exec.session();
    let s = POLICY_REPICK_STRIDE;
    let sizes = [s - 1, s, s + 1, 2 * s, 3 * s + 1, 1];
    let tags = ["etl", "dash", "adhoc"];

    for round in 0..ROUNDS {
        let mut rng = XorShift(
            0x9E37_79B9_7F4A_7C15 ^ ((round as u64 + 1) << 8) ^ policy_idx,
        );
        let mut handles = Vec::new();
        let mut trackers: Vec<(usize, Arc<Vec<AtomicUsize>>)> = Vec::new();
        for j in 0..JOBS_PER_ROUND {
            let size = sizes[(rng.next_u64() as usize) % sizes.len()];
            let hits: Arc<Vec<AtomicUsize>> =
                Arc::new((0..size).map(|_| AtomicUsize::new(0)).collect());
            let h2 = Arc::clone(&hits);
            let spec = GraphSpec::new("stress").node(
                NodeSpec::new("n", size),
                move |_w, r| {
                    for i in r.iter() {
                        h2[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            let opts = SubmitOpts::new()
                .tag(tags[j % tags.len()])
                .priority((rng.next_u64() % 3) as i64)
                .weight(1 + rng.next_u64() % 4);
            let h = session.submit_graph(spec, opts).expect("valid spec");
            handles.push(h);
            trackers.push((size, hits));
        }

        // Cancel a seeded third of the round's graphs from a second
        // thread, racing the workers mid-stint.
        let cancel_seed = rng.next_u64() | 1;
        std::thread::scope(|sc| {
            let hs = &handles;
            sc.spawn(move || {
                let mut rng = XorShift(cancel_seed);
                for h in hs.iter() {
                    if rng.next_u64() % 3 == 0 {
                        h.cancel();
                    }
                    std::thread::yield_now();
                }
            });
        });

        for (h, (size, hits)) in handles.into_iter().zip(trackers) {
            let report = h.join();
            let status = report.status("n").expect("node exists");
            let ran: usize = hits.iter().map(|a| a.load(Ordering::Relaxed)).sum();
            for (i, a) in hits.iter().enumerate() {
                assert!(
                    a.load(Ordering::Relaxed) <= 1,
                    "item {i} ran twice (policy {policy:?}, round {round})"
                );
            }
            match status {
                NodeStatus::Completed => assert_eq!(
                    ran, size,
                    "completed node missed items \
                     (policy {policy:?}, round {round})"
                ),
                NodeStatus::Cancelled => assert!(ran <= size),
                other => panic!(
                    "unexpected terminal status {other:?} \
                     (policy {policy:?}, round {round})"
                ),
            }
        }
        all_workers_barrier(&exec);
    }
}

#[test]
fn fifo_survives_a_repick_boundary_cancel_storm() {
    stress_policy(TenancyPolicy::Fifo, 1);
}

#[test]
fn fair_survives_a_repick_boundary_cancel_storm() {
    stress_policy(TenancyPolicy::Fair, 2);
}

#[test]
fn priority_survives_a_repick_boundary_cancel_storm() {
    stress_policy(TenancyPolicy::Priority, 3);
}
