//! Connected components (Listing 1) over the co-purchase graph.
//!
//! ```text
//! c = seq(1, n);
//! while (diff > 0 & iter <= maxi) {
//!     u = max(rowMaxs(G * t(c)), c);   # neighbour propagation
//!     diff = sum(u != c);
//!     c = u;
//! }
//! ```
//!
//! The propagation step is the scheduled vectorized operator: work items
//! are matrix rows, per-item cost ∝ row nnz (highly skewed — this is the
//! workload where the paper's dynamic schemes beat STATIC). Two
//! executions of the same pipeline exist:
//!
//! - **native**: CSR row kernel ([`crate::matrix::ops::cc_propagate_rows`]),
//!   the production path for the 20M-node scaled graph;
//! - **pjrt**: the AOT Pallas artifact `cc_propagate` over dense tiles,
//!   proving the three-layer composition (used on small graphs).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::SchedConfig;
use crate::matrix::CsrMatrix;
use crate::runtime::{DeviceClient, Manifest};
use crate::sched::{SchedReport, SubmitOpts};
use crate::sim::{self, CostModel, GraphShape, NodeModel, Workload};
use crate::topology::Topology;
use crate::util::DisjointMut;
use crate::vee::{report_from_graph, Pipeline, Vee};

/// Result of a connected-components run.
#[derive(Debug, Clone)]
pub struct CcResult {
    /// Final component label per vertex.
    pub labels: Vec<f32>,
    /// Iterations until fixpoint (or maxi).
    pub iterations: usize,
    /// Number of distinct components.
    pub components: usize,
    /// Per-iteration scheduling reports of the propagate operator.
    pub reports: Vec<SchedReport>,
    /// Per-iteration reports of the scheduled `diff` reduction (both
    /// the native and the PJRT path schedule it).
    pub diff_reports: Vec<SchedReport>,
}

impl CcResult {
    /// Total scheduled time across every job this run submitted
    /// (propagate + diff per iteration).
    pub fn total_time(&self) -> f64 {
        self.reports
            .iter()
            .chain(&self.diff_reports)
            .map(|r| r.makespan)
            .sum()
    }
}

/// The body of the scheduled `diff` reduction on both execution paths:
/// label mismatches between one task's window of the old and new label
/// vectors.
fn count_mismatches(new_labels: &[f32], old_labels: &[f32]) -> usize {
    new_labels
        .iter()
        .zip(old_labels)
        .filter(|(a, b)| a != b)
        .count()
}

fn count_components(labels: &[f32]) -> usize {
    // labels converge to the max vertex id of each component; count
    // fixpoints where label(v) == v+1 (ids are 1-based).
    labels
        .iter()
        .enumerate()
        .filter(|(i, &l)| l == (*i as f32) + 1.0)
        .count()
}

/// Native CSR execution under the given scheduling configuration.
///
/// Convenience wrapper: spawns a fresh engine (and worker pool) for the
/// run. Callers executing several configurations should build one
/// [`Vee`] and use [`run_with`] / [`Vee::with_config`] so every run
/// shares the same resident pool.
pub fn run_native(
    g: &CsrMatrix,
    topo: &Topology,
    sched: &SchedConfig,
    maxi: usize,
) -> CcResult {
    run_with(&Vee::new(topo.clone(), sched.clone()), g, maxi)
}

/// Native CSR execution on an existing engine: every iteration is one
/// task graph on the engine's resident pool expressing the loop body's
/// real dependency shape — the scheduled `propagate` operator followed
/// by the `diff` reduction (`diff = sum(u != c)`), which reads the
/// propagated labels and therefore carries a true dependency edge.
/// Worker threads are spawned exactly once per engine, not per
/// iteration or stage.
pub fn run_with(vee: &Vee, g: &CsrMatrix, maxi: usize) -> CcResult {
    let n = g.rows;
    // c = seq(1, n)
    let mut c: Vec<f32> = (0..n).map(|i| (i + 1) as f32).collect();
    let mut u = vec![0f32; n];
    let mut reports = Vec::new();
    let mut diff_reports = Vec::new();
    let mut iterations = 0;

    for _ in 0..maxi {
        iterations += 1;
        let diff_count = AtomicUsize::new(0);
        let report = {
            let out = DisjointMut::new(&mut u);
            let pipeline = iteration_pipeline(g, &c, &out, &diff_count);
            vee.run_pipeline(&pipeline)
        };
        reports.push(
            report
                .stage("propagate")
                .cloned()
                .expect("propagate stage always present"),
        );
        diff_reports.push(
            report.stage("diff").cloned().expect("diff stage always present"),
        );
        let diff = diff_count.load(Ordering::Relaxed);
        std::mem::swap(&mut c, &mut u);
        if diff == 0 {
            break;
        }
    }

    let components = count_components(&c);
    CcResult { labels: c, iterations, components, reports, diff_reports }
}

/// One CC loop iteration as a pipeline over borrowed label buffers:
/// the scheduled `propagate` operator writing into `out`'s disjoint
/// windows, then the `diff` reduction reading the labels it wrote (a
/// true dependency edge). Shared by [`run_with`] (one pipeline at a
/// time) and [`run_concurrent`] (many pipelines fused on one session).
fn iteration_pipeline<'a, 'b: 'a>(
    g: &'a CsrMatrix,
    c_ref: &'a [f32],
    out: &'a DisjointMut<'b, f32>,
    diff_count: &'a AtomicUsize,
) -> Pipeline<'a> {
    let n = g.rows;
    Pipeline::new("cc:iter")
        .stage("propagate", n, move |_w, range| {
            let slice = out.slice_mut(range.start, range.end);
            // write into the task's disjoint window
            for (off, r) in range.iter().enumerate() {
                let mut m = c_ref[r];
                for &col in g.row(r) {
                    let v = c_ref[col as usize];
                    if v > m {
                        m = v;
                    }
                }
                slice[off] = m;
            }
        })
        // diff = sum(u != c), parallel partial counts over the
        // labels `propagate` just wrote (shared reads are sound:
        // the writer node completed before this one dispatches)
        .stage("diff", n, move |_w, range| {
            let mismatches = count_mismatches(
                out.slice(range.start, range.end),
                &c_ref[range.start..range.end],
            );
            if mismatches > 0 {
                diff_count.fetch_add(mismatches, Ordering::Relaxed);
            }
        })
}

/// Per-pipeline state of one concurrent CC tenant.
struct CcJobState {
    c: Vec<f32>,
    u: Vec<f32>,
    converged: bool,
    iterations: usize,
    reports: Vec<SchedReport>,
    diff_reports: Vec<SchedReport>,
}

/// Run `jobs` identical CC pipelines *concurrently* through one
/// [`Session`](crate::sched::Session) of the engine's resident pool —
/// submission happens entirely on the calling thread; the only OS
/// threads involved are the executor's workers. Each round fuses the
/// unconverged pipelines' iteration graphs (`propagate → diff` each,
/// tagged `cc<i>`) into one merged scheduling horizon via
/// `Session::run_all`, so the executor's tenancy policy — not
/// submission interleaving — decides how the pool serves them.
///
/// Fused submission is dependency-aware (dag) dispatch by
/// construction — the engine's `graph=barrier` knob does not apply
/// here; callers wanting the barrier A/B baseline run sequential
/// [`run_with`] loops instead (as the CLI does).
///
/// Panics if `vee` is a one-shot engine (there is no resident pool to
/// share; callers fall back to sequential [`run_with`] loops).
pub fn run_concurrent(
    vee: &Vee,
    g: &CsrMatrix,
    jobs: usize,
    maxi: usize,
) -> Vec<CcResult> {
    let session = vee
        .session()
        .expect("run_concurrent needs the persistent executor");
    let n = g.rows;
    let mut states: Vec<CcJobState> = (0..jobs)
        .map(|_| CcJobState {
            c: (0..n).map(|i| (i + 1) as f32).collect(),
            u: vec![0f32; n],
            converged: false,
            iterations: 0,
            reports: Vec::new(),
            diff_reports: Vec::new(),
        })
        .collect();

    for _ in 0..maxi {
        let live: Vec<usize> = states
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.converged)
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            break;
        }
        let diffs: Vec<AtomicUsize> =
            live.iter().map(|_| AtomicUsize::new(0)).collect();
        let round_reports = {
            // Per-live-pipeline borrowed views for this round: the old
            // labels read-only, the new labels as disjoint task windows.
            let views: Vec<(&[f32], DisjointMut<'_, f32>)> = states
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| live.contains(i))
                .map(|(_, s)| (s.c.as_slice(), DisjointMut::new(&mut s.u)))
                .collect();
            let pipelines: Vec<Pipeline<'_>> = views
                .iter()
                .zip(&diffs)
                .map(|((c_ref, out), diff)| {
                    iteration_pipeline(g, c_ref, out, diff)
                })
                .collect();
            let specs = pipelines
                .iter()
                .zip(&live)
                .map(|(p, &i)| {
                    (
                        p.to_graph_spec(&vee.sched),
                        SubmitOpts::new().tag(&format!("cc{i}")),
                    )
                })
                .collect();
            session
                .run_all(specs)
                .expect("cc iteration graphs are acyclic")
        };
        for (graph, &i) in round_reports.into_iter().zip(&live) {
            let report = report_from_graph(graph);
            let s = &mut states[i];
            s.iterations += 1;
            s.reports.push(
                report
                    .stage("propagate")
                    .cloned()
                    .expect("propagate stage always present"),
            );
            s.diff_reports.push(
                report
                    .stage("diff")
                    .cloned()
                    .expect("diff stage always present"),
            );
        }
        for (k, &i) in live.iter().enumerate() {
            let s = &mut states[i];
            std::mem::swap(&mut s.c, &mut s.u);
            if diffs[k].load(Ordering::Relaxed) == 0 {
                s.converged = true;
            }
        }
    }

    states
        .into_iter()
        .map(|s| {
            let components = count_components(&s.c);
            CcResult {
                labels: s.c,
                iterations: s.iterations,
                components,
                reports: s.reports,
                diff_reports: s.diff_reports,
            }
        })
        .collect()
}

/// PJRT execution: the propagate step runs the AOT `cc_propagate`
/// artifact over dense `[cc_rows, cc_cols]` tiles (zero-padded; inert
/// because ids >= 1). A task = one row block; the scheduler hands out
/// row-block ranges exactly as in the native path. Kernel launches go
/// through the device-service thread (see `runtime::service`).
pub fn run_pjrt(
    g: &CsrMatrix,
    device: &DeviceClient,
    manifest: &Manifest,
    topo: &Topology,
    sched: &SchedConfig,
    maxi: usize,
) -> anyhow::Result<CcResult> {
    let (block_rows, block_cols) = manifest.cc_block;
    let n = g.rows;
    let n_row_blocks = n.div_ceil(block_rows);
    let n_col_blocks = n.div_ceil(block_cols);
    let vee = Vee::new(topo.clone(), sched.clone());

    let mut c: Vec<f32> = (0..n).map(|i| (i + 1) as f32).collect();
    let mut u = vec![0f32; n];
    let mut reports = Vec::new();
    let mut diff_reports = Vec::new();
    let mut iterations = 0;

    // padded column vector of ids, rebuilt each iteration
    for _ in 0..maxi {
        iterations += 1;
        let mut c_pad = vec![0f32; n_col_blocks * block_cols];
        c_pad[..n].copy_from_slice(&c);
        let c_pad = &c_pad;
        let c_ref = &c;
        let report = {
            // the mutable view of `u` lives only for the propagate pass
            let out = DisjointMut::new(&mut u);
            // work items are row *blocks* on this path
            vee.execute(n_row_blocks, |_w, range| {
                for rb in range.iter() {
                    let r0 = rb * block_rows;
                    let r1 = ((rb + 1) * block_rows).min(n);
                    // c_row block, zero-padded
                    let mut c_row = vec![0f32; block_rows];
                    c_row[..r1 - r0].copy_from_slice(&c_ref[r0..r1]);
                    let mut acc = c_row.clone();
                    for cb in 0..n_col_blocks {
                        let g_tile = g.densify_window(
                            r0,
                            r0 + block_rows,
                            cb * block_cols,
                            (cb + 1) * block_cols,
                        );
                        let c_tile = c_pad
                            [cb * block_cols..(cb + 1) * block_cols]
                            .to_vec();
                        let outs = device
                            .run_f32(
                                "cc_propagate",
                                vec![g_tile.data, c_tile, acc.clone()],
                            )
                            .expect("cc_propagate artifact failed");
                        acc.copy_from_slice(&outs[0]);
                    }
                    out.slice_mut(r0, r1).copy_from_slice(&acc[..r1 - r0]);
                }
            })
        };
        reports.push(report);
        // scheduled diff reduction, mirroring the native path so
        // total_time() stays comparable across backends
        let diff_count = AtomicUsize::new(0);
        {
            let (c_ref, u_ref) = (&c, &u);
            let diff_count = &diff_count;
            diff_reports.push(vee.execute(n, |_w, range| {
                let mismatches = count_mismatches(
                    &u_ref[range.start..range.end],
                    &c_ref[range.start..range.end],
                );
                if mismatches > 0 {
                    diff_count.fetch_add(mismatches, Ordering::Relaxed);
                }
            }));
        }
        let diff = diff_count.load(Ordering::Relaxed);
        std::mem::swap(&mut c, &mut u);
        if diff == 0 {
            break;
        }
    }

    let components = count_components(&c);
    Ok(CcResult { labels: c, iterations, components, reports, diff_reports })
}

/// Count iterations to convergence without timing anything (cheap
/// native fixpoint, used to parameterize the DES figures).
pub fn converge_iterations(g: &CsrMatrix, maxi: usize) -> usize {
    let topo = Topology::symmetric("seq", 1, 1, 1.0, 1.0);
    run_native(g, &topo, &SchedConfig::default(), maxi).iterations
}

/// DES workload for one propagate pass: per-row cost is affine in the
/// row's nnz, with constants from host calibration of the native kernel.
pub fn workload(g: &CsrMatrix, per_row: f64, per_nnz: f64) -> Workload {
    let costs: Vec<f64> = (0..g.rows)
        .map(|r| per_row + per_nnz * g.row_nnz(r) as f64)
        .collect();
    Workload::from_costs("cc_propagate", &costs)
}

/// One CC loop iteration's real task graph as a cost-described
/// [`GraphShape`] for virtual-time replay — the same
/// `propagate → diff` structure [`run_with`] submits per iteration.
/// `propagate` cost is affine in row nnz ([`workload`]); `diff` is one
/// label compare per row, costed at the calibrated per-row base.
pub fn iteration_shape(g: &CsrMatrix, per_row: f64, per_nnz: f64) -> GraphShape {
    GraphShape::new("cc:iter")
        .node(NodeModel::new("propagate", workload(g, per_row, per_nnz)))
        .node(
            NodeModel::new(
                "diff",
                Workload::uniform("cc_diff", g.rows, per_row),
            )
            .after("propagate"),
        )
}

/// Simulate the full CC run (iterations × one propagate pass) on a
/// modelled machine. Chunk sequences re-randomize per iteration via the
/// seed so PSS/RND* average sensibly.
pub fn simulate_run(
    g: &CsrMatrix,
    topo: &Topology,
    sched: &SchedConfig,
    costs: &CostModel,
    iterations: usize,
    per_row: f64,
    per_nnz: f64,
) -> (f64, Vec<sim::SimOutcome>) {
    let w = workload(g, per_row, per_nnz);
    let mut outcomes = Vec::with_capacity(iterations);
    let mut total = 0.0;
    for it in 0..iterations {
        let cfg = SchedConfig {
            seed: sched.seed.wrapping_add(it as u64),
            ..sched.clone()
        };
        let out = sim::simulate(topo, &cfg, &w, costs);
        total += out.makespan();
        outcomes.push(out);
    }
    (total, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{amazon_like, SnapGraph};
    use crate::matrix::CsrMatrix;
    use crate::sched::{QueueLayout, Scheme, VictimStrategy};

    fn two_triangles() -> CsrMatrix {
        // components {0,1,2} and {3,4}
        CsrMatrix::from_edges(
            5,
            5,
            &[(0, 1), (1, 0), (1, 2), (2, 1), (3, 4), (4, 3)],
        )
    }

    #[test]
    fn finds_two_components() {
        let g = two_triangles();
        let topo = Topology::symmetric("t", 1, 2, 1.0, 1.0);
        let r = run_native(&g, &topo, &SchedConfig::default(), 100);
        assert_eq!(r.components, 2);
        // labels converge to max id of each component (1-based)
        assert_eq!(r.labels, vec![3.0, 3.0, 3.0, 5.0, 5.0]);
        assert!(r.iterations >= 2);
    }

    #[test]
    fn connected_graph_single_component() {
        let g = amazon_like(&SnapGraph::small(300, 3)).symmetrize();
        let topo = Topology::symmetric("t", 1, 4, 1.0, 1.0);
        let r = run_native(&g, &topo, &SchedConfig::default(), 100);
        assert_eq!(r.components, 1);
        assert!(r.labels.iter().all(|&l| l == 300.0));
    }

    #[test]
    fn all_schemes_agree_on_labels() {
        let g = amazon_like(&SnapGraph::small(500, 9)).symmetrize();
        let topo = Topology::symmetric("t", 2, 2, 1.5, 1.0);
        let baseline =
            run_native(&g, &topo, &SchedConfig::default(), 100).labels;
        for scheme in Scheme::ALL {
            let cfg = SchedConfig::default()
                .with_scheme(scheme)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::RndPri);
            let r = run_native(&g, &topo, &cfg, 100);
            assert_eq!(r.labels, baseline, "{scheme:?} diverged");
        }
    }

    #[test]
    fn isolated_vertices_are_their_own_component() {
        let g = CsrMatrix::from_edges(4, 4, &[(0, 1), (1, 0)]);
        let topo = Topology::symmetric("t", 1, 1, 1.0, 1.0);
        let r = run_native(&g, &topo, &SchedConfig::default(), 100);
        assert_eq!(r.components, 3); // {0,1}, {2}, {3}
    }

    #[test]
    fn concurrent_pipelines_agree_with_sequential() {
        use crate::sched::TenancyPolicy;
        let g = amazon_like(&SnapGraph::small(400, 5)).symmetrize();
        for policy in TenancyPolicy::ALL {
            let vee = crate::vee::Vee::new(
                Topology::symmetric("t", 1, 4, 1.0, 1.0),
                SchedConfig::default(),
            )
            .with_tenancy_policy(policy);
            let baseline = run_with(&vee, &g, 100);
            let results = run_concurrent(&vee, &g, 3, 100);
            assert_eq!(results.len(), 3);
            for r in &results {
                assert_eq!(r.labels, baseline.labels, "{policy:?}");
                assert_eq!(r.iterations, baseline.iterations);
                assert_eq!(r.components, baseline.components);
                assert_eq!(r.reports.len(), r.iterations);
                assert_eq!(r.diff_reports.len(), r.iterations);
            }
            // one resident pool served every concurrent pipeline
            assert_eq!(vee.executor().unwrap().n_workers(), 4);
        }
    }

    #[test]
    fn multi_iteration_run_spawns_workers_once() {
        // A 31-node chain needs ~30 propagate iterations; every one must
        // be a job on the engine's single resident pool.
        let edges: Vec<(u32, u32)> = (0..30u32)
            .flat_map(|i| [(i, i + 1), (i + 1, i)])
            .collect();
        let g = CsrMatrix::from_edges(31, 31, &edges);
        let vee = crate::vee::Vee::new(
            Topology::symmetric("t", 1, 2, 1.0, 1.0),
            SchedConfig::default(),
        );
        let r = run_with(&vee, &g, 100);
        assert!(r.iterations >= 10, "chain converged in {}", r.iterations);
        assert_eq!(r.components, 1);
        let exec = vee.executor().unwrap();
        assert_eq!(exec.n_workers(), 2, "pool sized once from the topology");
        assert_eq!(
            exec.jobs_completed(),
            2 * r.iterations,
            "one propagate + one diff job per iteration, zero respawns"
        );
    }

    #[test]
    fn converge_iterations_matches_run() {
        let g = amazon_like(&SnapGraph::small(200, 4)).symmetrize();
        let topo = Topology::symmetric("t", 1, 2, 1.0, 1.0);
        let r = run_native(&g, &topo, &SchedConfig::default(), 100);
        assert_eq!(converge_iterations(&g, 100), r.iterations);
    }

    #[test]
    fn workload_costs_follow_nnz() {
        let g = two_triangles();
        let w = workload(&g, 1e-9, 1e-8);
        // row 1 has 2 nnz, rows 0,2,3,4 have 1
        assert!((w.chunk_cost(1, 2) - 21e-9).abs() < 1e-15);
        assert!((w.chunk_cost(0, 1) - 11e-9).abs() < 1e-15);
    }

    #[test]
    fn iteration_shape_replays_propagate_then_diff() {
        use crate::config::GraphMode;
        let g = amazon_like(&SnapGraph::small(2_000, 5)).symmetrize();
        let shape = iteration_shape(&g, 1e-8, 5e-9);
        let topo = Topology::broadwell20();
        let out = sim::replay(
            &shape,
            &topo,
            &SchedConfig::default(),
            &CostModel::recorded(),
            GraphMode::Dag,
        )
        .unwrap();
        let prop = out.node("propagate").unwrap();
        let diff = out.node("diff").unwrap();
        assert_eq!(prop.outcome.report.total_items(), g.rows);
        assert_eq!(diff.outcome.report.total_items(), g.rows);
        assert_eq!(diff.start, prop.finish, "diff waits for the labels");
    }

    #[test]
    fn simulate_run_scales_with_iterations() {
        let g = amazon_like(&SnapGraph::small(2_000, 5)).symmetrize();
        let topo = Topology::broadwell20();
        let cm = CostModel::recorded();
        let sched = SchedConfig::default().with_scheme(Scheme::Mfsc);
        let (t2, o2) = simulate_run(&g, &topo, &sched, &cm, 2, 1e-8, 5e-9);
        let (t4, o4) = simulate_run(&g, &topo, &sched, &cm, 4, 1e-8, 5e-9);
        assert_eq!(o2.len(), 2);
        assert_eq!(o4.len(), 4);
        assert!(t4 > 1.8 * t2 && t4 < 2.2 * t2);
    }
}
