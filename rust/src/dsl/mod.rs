//! DaphneDSL subset: lexer, parser and interpreter able to run the
//! paper's Listings 1 (connected components) and 2 (linear regression)
//! verbatim.
//!
//! The interpreter lowers vectorizable operators (`rowMaxs(G * t(c))`,
//! `syrk`, `gemv`, `mean`/`stddev`, elementwise maps) onto the VEE, so a
//! DSL script executes under the configured scheduling exactly like the
//! native pipelines — scheduling reports are collected per operator.

pub mod ast;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod value;

pub use interp::{Interp, RunOutput};
pub use value::Value;

use crate::vee::Vee;
use std::collections::BTreeMap;

/// Parse and run a script with `$param` bindings on an engine.
pub fn run_script(
    src: &str,
    params: &BTreeMap<String, String>,
    vee: &Vee,
) -> Result<RunOutput, String> {
    let tokens = lexer::lex(src)?;
    let program = parser::parse(&tokens)?;
    let interp = Interp::new(params.clone(), vee.clone());
    interp.run(&program)
}

/// The paper's Listing 1, verbatim.
pub const LISTING_1_CC: &str = r#"
# Connected components.
# Arguments: - f ... adjacency matrix filename
# Read adjacency matrix.
G = readMatrix($f);
# Initializations.
n = nrow(G);
maxi = 100;
c = seq(1, n);
diff = inf;
iter = 1;
# Iterative computation.
while (diff > 0 & iter <= maxi) {
  u = max(rowMaxs(G * t(c)), c); # Neighbor propagation
  diff = sum(u != c);            # Changed vertices.
  c = u;                         # Update assignment.
  iter = iter + 1;
}
"#;

/// The paper's Listing 2, verbatim.
pub const LISTING_2_LINREG: &str = r#"
# Linear regression model training on random data.
# Data generation (in double precision).
XY = rand($numRows, $numCols, 0.0, 1.0, 1, -1);
# Extraction of X and y.
X = XY[, seq(0, as.si64($numCols) - 2, 1)];
y = XY[, seq(as.si64($numCols) - 1, as.si64($numCols) - 1, 1)];
# Normalization, standardization.
Xmeans = mean(X, 1);
Xstddev = stddev(X, 1);
X = (X - Xmeans) / Xstddev;
X = cbind(X, fill(1.0, nrow(X), 1));
A = syrk(X);
lambda = fill(0.001, ncol(X), 1);
A = A + diagMatrix(lambda);
b = gemv(X, y);
beta = solve(A, b);
"#;
