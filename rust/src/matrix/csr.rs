//! Compressed-sparse-row matrix — the substrate for the connected-
//! components workload (the Amazon co-purchase graph is ~0.002% dense,
//! so the adjacency matrix only ever materialises as CSR).

use super::dense::DenseMatrix;

/// CSR matrix with unit values elided (an adjacency structure): only the
/// pattern matters for `G * t(c)` when G is 0/1, which is all the CC
/// pipeline needs. `vals` is optional for weighted uses.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, `rows + 1` entries.
    pub indptr: Vec<usize>,
    /// Column indices, sorted within each row.
    pub indices: Vec<u32>,
    /// Optional explicit values (None = all ones).
    pub vals: Option<Vec<f32>>,
}

impl CsrMatrix {
    /// Build from an edge list (unsorted, may contain duplicates).
    pub fn from_edges(rows: usize, cols: usize, edges: &[(u32, u32)]) -> Self {
        let mut counts = vec![0usize; rows + 1];
        for &(r, _) in edges {
            counts[r as usize + 1] += 1;
        }
        for i in 0..rows {
            counts[i + 1] += counts[i];
        }
        let mut indices = vec![0u32; edges.len()];
        let mut fill = counts.clone();
        for &(r, c) in edges {
            indices[fill[r as usize]] = c;
            fill[r as usize] += 1;
        }
        // sort + dedup within rows
        let mut indptr = vec![0usize; rows + 1];
        let mut out = Vec::with_capacity(indices.len());
        for r in 0..rows {
            let seg = &mut indices[counts[r]..counts[r + 1]];
            seg.sort_unstable();
            let before = out.len();
            let mut last = u32::MAX;
            for &c in seg.iter() {
                if c != last {
                    out.push(c);
                    last = c;
                }
            }
            indptr[r + 1] = indptr[r] + (out.len() - before);
        }
        CsrMatrix { rows, cols, indptr, indices: out, vals: None }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Density in [0, 1].
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// Column indices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[u32] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Number of non-zeros in row `r` — the task-cost driver for CC.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Per-row nnz as f64 (cost-model input).
    pub fn row_costs(&self) -> Vec<f64> {
        (0..self.rows).map(|r| self.row_nnz(r) as f64).collect()
    }

    /// Densify a row/column window into `[rows, cols]` f32 (the PJRT CC
    /// path feeds dense tiles to the `cc_propagate` artifact).
    pub fn densify_window(
        &self,
        row_start: usize,
        row_end: usize,
        col_start: usize,
        col_end: usize,
    ) -> DenseMatrix {
        let (r0, r1) = (row_start, row_end.min(self.rows));
        let (c0, c1) = (col_start, col_end.min(self.cols));
        let mut out = DenseMatrix::zeros(row_end - row_start, col_end - col_start);
        for r in r0..r1 {
            let row = out.row_mut(r - r0);
            for (k, &c) in self.row(r).iter().enumerate() {
                let c = c as usize;
                if c >= c0 && c < c1 {
                    let v = self
                        .vals
                        .as_ref()
                        .map(|v| v[self.indptr[r] + k])
                        .unwrap_or(1.0);
                    row[c - c0] = v;
                }
            }
        }
        out
    }

    /// Make the pattern symmetric (the CC algorithm expects an
    /// undirected graph; the SNAP data is directed co-purchase edges).
    pub fn symmetrize(&self) -> CsrMatrix {
        let mut edges = Vec::with_capacity(self.nnz() * 2);
        for r in 0..self.rows {
            for &c in self.row(r) {
                edges.push((r as u32, c));
                edges.push((c, r as u32));
            }
        }
        CsrMatrix::from_edges(
            self.rows.max(self.cols),
            self.rows.max(self.cols),
            &edges,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn small() -> CsrMatrix {
        // 0 -> {1, 2}, 1 -> {2}, 2 -> {}, 3 -> {0}
        CsrMatrix::from_edges(4, 4, &[(0, 2), (0, 1), (1, 2), (3, 0)])
    }

    #[test]
    fn from_edges_sorts_and_counts() {
        let m = small();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row(0), &[1, 2]);
        assert_eq!(m.row(1), &[2]);
        assert_eq!(m.row(2), &[] as &[u32]);
        assert_eq!(m.row(3), &[0]);
        assert_eq!(m.row_nnz(0), 2);
    }

    #[test]
    fn duplicate_edges_are_deduped() {
        let m = CsrMatrix::from_edges(2, 2, &[(0, 1), (0, 1), (0, 1)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.row(0), &[1]);
    }

    #[test]
    fn density_of_small() {
        assert!((small().density() - 4.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn densify_window_places_entries() {
        let m = small();
        let d = m.densify_window(0, 2, 1, 3);
        // rows 0..2, cols 1..3: row0 has cols {1,2} -> [1,1]; row1 {2} -> [0,1]
        assert_eq!(d.row(0), &[1.0, 1.0]);
        assert_eq!(d.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn densify_window_pads_beyond_bounds() {
        let m = small();
        let d = m.densify_window(3, 6, 0, 8);
        assert_eq!(d.rows, 3);
        assert_eq!(d.cols, 8);
        assert_eq!(d.row(0)[0], 1.0); // edge 3->0
        assert!(d.row(1).iter().all(|&x| x == 0.0)); // padded row
        assert!(d.row(2).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn symmetrize_adds_reverse_edges() {
        let m = small().symmetrize();
        assert!(m.row(2).contains(&0)); // reverse of 0->2
        assert!(m.row(0).contains(&3)); // reverse of 3->0
        // symmetric: nnz counts both directions exactly once each
        for r in 0..m.rows {
            for &c in m.row(r) {
                assert!(m.row(c as usize).contains(&(r as u32)), "{r}->{c}");
            }
        }
    }

    #[test]
    fn prop_from_edges_preserves_edge_set() {
        prop::check("csr edge set preserved", 50, |rng: &mut Rng| {
            let rows = rng.range(1, 50) as usize;
            let cols = rng.range(1, 50) as usize;
            let n_edges = rng.range(0, 200) as usize;
            let edges: Vec<(u32, u32)> = (0..n_edges)
                .map(|_| {
                    (rng.below(rows as u64) as u32, rng.below(cols as u64) as u32)
                })
                .collect();
            let m = CsrMatrix::from_edges(rows, cols, &edges);
            // every input edge present
            for &(r, c) in &edges {
                prop::ensure(
                    m.row(r as usize).contains(&c),
                    format!("missing edge {r}->{c}"),
                )?;
            }
            // rows sorted and unique
            for r in 0..rows {
                let row = m.row(r);
                prop::ensure(
                    row.windows(2).all(|w| w[0] < w[1]),
                    format!("row {r} not sorted-unique: {row:?}"),
                )?;
            }
            // indptr consistent
            prop::ensure(
                m.indptr[rows] == m.nnz(),
                "indptr tail != nnz".to_string(),
            )
        });
    }
}
