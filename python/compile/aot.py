"""AOT lowering: JAX stage functions -> HLO-text artifacts for rust/PJRT.

Interchange format is HLO **text**, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``<stage>.hlo.txt`` per entry in ``model.STAGES`` plus a
``manifest.json`` describing shapes, which the rust artifact registry
cross-checks at load time.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(name: str):
    fn, arg_shapes = model.STAGES[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in arg_shapes]
    lowered = jax.jit(fn).lower(*specs)
    n_outputs = len(lowered.out_info)
    return to_hlo_text(lowered), n_outputs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--stages",
        nargs="*",
        default=sorted(model.STAGES),
        help="subset of stages to lower (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "block_shapes": {
            "cc": [model.CC_ROWS, model.CC_COLS],
            "lr": [model.LR_ROWS, model.LR_COLS],
        },
        "stages": {},
    }
    for name in args.stages:
        text, n_outputs = lower_stage(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["stages"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [list(s) for s in model.STAGES[name][1]],
            "outputs": n_outputs,
            "dtype": "f32",
        }
        print(f"lowered {name:>16} -> {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
