//! Integration: AOT JAX/Pallas artifacts executed from rust via PJRT,
//! validated against the native rust kernels. Requires `make artifacts`
//! (tests skip with a notice if artifacts are absent).
//!
//! The non-`pjrt` build is *tested*, not just compiled: the stub
//! `Runtime`/`DeviceService` must fail with descriptive errors (no
//! hangs, no panics), and GPU-class graph placement must degrade
//! gracefully to the CPU pool with an annotated `NodeReport`.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use daphne_sched::apps::{cc, linreg};
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::matrix::DenseMatrix;
use daphne_sched::runtime::{DeviceService, Runtime};
use daphne_sched::sched::{QueueLayout, Scheme};
use daphne_sched::topology::Topology;
use daphne_sched::util::Rng;

fn artifacts_ready() -> bool {
    let ok = Runtime::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
    }
    ok
}

fn topo() -> Topology {
    Topology::symmetric("t", 1, 2, 1.0, 1.0)
}

#[test]
fn device_service_runs_cc_propagate_tile() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (rows, cols) = service.manifest.cc_block;
    // G = single edge row0 -> col3; ids = index+1
    let mut g = vec![0f32; rows * cols];
    g[3] = 1.0;
    let c: Vec<f32> = (0..cols).map(|i| (i + 1) as f32).collect();
    let c_row: Vec<f32> = (0..rows).map(|i| (i + 1) as f32).collect();
    let out = client
        .run_f32("cc_propagate", vec![g, c.clone(), c_row.clone()])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), rows);
    // row 0: max(own id 1, neighbour id 4) = 4; all others keep own id
    assert_eq!(out[0][0], 4.0);
    for (i, &v) in out[0].iter().enumerate().skip(1) {
        assert_eq!(v, (i + 1) as f32, "row {i}");
    }
}

#[test]
fn device_service_concurrent_clients() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (rows, cols) = service.manifest.lr_block;
    let mut rng = Rng::new(11);
    let x = DenseMatrix::rand(rows, cols, 0.0, 1.0, rng.next_u64());
    std::thread::scope(|s| {
        for _ in 0..4 {
            let client = client.clone();
            let x = x.data.clone();
            s.spawn(move || {
                let out = client.run_f32("lr_colstats", vec![x]).unwrap();
                assert_eq!(out.len(), 2);
                assert_eq!(out[0].len(), cols);
            });
        }
    });
}

#[test]
fn pjrt_cc_matches_native_labels() {
    if !artifacts_ready() {
        return;
    }
    let g = amazon_like(&SnapGraph::small(300, 21)).symmetrize();
    let (service, client) = DeviceService::start_default().unwrap();
    let sched = SchedConfig::default().with_scheme(Scheme::Gss);
    let native = cc::run_native(&g, &topo(), &sched, 100);
    let pjrt = cc::run_pjrt(
        &g,
        &client,
        &service.manifest,
        &topo(),
        &sched,
        100,
    )
    .unwrap();
    assert_eq!(native.labels, pjrt.labels);
    assert_eq!(native.iterations, pjrt.iterations);
    assert_eq!(native.components, pjrt.components);
}

#[test]
fn pjrt_linreg_matches_native_beta() {
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (_, d) = service.manifest.lr_block;
    let n = 1024;
    let mut rng = Rng::new(5);
    let x = DenseMatrix::rand(n, d, 0.0, 1.0, rng.next_u64());
    let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let sched = SchedConfig::default()
        .with_scheme(Scheme::Fac2)
        .with_layout(QueueLayout::PerCore);
    let native = linreg::run_native(&x, &y, 1e-3, &topo(), &sched).unwrap();
    let pjrt = linreg::run_pjrt(
        &x,
        &y,
        1e-3,
        &client,
        &service.manifest,
        &topo(),
        &sched,
    )
    .unwrap();
    assert_eq!(native.beta.len(), pjrt.beta.len());
    for (i, (a, b)) in native.beta.iter().zip(&pjrt.beta).enumerate() {
        assert!(
            (a - b).abs() < 5e-2 * a.abs().max(1.0),
            "beta[{i}]: native {a} vs pjrt {b}"
        );
    }
}

#[test]
fn pjrt_linreg_handles_padding_tail() {
    // n not a multiple of the block: the closed-form padding correction
    // must keep A/b exact.
    if !artifacts_ready() {
        return;
    }
    let (service, client) = DeviceService::start_default().unwrap();
    let (block_rows, d) = service.manifest.lr_block;
    let n = block_rows + 37;
    let mut rng = Rng::new(9);
    let x = DenseMatrix::rand(n, d, 0.0, 1.0, rng.next_u64());
    let y: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let sched = SchedConfig::default();
    let native = linreg::run_native(&x, &y, 1e-3, &topo(), &sched).unwrap();
    let pjrt = linreg::run_pjrt(
        &x,
        &y,
        1e-3,
        &client,
        &service.manifest,
        &topo(),
        &sched,
    )
    .unwrap();
    for (i, (a, b)) in native.beta.iter().zip(&pjrt.beta).enumerate() {
        assert!(
            (a - b).abs() < 5e-2 * a.abs().max(1.0),
            "beta[{i}]: native {a} vs pjrt {b}"
        );
    }
}

// ---------------------------------------------------------------------------
// stub path (built without the `pjrt` feature): graceful-fallback tests
// ---------------------------------------------------------------------------

/// The stub `Runtime` must error descriptively — naming the missing
/// feature — rather than pretending artifacts can execute.
#[cfg(not(feature = "pjrt"))]
#[test]
fn stub_runtime_load_errors_descriptively() {
    // `.err()` rather than `.expect_err()`: the stub Runtime is not Debug
    let err = Runtime::load(std::path::Path::new("artifacts"))
        .err()
        .expect("stub Runtime::load must always fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "error must name the feature: {msg}");
    assert!(
        msg.contains("artifacts"),
        "error must name the requested dir: {msg}"
    );
}

/// `DeviceService::start` against a parseable manifest must return a
/// descriptive `Err` on the stub build — the service thread exits and
/// is joined; the call neither hangs nor panics.
#[cfg(not(feature = "pjrt"))]
#[test]
fn stub_device_service_start_fails_gracefully() {
    let dir = std::env::temp_dir().join("daphne_sched_pjrt_stub_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{
          "block_shapes": {"cc": [128, 1024], "lr": [256, 128]},
          "stages": {
            "cc_propagate": {"file": "cc_propagate.hlo.txt",
                              "args": [[128, 1024], [1024], [128]],
                              "outputs": 1, "dtype": "f32"}
          }
        }"#,
    )
    .unwrap();
    let err = DeviceService::start(dir)
        .err()
        .expect("stub DeviceService::start must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "error must name the feature: {msg}");
}

/// GPU-class graph placement on a stub build: the node is rerouted to
/// the CPU pool (it executes there — asserted via worker ids), the
/// graph completes, and the degradation is annotated on the
/// `NodeReport` rather than silent.
#[cfg(not(feature = "pjrt"))]
#[test]
fn stub_gpu_placement_falls_back_to_cpu_pool_with_annotation() {
    use daphne_sched::sched::graph::GraphSpec;
    use daphne_sched::sched::{Executor, NodeSpec};
    use daphne_sched::topology::DeviceClass;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    let exec = Executor::new(
        Arc::new(Topology::heterogeneous(
            "h",
            1,
            2,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        )),
        Arc::new(SchedConfig::default()),
    );
    let items = AtomicUsize::new(0);
    let workers = Mutex::new(Vec::new());
    let spec = GraphSpec::new("gpu-fallback").node(
        NodeSpec::new("kernelish", 5_000).on(DeviceClass::Gpu),
        |w, r| {
            workers.lock().unwrap().push(w);
            items.fetch_add(r.len(), Ordering::Relaxed);
        },
    );
    let report = exec.run_graph(spec).unwrap();
    assert!(report.all_completed());
    assert_eq!(items.load(Ordering::Relaxed), 5_000);
    let node = report.node("kernelish").unwrap();
    assert_eq!(
        node.device,
        DeviceClass::Cpu,
        "stub build must reroute GPU placement to the CPU pool"
    );
    let note = node
        .fallback
        .as_ref()
        .expect("the degradation must be annotated, not silent");
    assert!(note.contains("pjrt"), "annotation names the cause: {note}");
    // CPU pool is workers 0..2 on this topology
    assert!(
        workers.lock().unwrap().iter().all(|&w| w < 2),
        "fallback node executed off the CPU pool"
    );
}

/// On a `pjrt` build the same placement is honoured: no fallback, the
/// node reports the GPU pool.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_gpu_placement_is_honoured_without_fallback() {
    use daphne_sched::sched::graph::GraphSpec;
    use daphne_sched::sched::{Executor, NodeSpec};
    use daphne_sched::topology::DeviceClass;
    use std::sync::Arc;

    let exec = Executor::new(
        Arc::new(Topology::heterogeneous(
            "h",
            1,
            2,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        )),
        Arc::new(SchedConfig::default()),
    );
    let spec = GraphSpec::new("gpu").node(
        NodeSpec::new("kernelish", 1_000).on(DeviceClass::Gpu),
        |_w, _r| {},
    );
    let report = exec.run_graph(spec).unwrap();
    let node = report.node("kernelish").unwrap();
    assert_eq!(node.device, DeviceClass::Gpu);
    assert!(node.fallback.is_none());
}
