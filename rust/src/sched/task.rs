//! Tasks: the smallest unit of work DaphneSched schedules.
//!
//! DAPHNE exploits data parallelism, so a task is a contiguous range of
//! fine-grained work items (rows of the input matrix); the partitioning
//! scheme decides each task's extent (variable-size tasks, Fig. 3b).

/// A half-open range `[start, end)` of work items forming one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskRange {
    pub start: usize,
    pub end: usize,
}

impl TaskRange {
    pub fn new(start: usize, end: usize) -> Self {
        debug_assert!(start <= end, "inverted task range {start}..{end}");
        TaskRange { start, end }
    }

    /// Number of work items in the task (its granularity).
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// Iterate over the item indices.
    pub fn iter(&self) -> std::ops::Range<usize> {
        self.start..self.end
    }

    /// Split off the first `n` items (used by the per-queue partitioners).
    pub fn split_first(&self, n: usize) -> (TaskRange, TaskRange) {
        let mid = (self.start + n).min(self.end);
        (
            TaskRange::new(self.start, mid),
            TaskRange::new(mid, self.end),
        )
    }
}

impl From<std::ops::Range<usize>> for TaskRange {
    fn from(r: std::ops::Range<usize>) -> Self {
        TaskRange::new(r.start, r.end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_and_empty() {
        assert_eq!(TaskRange::new(3, 10).len(), 7);
        assert!(TaskRange::new(4, 4).is_empty());
        assert!(!TaskRange::new(4, 5).is_empty());
    }

    #[test]
    fn split_first_respects_bounds() {
        let t = TaskRange::new(10, 20);
        let (a, b) = t.split_first(4);
        assert_eq!((a.start, a.end), (10, 14));
        assert_eq!((b.start, b.end), (14, 20));
        let (a, b) = t.split_first(100);
        assert_eq!(a, t);
        assert!(b.is_empty());
    }

    #[test]
    fn iter_covers_items() {
        let t = TaskRange::new(2, 5);
        assert_eq!(t.iter().collect::<Vec<_>>(), vec![2, 3, 4]);
    }
}
