//! Per-app cost calibration: fits the DES per-item constants from real
//! single-thread executions of the native kernels on this host.

use std::time::Instant;

use crate::graph::{amazon_like, SnapGraph};
use crate::matrix::{ops, DenseMatrix};

/// Per-item cost constants for the two workloads (seconds).
#[derive(Debug, Clone, Copy)]
pub struct AppCosts {
    /// CC propagate: fixed per-row cost.
    pub cc_per_row: f64,
    /// CC propagate: additional cost per stored nnz in the row.
    pub cc_per_nnz: f64,
    /// LR: cost of one row through one scheduled pass (d-column
    /// standardize+syrk+gemv averaged over the three passes).
    pub lr_per_row: f64,
    /// LR: serialized per-task reduction merge for the syrk pass. Every
    /// task folds its d×d partial of A into the shared accumulator
    /// under a lock, so the cost is per *task*, not per row — this is
    /// what makes fine-grained schemes ~2× slower than STATIC in
    /// Fig. 10 (the paper's "scheduling overhead can artificially
    /// introduce load imbalance ... contention on the work queue").
    /// 2.2 ms ≈ a ~2000-column partial at ~0.5 ns/element (the paper
    /// does not state numCols; DESIGN.md records this assumption).
    pub lr_merge: f64,
}

impl AppCosts {
    /// Values measured on the reference host (EXPERIMENTS.md
    /// §Calibration); used by default so bench output is reproducible.
    pub fn recorded() -> Self {
        AppCosts {
            cc_per_row: 10.3e-9,
            cc_per_nnz: 1.1e-9,
            lr_per_row: 8.7e-7,
            lr_merge: 2.2e-3,
        }
    }

    /// Measure on the current host.
    pub fn measure() -> Self {
        let (cc_per_row, cc_per_nnz) = measure_cc();
        AppCosts {
            cc_per_row,
            cc_per_nnz,
            lr_per_row: measure_lr(64),
            ..Self::recorded()
        }
    }
}

/// Fit `(per_row, per_nnz)` from two native propagate passes over graphs
/// with different densities (two equations, two unknowns).
pub fn measure_cc() -> (f64, f64) {
    let run = |out_degree: usize| -> (f64, f64, f64) {
        let spec = SnapGraph {
            nodes: 200_000,
            out_degree,
            copy_prob: 0.7,
            seed: 0xCA11,
        };
        let g = amazon_like(&spec).symmetrize();
        let ids: Vec<f32> = (0..g.rows).map(|i| (i + 1) as f32).collect();
        let mut out = vec![0f32; g.rows];
        // warm
        ops::cc_propagate_rows(&g, &ids, &mut out, 0, g.rows);
        let t0 = Instant::now();
        let reps = 3;
        for _ in 0..reps {
            ops::cc_propagate_rows(&g, &ids, &mut out, 0, g.rows);
        }
        let secs = t0.elapsed().as_secs_f64() / reps as f64;
        std::hint::black_box(&out);
        (secs, g.rows as f64, g.nnz() as f64)
    };
    let (t1, r1, n1) = run(4);
    let (t2, _r2, n2) = run(16);
    // t = per_row * r + per_nnz * n  (same row count both runs)
    let per_nnz = ((t2 - t1) / (n2 - n1)).max(1e-11);
    let per_row = ((t1 - per_nnz * n1) / r1).max(1e-11);
    (per_row, per_nnz)
}

/// Measure the per-row cost of one LR pass at `d` feature columns.
pub fn measure_lr(d: usize) -> f64 {
    let n = 20_000;
    let x = DenseMatrix::rand(n, d, 0.0, 1.0, 7);
    let y: Vec<f32> = vec![1.0; n];
    let mut a = vec![0f32; d * d];
    let mut b = vec![0f32; d];
    ops::syrk_rows(&x, &mut a, 0, n); // warm
    let t0 = Instant::now();
    let reps = 3;
    for _ in 0..reps {
        ops::syrk_rows(&x, &mut a, 0, n);
        ops::gemv_rows(&x, &y, &mut b, 0, n);
    }
    let secs = t0.elapsed().as_secs_f64() / reps as f64;
    std::hint::black_box((&a, &b));
    (secs / n as f64).max(1e-10)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_cc_costs_plausible() {
        let (per_row, per_nnz) = measure_cc();
        assert!((1e-11..1e-6).contains(&per_row), "per_row={per_row}");
        assert!((1e-11..1e-6).contains(&per_nnz), "per_nnz={per_nnz}");
    }

    #[test]
    fn measured_lr_cost_plausible() {
        let c = measure_lr(32);
        assert!((1e-9..1e-4).contains(&c), "lr_per_row={c}");
    }
}
