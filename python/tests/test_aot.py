"""AOT lowering tests: every stage lowers to parseable HLO text with the
expected entry computation, and the manifest matches model.STAGES."""

import json

from compile import aot, model


def test_all_stages_lower_to_hlo_text():
    for name in model.STAGES:
        text, n_outputs = aot.lower_stage(name)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        assert n_outputs >= 1, name
        # return_tuple=True => the root is a tuple even for 1 output
        assert "tuple" in text, name


def test_cc_artifact_has_expected_params():
    text, n_outputs = aot.lower_stage("cc_propagate")
    assert n_outputs == 1
    # G block, c (reshaped to 1xC inside the kernel wrapper), c_row
    assert f"f32[{model.CC_ROWS},{model.CC_COLS}]" in text
    assert f"f32[{model.CC_ROWS}]" in text


def test_manifest_writing(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = [
        "aot",
        "--out-dir",
        str(tmp_path),
        "--stages",
        "lr_syrk",
        "lr_gemv",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv

    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert set(manifest["stages"]) == {"lr_syrk", "lr_gemv"}
    for name, entry in manifest["stages"].items():
        hlo = (tmp_path / entry["file"]).read_text()
        assert "ENTRY" in hlo
        assert entry["args"] == [list(s) for s in model.STAGES[name][1]]
