//! Observability: event-level scheduler tracing, live serve metrics,
//! and Chrome-trace export with DES timeline parity.
//!
//! Three pieces, all designed around the executor's coordinator-free
//! dispatch and the PR 6 lock-rank discipline — the dispatch path must
//! never gain a lock (or an allocation) on account of being observed:
//!
//! - [`trace`] — bounded per-worker ring buffers of [`trace::TraceEvent`]s
//!   (atomics only; a disabled trace is one relaxed load and a branch).
//!   The hook points live in `sched::executor` / `sched::graph` /
//!   `sched::session` / `serve`, gated by the `trace=off|on|sampled:<n>`
//!   config key ([`crate::config::TraceMode`]).
//! - [`export`] — merges the rings into a Chrome trace-event JSON file
//!   (one lane per worker plus counter tracks; loadable in Perfetto)
//!   and distills an [`export::ObsSummary`] (steal efficiency,
//!   park/unpark churn, per-tag queue-delay histogram) for the CLI.
//! - [`live`] — a [`live::MetricsRegistry`] of atomic counters
//!   (admitted, shed, backlog high-water, steals, re-picks) snapshotted
//!   on an interval during `serve` soaks.
//!
//! The DES (`sim::graph` / `sim::serve`) emits the *same* event stream
//! in virtual time via [`trace::record_at`], so a real run and its
//! virtual-time replay are diffable timeline-for-timeline
//! (`rust/tests/obs_trace_integration.rs` pins per-job event-ordering
//! and admission-decision parity on a shared burst trace).
//!
//! On top of the recorder sit the post-hoc consumers:
//!
//! - [`analyze`] — critical-path extraction with queueing/service/
//!   migration attribution and a per-worker utilization waterfall,
//!   reconstructed from the event stream alone.
//! - [`report`] — the real-vs-DES divergence diff ([`report::diff_traces`])
//!   and the machine-readable `BENCH_<name>.json` emitter
//!   ([`report::BenchReport`]), plus the Chrome-trace service-time
//!   reader behind `tune ... calibrate=<trace.json>`.
//!
//! Layering: the recorder modules (`trace` / `export` / `live`) import
//! only `util` / `topology` / `config`; the analysis modules
//! (`analyze` / `report`) may additionally read `sim` *public* types —
//! never `sched` internals (repolint `layering-obs`). `sched`, `sim`
//! and `serve` may import `obs`, never the reverse.

pub mod analyze;
pub mod export;
pub mod live;
pub mod report;
pub mod trace;

pub use analyze::{critical_span_ratio, Analysis};
pub use export::ObsSummary;
pub use live::{metrics, MetricsRegistry, MetricsSnapshot};
pub use report::{diff_traces, BenchReport, TraceDiff};
pub use trace::{TraceEvent, TraceKind, OBS_CONTROL_WORKER};
