//! Open-loop serving acceptance: for the same seeded arrival trace the
//! DES mirror (`sim::serve::replay_open_loop`) and the real serving
//! loop (`serve::run_serve`) agree on the per-request admission
//! decisions under `Bounded`, and on the attained-QPS / tail-latency
//! orderings between admission settings.
//!
//! The scenario is the burst stress case: every request arrives at
//! t = 0, and each request is heavy enough that no admitted request can
//! finish before the submission sweep ends. That makes `Bounded { k }`
//! accept exactly the first `k` arrivals — a decision sequence with no
//! timing dependence at all — so the DES and the wall-clock executor
//! must produce it bit for bit.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::sync::Arc;

use daphne_sched::config::{ArrivalPattern, SchedConfig};
use daphne_sched::sched::{AdmissionPolicy, Executor, TenancyPolicy};
use daphne_sched::serve::{run_serve, ServeReport, ServeSpec};
use daphne_sched::sim::{self, GraphShape, NodeModel, OpenLoopSpec};
use daphne_sched::topology::Topology;

/// 100 rps over a 0.2 s window = a 20-request burst at t = 0. The
/// window is deliberately long relative to the bounded drain, so
/// bounded attained throughput is pinned at `BOUND / DURATION` = 10 rps
/// — far below the pool's service capacity on any host, which is what
/// keeps the open-vs-bounded attained ordering timing-independent.
const QPS: f64 = 100.0;
const DURATION: f64 = 0.2;
const ROWS: usize = 8;
const BOUND: usize = 2;

fn topo2() -> Topology {
    Topology::symmetric("t2", 1, 2, 1.0, 1.0)
}

/// The DES twin of the real `RequestKind::Linreg` request: same node
/// names and item counts, modelled per-item cost.
fn des_request() -> GraphShape {
    let per_item = 1e-3;
    GraphShape::new("linreg-infer")
        .node(NodeModel::uniform("colstats", ROWS, per_item))
        .node(NodeModel::uniform("stats", 1, per_item).after("colstats"))
        .node(
            NodeModel::uniform("standardize", ROWS, per_item).after("stats"),
        )
}

fn des_outcome(admission: AdmissionPolicy) -> sim::ServeSimOutcome {
    let spec = OpenLoopSpec {
        request: des_request(),
        qps: QPS,
        duration: DURATION,
        warmup: 0.0,
        slo: 0.05,
        admission,
        est_cost: 8.5e-3,
        arrival: ArrivalPattern::Burst,
        seed: 7,
        priority: 2,
        weight: 4,
        batch: Vec::new(),
    };
    sim::replay_open_loop(
        &spec,
        &topo2(),
        &SchedConfig::fine_grained(),
        &sim::CostModel::recorded(),
        TenancyPolicy::Fifo,
    )
    .unwrap()
}

fn real_report(admission: AdmissionPolicy, work: u64) -> ServeReport {
    let exec = Executor::new_with_policy(
        Arc::new(topo2()),
        Arc::new(SchedConfig::fine_grained()),
        TenancyPolicy::Fifo,
    );
    let spec = ServeSpec {
        qps: QPS,
        duration: DURATION,
        warmup: 0.0,
        rows: ROWS,
        // heavy enough that the earliest completion lands well after
        // the ~microseconds-long burst submission sweep, on any host
        work,
        batch_tenants: 0,
        admission,
        arrival: ArrivalPattern::Burst,
        slo: 30.0, // generous: agreement, not performance, is asserted
        seed: 7,
        ..ServeSpec::default()
    };
    run_serve(&exec, &spec).unwrap()
}

#[test]
fn bounded_admission_decisions_agree_between_des_and_real_executor() {
    let des = des_outcome(AdmissionPolicy::Bounded { max_backlog: BOUND });
    let real =
        real_report(AdmissionPolicy::Bounded { max_backlog: BOUND }, 1_000_000);

    let expected: Vec<bool> = (0..20).map(|i| i < BOUND).collect();
    assert_eq!(des.offered, 20);
    assert_eq!(real.offered, 20);
    assert_eq!(des.decisions, expected, "DES admits exactly the bound");
    assert_eq!(
        real.decisions, des.decisions,
        "real loop must reproduce the DES admission trace"
    );
    assert_eq!((des.served, des.shed), (BOUND, 20 - BOUND));
    assert_eq!((real.served, real.shed), (BOUND, 20 - BOUND));
    assert_eq!(real.failed, 0);
}

#[test]
fn attained_qps_and_tail_orderings_agree_between_des_and_real_executor() {
    // DES prediction: open admits the whole burst, so it drains more
    // requests per second over its (longer) span and its tail diverges;
    // bounded serves only the bound over the same window.
    let des_open = des_outcome(AdmissionPolicy::Open);
    let des_bounded =
        des_outcome(AdmissionPolicy::Bounded { max_backlog: BOUND });
    assert!(des_open.decisions.iter().all(|&d| d), "open admits all");
    assert!(
        des_open.attained_qps > des_bounded.attained_qps * 1.3,
        "DES: open {} rps must beat bounded {} rps decisively",
        des_open.attained_qps,
        des_bounded.attained_qps
    );
    assert!(
        des_open.p99 > des_bounded.p99,
        "DES: open tail {} must exceed bounded tail {}",
        des_open.p99,
        des_bounded.p99
    );

    // Real executor: the same orderings on the wall clock. Only the
    // orderings are asserted — absolute rates depend on the host — but
    // both are driven by served counts (20 vs 2), not timing margins.
    // Lighter per-request work than the decisions test keeps the open
    // drain span short even in unoptimized builds, so open's attained
    // rate stays decisively above bounded's 10 rps floor.
    let real_open = real_report(AdmissionPolicy::Open, 200_000);
    let real_bounded =
        real_report(AdmissionPolicy::Bounded { max_backlog: BOUND }, 200_000);
    assert!(real_open.decisions.iter().all(|&d| d), "open admits all");
    assert_eq!(real_open.served, 20);
    assert_eq!(real_open.failed, 0);
    assert!(
        real_open.attained_qps > real_bounded.attained_qps,
        "executor: open {} rps must beat bounded {} rps, as the DES \
         predicted ({} vs {})",
        real_open.attained_qps,
        real_bounded.attained_qps,
        des_open.attained_qps,
        des_bounded.attained_qps
    );
    assert!(
        real_open.p99 > real_bounded.p99,
        "executor: open tail {}s must exceed bounded tail {}s, as the \
         DES predicted ({} vs {})",
        real_open.p99,
        real_bounded.p99,
        des_open.p99,
        des_bounded.p99
    );
}
