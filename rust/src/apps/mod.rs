//! The paper's two evaluated IDA pipelines (§4), plus the heterogeneous
//! pipeline the placement subsystem targets:
//!
//! - [`cc`] — connected components over a co-purchase graph (Listing 1):
//!   sparse, heavy-tailed row costs → dynamic partitioning wins.
//! - [`linreg`] — linear-regression model training (Listing 2): dense,
//!   uniform row costs → STATIC wins, scheduling overhead only hurts.
//! - [`hetero`] — the heterogeneous diamond (à la Trident): a dense
//!   accelerator-friendly branch and a sparse CPU-friendly branch,
//!   replayed on the modelled hetero machines under
//!   any/pinned/autotuned placement (`figure hetero`).

pub mod cc;
pub mod hetero;
pub mod linreg;
