"""Pallas kernel for the connected-components neighbour-propagation step.

This is the compute hot-spot of Listing 1: ``u = max(rowMaxs(G * t(c)), c)``.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the DAPHNE CPU runtime
row-partitions G across worker threads; here the same schedule is
expressed as a Pallas grid over column tiles with the row block resident
in VMEM. The output block acts as a max-accumulator across the column
grid — the classic reduction-into-output pattern that replaces the CPU
runtime's per-thread row loop.

Lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec structure is still what a real TPU build
would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 8x128 is the float32 VPU lane layout; the row tile
# is kept at 128 so the (row, col) block is one MXU-shaped 128x128 tile.
ROW_TILE = 128
COL_TILE = 128


def _kernel(g_ref, c_ref, crow_ref, u_ref):
    """One (row-block, col-tile) grid step.

    g_ref:    [TR, TC] adjacency tile.
    c_ref:    [1, TC]  component ids of the column vertices of this tile.
    crow_ref: [TR]     component ids of the row vertices (same for all j).
    u_ref:    [TR]     output accumulator (max across column tiles).
    """
    j = pl.program_id(0)

    # rowMaxs(G * t(c)) over this column tile.
    prod = g_ref[...] * c_ref[...]  # [TR, TC]
    tile_max = jnp.max(prod, axis=1)  # [TR]

    # First column tile initialises the accumulator with the row's own id
    # (the `max(..., c)` part of Listing 1); later tiles fold in.
    @pl.when(j == 0)
    def _init():
        u_ref[...] = jnp.maximum(tile_max, crow_ref[...])

    @pl.when(j != 0)
    def _fold():
        u_ref[...] = jnp.maximum(u_ref[...], tile_max)


@functools.partial(jax.jit, static_argnames=("row_tile", "col_tile"))
def cc_propagate(g, c, c_row, *, row_tile=ROW_TILE, col_tile=COL_TILE):
    """Tiled ``max(rowMaxs(G * t(c)), c)``.

    Args:
      g: ``[R, C]`` f32 dense adjacency block. R % row_tile == 0,
         C % col_tile == 0 (callers zero-pad; padding is inert because
         component ids are >= 1).
      c: ``[C]`` f32 column-vertex ids.
      c_row: ``[R]`` f32 row-vertex ids.

    Returns:
      ``[R]`` f32 updated row ids.
    """
    rows, cols = g.shape
    assert rows % row_tile == 0 and cols % col_tile == 0, (rows, cols)
    grid = (cols // col_tile, rows // row_tile)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, col_tile), lambda j, i: (i, j)),
            pl.BlockSpec((1, col_tile), lambda j, i: (0, j)),
            pl.BlockSpec((row_tile,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((row_tile,), lambda j, i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(g, c.reshape(1, cols), c_row)
