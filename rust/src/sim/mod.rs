//! Discrete-event simulation of DaphneSched on modelled machines.
//!
//! This is the testbed substitution (DESIGN.md §3): the paper's
//! experiments ran on 20- and 56-core Xeons; here the *same* scheduler
//! components — [`crate::sched::queue::TaskSource`] layouts, the
//! partitioners, [`crate::sched::victim::VictimSelector`] — are driven in
//! virtual time over a [`crate::topology::Topology`] model. Scheduling
//! behaviour (which worker gets which chunk, in what order) is produced
//! by the real code; only *durations* are modelled:
//!
//! - per-item execution cost (workload-derived, e.g. row nnz for CC),
//! - queue access cost with serialization (lock contention emerges from
//!   queuing at the critical section, not from a fitted curve),
//! - NUMA locality factors for remote queue access, remote steals and
//!   remote block execution.
//!
//! Cost-model constants are calibrated against the host by
//! [`calibrate`], so simulated makespans are in host-seconds.
//!
//! On top of single-job simulation sits **virtual-time graph replay**
//! ([`graph`]): a [`GraphShape`] of cost-described nodes (the DES
//! sibling of [`crate::sched::graph::GraphSpec`]) is replayed with
//! dependency-aware dispatch — a worker retiring a node's last chunk
//! enqueues ready dependents at the current virtual time, so
//! DAG-overlap wins are predictable on the modelled 20- and 56-core
//! machines, not just measurable on the host. Heterogeneous machine
//! models ([`crate::topology::Topology::heterogeneous`]) replay with
//! per-device-class pools: node [`Placement`](crate::sched::Placement)s
//! route work to the modelled CPU or accelerator pool, whose speed
//! factor and isolation the event loop honours. The replay is the
//! oracle for graph-level autotuning
//! ([`crate::sched::autotune::tune_graph`]), including placement as a
//! tuning dimension.
//!
//! Multi-tenant workloads replay through [`graph::replay_tenants`]:
//! many [`TenantSpec`] graphs with arrival offsets and tenancy options
//! share the modelled pool under a cross-job
//! [`TenancyPolicy`](crate::sched::TenancyPolicy) — the virtual-time
//! mirror of [`crate::sched::Session`] submission, and the oracle
//! behind `figure tenancy` and
//! [`crate::sched::autotune::tune_tenancy`].
//!
//! The open-loop serving regime replays through [`serve`]: a
//! deterministic arrival trace of small request graphs, admitted per
//! [`AdmissionPolicy`](crate::sched::AdmissionPolicy), over batch
//! tenants — the DES mirror of [`crate::serve`] and the oracle behind
//! `figure serve`.
//!
//! Elastic pools replay through [`elastic`]: stepped-capacity
//! schedules and the SLO-driven
//! [`ScalingController`](crate::sched::ScalingController) run over the
//! real [`crate::sched::elastic`] overlay arithmetic in virtual time —
//! the mirror of runtime pool resizing and the oracle behind
//! `figure elastic`.

pub mod calibrate;
pub mod elastic;
pub mod engine;
pub mod graph;
pub mod model;
pub mod serve;

pub use elastic::{
    replay_elastic, replay_steps, ElasticJob, ElasticSimOutcome,
    ElasticSimSpec, ElasticStep,
};
pub use engine::{simulate, SimOutcome};
pub use graph::{
    isolated_makespans, replay, replay_placed, replay_tenants,
    replay_tenants_admitted, replay_tenants_with, GraphShape,
    GraphSimOutcome, NodeModel, NodeSimOutcome, SimAdmission,
    TenancySimOutcome, TenantOutcome, TenantSpec,
};
pub use model::{CostModel, TraceCalibration, Workload};
pub use serve::{
    arrival_times, replay_open_loop, OpenLoopSpec, ServeSimOutcome,
    SERVE_TAG,
};
