//! The paper's first evaluation app (Listing 1): connected components
//! over the (synthetic) Amazon co-purchase graph, swept over all eleven
//! partitioning schemes natively, then reproduced on the modelled
//! Broadwell/Cascade Lake machines via the DES.
//!
//! ```sh
//! cargo run --release --example connected_components [nodes] [scale]
//! ```

use daphne_sched::apps::cc;
use daphne_sched::bench::AppCosts;
use daphne_sched::config::SchedConfig;
use daphne_sched::graph::{amazon_like, scale_up, SnapGraph};
use daphne_sched::sched::Scheme;
use daphne_sched::sim::CostModel;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn main() {
    let mut args = std::env::args().skip(1);
    let nodes: usize =
        args.next().and_then(|s| s.parse().ok()).unwrap_or(50_000);
    let scale: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(1);

    let g = amazon_like(&SnapGraph::small(nodes, 1)).symmetrize();
    let g = if scale > 1 { scale_up(&g, scale) } else { g };
    println!(
        "graph: {} nodes / {} edges; host has {} cores\n",
        g.rows,
        g.nnz(),
        Topology::host().n_cores()
    );

    // -- native execution on this host, all schemes --------------------
    // one engine = one resident worker pool; each scheme's run submits
    // its jobs with a per-job config override instead of respawning
    println!("native execution (host):");
    let vee = Vee::new(Topology::host(), SchedConfig::default());
    for scheme in Scheme::ALL {
        let cfg = SchedConfig::default().with_scheme(scheme);
        let r = cc::run_with(&vee.with_config(cfg), &g, 100);
        println!(
            "  {:<7} {:.4}s  ({} iterations, {} components)",
            scheme.name(),
            r.total_time(),
            r.iterations,
            r.components
        );
    }

    // -- modelled machines (the paper's testbeds) ----------------------
    let iters = cc::converge_iterations(&g, 100);
    let costs = CostModel::daphne_like();
    let app = AppCosts::recorded();
    for machine in [Topology::broadwell20(), Topology::cascadelake56()] {
        println!("\nsimulated on {} ({} cores):", machine.name, machine.n_cores());
        for scheme in Scheme::FIGURES {
            let cfg = SchedConfig::default().with_scheme(scheme).with_seed(1);
            let (t, _) = cc::simulate_run(
                &g,
                &machine,
                &cfg,
                &costs,
                iters,
                app.cc_per_row,
                app.cc_per_nnz,
            );
            println!("  {:<7} {t:.4}s", scheme.name());
        }
    }
}
