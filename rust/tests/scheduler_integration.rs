//! Cross-module integration: the full scheduler matrix (11 schemes × 4
//! layouts × 4 victims) drives both evaluated apps correctly, the
//! task-graph API enforces exactly its declared dependencies (overlap,
//! cycle rejection, failure propagation, partitioning invariants under
//! concurrent nodes), and the DES reproduces the paper's qualitative
//! orderings at small scale.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use daphne_sched::apps::{cc, linreg};
use daphne_sched::config::{GraphMode, SchedConfig};
use daphne_sched::graph::{amazon_like, scale_up, SnapGraph};
use daphne_sched::sched::graph::GraphSpec;
use daphne_sched::sched::{
    Executor, GraphError, JobSpec, NodeSpec, NodeStatus, QueueLayout, Scheme,
    VictimStrategy,
};
use daphne_sched::sim::{self, CostModel, Workload};
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn host2() -> Topology {
    Topology::symmetric("t", 2, 1, 1.5, 1.0)
}

/// The three queue layouts of Fig. 4 (the centralized one in both its
/// locked and atomic variants).
const ALL_LAYOUTS: [QueueLayout; 4] = [
    QueueLayout::Centralized { atomic: false },
    QueueLayout::Centralized { atomic: true },
    QueueLayout::PerGroup,
    QueueLayout::PerCore,
];

fn hit_counters(n: usize) -> Vec<AtomicUsize> {
    (0..n).map(|_| AtomicUsize::new(0)).collect()
}

fn assert_exactly_once(hits: &[AtomicUsize], ctx: &str) {
    for (i, h) in hits.iter().enumerate() {
        assert_eq!(h.load(Ordering::Relaxed), 1, "{ctx}: item {i} ran != once");
    }
}

/// Partitioning invariant under pool reuse: ≥3 consecutive jobs on one
/// persistent executor, every item of every job handed out exactly
/// once, for all queue layouts.
#[test]
fn pool_reuse_preserves_partitioning_across_consecutive_jobs() {
    for layout in ALL_LAYOUTS {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Fac2)
            .with_layout(layout)
            .with_victim(VictimStrategy::SeqPri);
        let exec = Executor::new(
            Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
            Arc::new(cfg),
        );
        for (job, total) in [4_001usize, 9_999, 1, 6_500].iter().enumerate() {
            let hits = hit_counters(*total);
            let report = exec.run(JobSpec::new(*total), |_w, r| {
                for i in r.iter() {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert_eq!(report.total_items(), *total, "{layout:?} job {job}");
            assert_exactly_once(&hits, &format!("{layout:?} job {job}"));
        }
        assert_eq!(exec.jobs_completed(), 4);
    }
}

/// Partitioning invariant under multiplexing: two jobs submitted
/// concurrently to the same executor both complete with full item
/// coverage, for all queue layouts.
#[test]
fn two_concurrent_jobs_cover_all_items_on_one_pool() {
    for layout in ALL_LAYOUTS {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Gss)
            .with_layout(layout)
            .with_victim(VictimStrategy::Rnd);
        let exec = Executor::new(
            Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
            Arc::new(cfg),
        );
        let a = hit_counters(8_000);
        let b = hit_counters(5_432);
        exec.scope(|s| {
            let ha = s.submit(JobSpec::new(a.len()).named("job-a"), |_w, r| {
                for i in r.iter() {
                    a[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            let hb = s.submit(JobSpec::new(b.len()).named("job-b"), |_w, r| {
                for i in r.iter() {
                    b[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert_eq!(ha.wait().total_items(), a.len(), "{layout:?}");
            assert_eq!(hb.wait().total_items(), b.len(), "{layout:?}");
        });
        assert_exactly_once(&a, &format!("{layout:?} concurrent job a"));
        assert_exactly_once(&b, &format!("{layout:?} concurrent job b"));
    }
}

/// Bounded spin-wait on a flag; true if it was set within the deadline.
fn wait_for(flag: &AtomicBool) -> bool {
    let t0 = Instant::now();
    while !flag.load(Ordering::Acquire) {
        if t0.elapsed() > Duration::from_secs(20) {
            return false;
        }
        std::hint::spin_loop();
    }
    true
}

/// Acceptance: a diamond A → {B, C} → D runs B and C *concurrently* on
/// one resident pool — each branch's body observes the other branch
/// in-flight — while A-before-{B,C} and {B,C}-before-D ordering holds.
#[test]
fn diamond_graph_overlaps_independent_branches_on_one_pool() {
    let exec = Executor::new(
        Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
        Arc::new(SchedConfig::default()),
    );
    let a_items = AtomicUsize::new(0);
    let order_ok = AtomicBool::new(true);
    let b_in = AtomicBool::new(false);
    let c_in = AtomicBool::new(false);
    let overlap = AtomicBool::new(true);
    let b_done = AtomicBool::new(false);
    let c_done = AtomicBool::new(false);
    let spec = GraphSpec::new("diamond")
        .node(NodeSpec::new("a", 1_000), |_w, r| {
            a_items.fetch_add(r.len(), Ordering::SeqCst);
        })
        .node(NodeSpec::new("b", 1).after("a"), |_w, _r| {
            if a_items.load(Ordering::SeqCst) != 1_000 {
                order_ok.store(false, Ordering::SeqCst);
            }
            b_in.store(true, Ordering::Release);
            // hold this worker inside b until c is also in flight
            if !wait_for(&c_in) {
                overlap.store(false, Ordering::SeqCst);
            }
            b_done.store(true, Ordering::Release);
        })
        .node(NodeSpec::new("c", 1).after("a"), |_w, _r| {
            if a_items.load(Ordering::SeqCst) != 1_000 {
                order_ok.store(false, Ordering::SeqCst);
            }
            c_in.store(true, Ordering::Release);
            if !wait_for(&b_in) {
                overlap.store(false, Ordering::SeqCst);
            }
            c_done.store(true, Ordering::Release);
        })
        .node(NodeSpec::new("d", 200).after("b").after("c"), |_w, _r| {
            if !b_done.load(Ordering::Acquire) || !c_done.load(Ordering::Acquire)
            {
                order_ok.store(false, Ordering::SeqCst);
            }
        });
    let report = exec.run_graph(spec).expect("diamond is acyclic");
    assert!(order_ok.load(Ordering::SeqCst), "dependency order violated");
    assert!(
        overlap.load(Ordering::SeqCst),
        "b and c never overlapped on the pool"
    );
    assert!(report.all_completed());
    assert_eq!(report.report("a").unwrap().total_items(), 1_000);
    assert_eq!(report.report("d").unwrap().total_items(), 200);
    assert_eq!(exec.jobs_completed(), 4);
}

/// Acceptance: cyclic specs are rejected with an error up front — no
/// node dispatches and nothing deadlocks.
#[test]
fn cyclic_graph_specs_are_rejected_not_deadlocked() {
    let exec = Executor::new(
        Arc::new(Topology::symmetric("t2", 1, 2, 1.0, 1.0)),
        Arc::new(SchedConfig::default()),
    );
    let three_cycle = GraphSpec::new("cycle3")
        .node(NodeSpec::new("a", 10).after("c"), |_w, _r| {})
        .node(NodeSpec::new("b", 10).after("a"), |_w, _r| {})
        .node(NodeSpec::new("c", 10).after("b"), |_w, _r| {});
    match exec.submit_graph(three_cycle) {
        Err(GraphError::Cycle(names)) => assert_eq!(names.len(), 3),
        other => panic!("expected cycle rejection, got {other:?}"),
    }
    // a cycle hanging off an acyclic prefix is still rejected whole
    let tail_cycle = GraphSpec::new("tail")
        .node(NodeSpec::new("root", 10), |_w, _r| {})
        .node(NodeSpec::new("x", 10).after("root").after("y"), |_w, _r| {})
        .node(NodeSpec::new("y", 10).after("x"), |_w, _r| {});
    assert!(matches!(
        exec.submit_graph(tail_cycle),
        Err(GraphError::Cycle(_))
    ));
    assert_eq!(exec.jobs_completed(), 0, "rejected specs dispatch nothing");
    // and the pool still works
    assert_eq!(
        exec.run(JobSpec::new(500), |_w, _r| {}).total_items(),
        500
    );
}

/// A panicking node fails, its transitive dependents cancel, and the
/// independent branch (plus the pool itself) keeps working.
#[test]
fn panic_in_node_cancels_dependents_but_not_independent_branches() {
    let exec = Executor::new(
        Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
        Arc::new(SchedConfig::default()),
    );
    let e_ran = Arc::new(AtomicUsize::new(0));
    let e_ran2 = Arc::clone(&e_ran);
    let spec = GraphSpec::new("partial-failure")
        .node(NodeSpec::new("a", 100), |_w, _r| {})
        .node(NodeSpec::new("bad", 100).after("a"), |_w, r| {
            if r.start == 0 {
                panic!("injected node failure");
            }
        })
        .node(NodeSpec::new("child", 100).after("bad"), |_w, _r| {})
        .node(
            NodeSpec::new("grandchild", 100).after("child"),
            |_w, _r| {},
        )
        .node(NodeSpec::new("c", 100).after("a"), |_w, _r| {})
        .node(NodeSpec::new("e", 100).after("c"), move |_w, r| {
            e_ran2.fetch_add(r.len(), Ordering::Relaxed);
        });
    let report = exec.submit_graph(spec).unwrap().join();
    assert_eq!(report.status("a"), Some(NodeStatus::Completed));
    assert_eq!(report.status("bad"), Some(NodeStatus::Failed));
    assert_eq!(report.status("child"), Some(NodeStatus::Cancelled));
    assert_eq!(report.status("grandchild"), Some(NodeStatus::Cancelled));
    assert_eq!(report.status("c"), Some(NodeStatus::Completed));
    assert_eq!(report.status("e"), Some(NodeStatus::Completed));
    assert_eq!(e_ran.load(Ordering::Relaxed), 100);
    assert!(!report.all_completed());
    // cancelled nodes never dispatched
    assert!(report.node("child").unwrap().report.is_none());
    // the pool survives the abort
    assert_eq!(
        exec.run(JobSpec::new(2_000), |_w, _r| {}).total_items(),
        2_000
    );
}

/// Partitioning invariant while two independent graph nodes run
/// concurrently, for every queue layout: each node's items are handed
/// out exactly once, and per-node config overrides take effect.
#[test]
fn graph_nodes_preserve_partitioning_invariants_on_all_layouts() {
    for layout in ALL_LAYOUTS {
        let cfg = SchedConfig::default()
            .with_scheme(Scheme::Fac2)
            .with_layout(layout)
            .with_victim(VictimStrategy::SeqPri);
        let exec = Executor::new(
            Arc::new(Topology::symmetric("t4", 2, 2, 1.5, 1.0)),
            Arc::new(cfg.clone()),
        );
        let a = hit_counters(1_000);
        let b = hit_counters(8_000);
        let c = hit_counters(5_431);
        let d = hit_counters(900);
        let spec = GraphSpec::new("invariants")
            .node(NodeSpec::new("a", a.len()), |_w, r| {
                for i in r.iter() {
                    a[i].fetch_add(1, Ordering::Relaxed);
                }
            })
            .node(
                NodeSpec::new("b", b.len()).after("a").with_config(
                    cfg.clone().with_scheme(Scheme::Gss),
                ),
                |_w, r| {
                    for i in r.iter() {
                        b[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            )
            .node(NodeSpec::new("c", c.len()).after("a"), |_w, r| {
                for i in r.iter() {
                    c[i].fetch_add(1, Ordering::Relaxed);
                }
            })
            .node(
                NodeSpec::new("d", d.len()).after("b").after("c"),
                |_w, r| {
                    for i in r.iter() {
                        d[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
        let report = exec.run_graph(spec).expect("acyclic");
        assert!(report.all_completed(), "{layout:?}");
        for (hits, name) in [(&a, "a"), (&b, "b"), (&c, "c"), (&d, "d")] {
            assert_exactly_once(hits, &format!("{layout:?} node {name}"));
            assert_eq!(
                report.report(name).unwrap().total_items(),
                hits.len(),
                "{layout:?} node {name}"
            );
        }
        assert_eq!(report.report("b").unwrap().scheme, "GSS", "{layout:?}");
        assert_eq!(report.report("c").unwrap().scheme, "FAC2", "{layout:?}");
    }
}

/// Acceptance: a linear `Pipeline::stage` chain preserves the classic
/// barrier semantics through the graph API, and both dispatch modes
/// agree with each other on a full app run.
#[test]
fn linear_pipelines_and_apps_agree_across_graph_modes() {
    let g = amazon_like(&SnapGraph::small(400, 2)).symmetrize();
    let topo = Topology::symmetric("t4", 1, 4, 1.0, 1.0);
    let dag = Vee::new(topo.clone(), SchedConfig::default());
    let barrier = Vee::new(topo, SchedConfig::default())
        .with_graph_mode(GraphMode::Barrier);
    assert_eq!(dag.graph_mode(), GraphMode::Dag);
    let r_dag = cc::run_with(&dag, &g, 100);
    let r_bar = cc::run_with(&barrier, &g, 100);
    assert_eq!(r_dag.labels, r_bar.labels);
    assert_eq!(r_dag.iterations, r_bar.iterations);
    assert_eq!(r_dag.components, r_bar.components);
}

/// Two full app pipelines submitted concurrently from separate threads
/// onto one shared engine produce the same results as isolated runs.
#[test]
fn concurrent_app_pipelines_on_shared_engine_match_isolated_runs() {
    let g = amazon_like(&SnapGraph::small(400, 2)).symmetrize();
    let expected =
        cc::run_native(&g, &host2(), &SchedConfig::default(), 100).labels;
    let vee = Vee::new(
        Topology::symmetric("t4", 1, 4, 1.0, 1.0),
        SchedConfig::default().with_scheme(Scheme::Mfsc),
    );
    let results: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| s.spawn(|| cc::run_with(&vee, &g, 100).labels))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for labels in results {
        assert_eq!(labels, expected);
    }
}

#[test]
fn full_config_matrix_runs_cc_correctly() {
    let g = amazon_like(&SnapGraph::small(400, 2)).symmetrize();
    let expected =
        cc::run_native(&g, &host2(), &SchedConfig::default(), 100).labels;
    let layouts = [
        QueueLayout::Centralized { atomic: false },
        QueueLayout::Centralized { atomic: true },
        QueueLayout::PerGroup,
        QueueLayout::PerCore,
    ];
    for scheme in Scheme::ALL {
        for layout in layouts {
            for victim in VictimStrategy::ALL {
                let cfg = SchedConfig {
                    scheme,
                    layout,
                    victim,
                    seed: 99,
                    stages: None,
                    pls_swr: 0.5,
                };
                let got = cc::run_native(&g, &host2(), &cfg, 100);
                assert_eq!(
                    got.labels, expected,
                    "{scheme:?}/{layout:?}/{victim:?}"
                );
                // stealing layouts only steal when legal
                if !layout.steals() {
                    assert_eq!(got.reports[0].total_steals(), 0);
                }
            }
        }
    }
}

#[test]
fn scaled_graph_has_k_times_components() {
    let g = amazon_like(&SnapGraph::small(150, 8)).symmetrize();
    let scaled = scale_up(&g, 4);
    let r = cc::run_native(&scaled, &host2(), &SchedConfig::default(), 100);
    assert_eq!(r.components, 4, "4 disjoint copies = 4 components");
}

#[test]
fn des_reproduces_fig7_ordering_smallscale() {
    // Sparse CC workload on modelled Broadwell under the figure
    // environment (DAPHNE-like dispatch costs + OS interference): MFSC
    // must beat STATIC (the paper's headline Fig. 7a result). Averaged
    // over iterations like the figure harness.
    let g = amazon_like(&SnapGraph::small(200_000, 1)).symmetrize();
    let topo = Topology::broadwell20();
    let costs = CostModel::daphne_like();
    let base = SchedConfig::default().with_seed(1);
    let (t_static, _) = cc::simulate_run(
        &g,
        &topo,
        &base.clone().with_scheme(Scheme::Static),
        &costs,
        10,
        10.3e-9,
        1.1e-9,
    );
    let (t_mfsc, _) = cc::simulate_run(
        &g,
        &topo,
        &base.clone().with_scheme(Scheme::Mfsc),
        &costs,
        10,
        10.3e-9,
        1.1e-9,
    );
    assert!(
        t_mfsc < t_static,
        "MFSC {t_mfsc} must beat STATIC {t_static} on sparse CC"
    );
}

#[test]
fn des_reproduces_fig10_ordering_smallscale() {
    // Dense LR workload: STATIC must beat the fine-grained dynamic
    // schemes (Fig. 10) because scheduling overhead is pure loss.
    let topo = Topology::broadwell20();
    let costs = CostModel::recorded();
    let w = linreg::workload(200_000, 3e-8);
    let time = |scheme: Scheme| {
        sim::simulate(
            &topo,
            &SchedConfig::default().with_scheme(scheme),
            &w,
            &costs,
        )
        .makespan()
    };
    let t_static = time(Scheme::Static);
    for scheme in [Scheme::Mfsc, Scheme::Tfss, Scheme::Pls, Scheme::Pss] {
        let t = time(scheme);
        assert!(
            t >= t_static * 0.98,
            "{scheme:?} ({t}) must not beat STATIC ({t_static}) on dense LR"
        );
    }
}

#[test]
fn des_ss_explodes_on_central_queue() {
    // §4: SS execution time "explodes" under central-queue contention —
    // the reason it is omitted from Figs. 7-10.
    let topo = Topology::cascadelake56();
    let costs = CostModel::recorded();
    let w = Workload::uniform("u", 500_000, 1e-8);
    let t_ss = sim::simulate(
        &topo,
        &SchedConfig::default().with_scheme(Scheme::Ss),
        &w,
        &costs,
    )
    .makespan();
    let t_gss = sim::simulate(
        &topo,
        &SchedConfig::default().with_scheme(Scheme::Gss),
        &w,
        &costs,
    )
    .makespan();
    assert!(
        t_ss > 10.0 * t_gss,
        "SS ({t_ss}) must explode vs GSS ({t_gss})"
    );
}

#[test]
fn linreg_beta_invariant_across_machines() {
    let (x, y) = linreg::generate(&linreg::LinregSpec {
        rows: 1200,
        cols: 9,
        lambda: 1e-3,
        seed: 5,
    });
    let a = linreg::run_native(&x, &y, 1e-3, &host2(), &SchedConfig::default())
        .unwrap()
        .beta;
    let b = linreg::run_native(
        &x,
        &y,
        1e-3,
        &Topology::symmetric("t4", 1, 4, 1.0, 1.0),
        &SchedConfig::default().with_scheme(Scheme::Fac2),
    )
    .unwrap()
    .beta;
    for (i, (p, q)) in a.iter().zip(&b).enumerate() {
        assert!((p - q).abs() < 1e-3, "beta[{i}]: {p} vs {q}");
    }
}
