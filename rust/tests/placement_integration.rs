//! Integration: heterogeneous device pools end-to-end — placement-aware
//! dispatch on the real executor (pool isolation, graceful rejection)
//! and the DES placement oracle (accelerator wins on the modelled
//! machines, autotuned placement beating the all-CPU baseline).

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use daphne_sched::apps::hetero;
use daphne_sched::config::{GraphMode, SchedConfig};
use daphne_sched::sched::autotune::{self, SearchSpace};
use daphne_sched::sched::graph::GraphSpec;
use daphne_sched::sched::{
    Executor, GraphError, JobSpec, NodeSpec, Placement, PoolId, QueueLayout,
    Scheme,
};
use daphne_sched::sim::{self, CostModel};
use daphne_sched::topology::{DeviceClass, Topology};

/// 2 CPU cores + 2 GPU devices: the smallest topology where both pool
/// isolation and cross-pool overlap are observable with real threads.
fn hetero_topo() -> Arc<Topology> {
    Arc::new(Topology::heterogeneous(
        "t-hetero",
        1,
        2,
        1.0,
        1.0,
        &[(DeviceClass::Gpu, 2, 2.0)],
    ))
}

/// ACCEPTANCE: a class-pinned node never executes on — or steals from —
/// a foreign pool, across every queue layout (stealing ones included),
/// while nodes on different pools run concurrently on one executor.
#[test]
fn class_pinned_nodes_never_cross_pool_boundaries() {
    for layout in [
        QueueLayout::Centralized { atomic: false },
        QueueLayout::Centralized { atomic: true },
        QueueLayout::PerGroup,
        QueueLayout::PerCore,
    ] {
        let exec = Executor::new(
            hetero_topo(),
            Arc::new(
                SchedConfig::default()
                    .with_scheme(Scheme::Fac2)
                    .with_layout(layout),
            ),
        );
        let cpu_workers = Mutex::new(HashSet::new());
        let accel_workers = Mutex::new(HashSet::new());
        let cpu_items = AtomicUsize::new(0);
        let accel_items = AtomicUsize::new(0);
        // Per-item coverage: pool scoping must not lose or duplicate
        // work even with stealing enabled inside each pool.
        let spec = GraphSpec::new("isolation")
            .node(
                NodeSpec::new("cpu", 20_000).on(DeviceClass::Cpu),
                |w, r| {
                    cpu_workers.lock().unwrap().insert(w);
                    cpu_items.fetch_add(r.len(), Ordering::Relaxed);
                },
            )
            .node(
                // Pool(1) rather than Class(Gpu): explicit-pool pinning
                // is strict on every build, while Class(Gpu) degrades
                // to the CPU pool when `pjrt` is absent.
                NodeSpec::new("accel", 20_000)
                    .with_placement(Placement::Pool(PoolId(1))),
                |w, r| {
                    accel_workers.lock().unwrap().insert(w);
                    accel_items.fetch_add(r.len(), Ordering::Relaxed);
                },
            )
            .node(
                NodeSpec::new("join", 100).after("cpu").after("accel"),
                |_w, _r| {},
            );
        let report = exec.run_graph(spec).unwrap();
        assert!(report.all_completed(), "{layout:?}");
        assert_eq!(cpu_items.load(Ordering::Relaxed), 20_000, "{layout:?}");
        assert_eq!(accel_items.load(Ordering::Relaxed), 20_000, "{layout:?}");
        let cpu = cpu_workers.into_inner().unwrap();
        let accel = accel_workers.into_inner().unwrap();
        assert!(
            cpu.iter().all(|&w| w < 2),
            "{layout:?}: cpu-pinned node executed on workers {cpu:?}"
        );
        assert!(
            accel.iter().all(|&w| w >= 2),
            "{layout:?}: pool-pinned node executed on workers {accel:?}"
        );
        assert_eq!(report.node("cpu").unwrap().device, DeviceClass::Cpu);
        assert_eq!(report.node("accel").unwrap().device, DeviceClass::Gpu);
    }
}

/// ACCEPTANCE: `Placement::Class` for a class absent from the topology
/// is a hard `GraphError` from submission — the graph is rejected
/// before anything dispatches; nothing hangs and the pool stays usable.
#[test]
fn absent_class_is_a_graph_error_not_a_hang() {
    // CPU-only executor
    let exec = Executor::new(
        Arc::new(Topology::symmetric("t2", 1, 2, 1.0, 1.0)),
        Arc::new(SchedConfig::default()),
    );
    let spec = GraphSpec::new("impossible")
        .node(NodeSpec::new("ok", 100), |_w, _r| {})
        .node(
            NodeSpec::new("fpga", 100).after("ok").on(DeviceClass::Fpga),
            |_w, _r| {},
        );
    match exec.submit_graph(spec) {
        Err(GraphError::NoSuchPool { node, wanted }) => {
            assert_eq!(node, "fpga");
            assert_eq!(wanted, "class:fpga");
        }
        other => panic!("expected NoSuchPool, got {other:?}"),
    }
    assert_eq!(exec.jobs_completed(), 0, "nothing may have dispatched");
    // the executor still runs plain work afterwards
    let r = exec.run(JobSpec::new(1_000), |_w, _r| {});
    assert_eq!(r.total_items(), 1_000);

    // and the DES oracle rejects the same shape with the same error —
    // a shape that tunes/replays is a shape that submits
    let shape = hetero::pinned_diamond(2, DeviceClass::Gpu);
    let err = sim::replay(
        &shape,
        &Topology::symmetric("t2", 1, 2, 1.0, 1.0),
        &SchedConfig::default(),
        &CostModel::recorded(),
        GraphMode::Dag,
    )
    .unwrap_err();
    assert!(matches!(err, GraphError::NoSuchPool { .. }));
}

/// Spin until `flag` is set (or a generous timeout); true on success.
fn wait_for(flag: &std::sync::atomic::AtomicBool) -> bool {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while !flag.load(Ordering::Acquire) {
        if std::time::Instant::now() > deadline {
            return false;
        }
        std::hint::spin_loop();
    }
    true
}

/// Cross-pool overlap on real threads: a CPU node and an
/// accelerator-pool node with no edge between them run *concurrently*
/// on disjoint workers — asserted via an in-body handshake (each side
/// blocks until it has seen the other side running; a serialized
/// dispatch would time out, not hang).
#[test]
fn pools_overlap_independent_nodes_on_real_threads() {
    use std::sync::atomic::AtomicBool;
    let exec = Executor::new(hetero_topo(), Arc::new(SchedConfig::default()));
    let cpu_started = AtomicBool::new(false);
    let accel_started = AtomicBool::new(false);
    let handshake_ok = AtomicBool::new(true);
    let spec = GraphSpec::new("overlap")
        .node(NodeSpec::new("cpu", 2).on(DeviceClass::Cpu), |_w, _r| {
            cpu_started.store(true, Ordering::Release);
            if !wait_for(&accel_started) {
                handshake_ok.store(false, Ordering::Release);
            }
        })
        .node(
            NodeSpec::new("accel", 2)
                .with_placement(Placement::Pool(PoolId(1))),
            |_w, _r| {
                accel_started.store(true, Ordering::Release);
                if !wait_for(&cpu_started) {
                    handshake_ok.store(false, Ordering::Release);
                }
            },
        );
    let report = exec.run_graph(spec).unwrap();
    assert!(report.all_completed());
    assert!(
        handshake_ok.load(Ordering::Acquire),
        "independent nodes on different pools never ran concurrently"
    );
}

/// ACCEPTANCE: on the modelled 56-core machine with its accelerator
/// pool at 4× CPU speed, replaying the heterogeneous diamond with
/// *autotuned* placement beats the all-CPU `Placement::Any` baseline by
/// a measurable margin.
#[test]
fn autotuned_placement_beats_all_cpu_any_on_hetero56() {
    let machine = Topology::hetero56();
    let w = machine.class_cores(DeviceClass::Cpu);
    assert_eq!(w, 56);
    let gpu0 = machine
        .places
        .iter()
        .position(|p| p.device == DeviceClass::Gpu)
        .unwrap();
    assert_eq!(
        machine.speed_of(gpu0),
        4.0 * machine.core_speed,
        "acceptance models the accelerator pool at 4x CPU speed"
    );
    let costs = CostModel::recorded(); // deterministic oracle
    let sched = SchedConfig::default();
    let shape = hetero::diamond_shape(w);

    // all-CPU baseline: every node Placement::Any
    let any = sim::replay(&shape, &machine, &sched, &costs, GraphMode::Dag)
        .unwrap();
    assert!(
        any.nodes.iter().all(|n| n.device == DeviceClass::Cpu),
        "Any must resolve to the CPU pool"
    );

    // autotuned: placement is the fourth tuned dimension
    let space = SearchSpace {
        schemes: vec![Scheme::Static, Scheme::Gss, Scheme::Mfsc],
        layouts: vec![
            QueueLayout::Centralized { atomic: false },
            QueueLayout::PerCore,
        ],
        victims: vec![daphne_sched::sched::VictimStrategy::SeqPri],
        placements: SearchSpace::for_machine(&machine).placements,
    };
    let tuning =
        autotune::tune_graph(&shape, &machine, &costs, &space, 1, 1).unwrap();

    assert!(
        tuning.predicted < any.makespan() * 0.95,
        "autotuned {} must beat all-CPU {} by a measurable margin",
        tuning.predicted,
        any.makespan()
    );
    // the win comes from actually using the accelerator pool
    assert!(
        tuning
            .per_node
            .iter()
            .any(|c| c.placement == Placement::Class(DeviceClass::Gpu)),
        "tuned assignment never used the GPU pool: {:?}",
        tuning
            .per_node
            .iter()
            .map(|c| (c.name.clone(), c.placement))
            .collect::<Vec<_>>()
    );
    // replaying the tuned assignment reproduces the prediction
    let configs: Vec<SchedConfig> =
        tuning.per_node.iter().map(|c| c.config.clone()).collect();
    let placements: Vec<Placement> =
        tuning.per_node.iter().map(|c| c.placement).collect();
    let replayed = sim::replay_placed(
        &shape,
        &machine,
        &configs,
        &placements,
        &costs,
        GraphMode::Dag,
    )
    .unwrap()
    .makespan();
    assert!(
        (replayed - tuning.predicted).abs() / tuning.predicted < 1e-9,
        "replayed {replayed} vs predicted {}",
        tuning.predicted
    );
    // and the hand-pinned variant is also a win (sanity: the tuner is
    // not beating a strawman)
    let pinned = sim::replay(
        &hetero::pinned_diamond(w, DeviceClass::Gpu),
        &machine,
        &sched,
        &costs,
        GraphMode::Dag,
    )
    .unwrap();
    assert!(pinned.makespan() < any.makespan());
    assert!(tuning.predicted <= pinned.makespan() * 1.05);
}

/// Same-seed determinism of the placement-aware replay and tuner.
#[test]
fn hetero_replay_and_tuning_are_deterministic() {
    let machine = Topology::hetero20();
    let w = machine.class_cores(DeviceClass::Cpu);
    let costs = CostModel::recorded();
    let shape = hetero::pinned_diamond(w, DeviceClass::Gpu);
    let sched = SchedConfig::default().with_seed(7);
    let a = sim::replay(&shape, &machine, &sched, &costs, GraphMode::Dag)
        .unwrap();
    let b = sim::replay(&shape, &machine, &sched, &costs, GraphMode::Dag)
        .unwrap();
    assert_eq!(a.makespan(), b.makespan());
    assert_eq!(a.critical_path, b.critical_path);

    let space = SearchSpace {
        schemes: vec![Scheme::Static, Scheme::Gss],
        layouts: vec![QueueLayout::Centralized { atomic: false }],
        victims: vec![daphne_sched::sched::VictimStrategy::Seq],
        placements: SearchSpace::for_machine(&machine).placements,
    };
    let shape = hetero::diamond_shape(w);
    let t1 = autotune::tune_graph(&shape, &machine, &costs, &space, 5, 1)
        .unwrap();
    let t2 = autotune::tune_graph(&shape, &machine, &costs, &space, 5, 1)
        .unwrap();
    assert_eq!(t1.predicted, t2.predicted);
    for (x, y) in t1.per_node.iter().zip(&t2.per_node) {
        assert_eq!(x.placement, y.placement);
        assert_eq!(x.config.scheme, y.config.scheme);
    }
}
