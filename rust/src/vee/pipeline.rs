//! Pipelines: named stages of vectorized operators, sugar over the
//! scheduler's task-graph API ([`crate::sched::graph`]).
//!
//! [`Pipeline::stage`] chains each stage after the previous one — a
//! linear pipeline reproduces the classic barrier-per-stage semantics
//! as dependency edges. [`Pipeline::stage_after`] states dependencies
//! explicitly, so independent stages (e.g. two reductions over the same
//! standardized matrix) overlap on the engine's resident pool.
//!
//! [`Pipeline::run`] submits the whole pipeline as one
//! [`GraphSpec`](crate::sched::GraphSpec) via `Executor::run_graph`
//! when the engine is in `graph=dag` mode; in `graph=barrier` mode (or
//! on a one-shot engine) it serializes the stages in dependency order
//! with a full barrier between them, which is the A/B baseline for the
//! figures. Worker threads are never respawned per stage either way.

use std::sync::Arc;
use std::time::Instant;

use super::Vee;
use crate::config::{GraphMode, SchedConfig};
use crate::sched::graph::{toposort, GraphError, GraphSpec, NodeSpec};
use crate::sched::{GraphReport, SchedReport, TaskRange};
use crate::sim::{GraphShape, NodeModel, Workload};

/// One vectorized operator: a name, an item count, the names of the
/// stages it depends on, and a body executed over task ranges.
pub struct Stage<'a> {
    pub name: String,
    pub items: usize,
    /// Stages that must complete first (empty = pipeline root).
    pub after: Vec<String>,
    #[allow(clippy::type_complexity)]
    pub body: Box<dyn Fn(usize, TaskRange) + Send + Sync + 'a>,
}

impl<'a> Stage<'a> {
    pub fn new<F>(name: &str, items: usize, body: F) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'a,
    {
        Stage {
            name: name.to_string(),
            items,
            after: Vec::new(),
            body: Box::new(body),
        }
    }
}

/// A named set of stages connected by dependency edges.
#[derive(Default)]
pub struct Pipeline<'a> {
    pub name: String,
    pub stages: Vec<Stage<'a>>,
}

impl<'a> Pipeline<'a> {
    pub fn new(name: &str) -> Self {
        Pipeline { name: name.to_string(), stages: Vec::new() }
    }

    /// Append a stage that runs after every *open branch* — each stage
    /// added so far that no other stage depends on yet. In a linear
    /// chain that is exactly the previously added stage (the classic
    /// barrier chain); after [`Pipeline::stage_after`] branches, a
    /// plain `stage` is a join of all of them, never a silent
    /// attachment to one arbitrary branch.
    ///
    /// Stage names are identity in the graph API: adding two stages
    /// with the same name makes the pipeline invalid (an error from
    /// [`Pipeline::try_run`], a panic from [`Pipeline::run`]).
    pub fn stage<F>(mut self, name: &str, items: usize, body: F) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'a,
    {
        let mut stage = Stage::new(name, items, body);
        stage.after = {
            let depended: std::collections::HashSet<&str> = self
                .stages
                .iter()
                .flat_map(|s| s.after.iter().map(String::as_str))
                .collect();
            self.stages
                .iter()
                .map(|s| s.name.as_str())
                .filter(|n| !depended.contains(n))
                .map(str::to_string)
                .collect()
        };
        self.stages.push(stage);
        self
    }

    /// Append a stage with explicit dependencies (`after` empty = a
    /// root that can start immediately). Stages whose dependency sets
    /// don't order them relative to each other run concurrently on the
    /// engine's pool in `graph=dag` mode.
    pub fn stage_after<F>(
        mut self,
        name: &str,
        items: usize,
        after: &[&str],
        body: F,
    ) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'a,
    {
        let mut stage = Stage::new(name, items, body);
        stage.after = after.iter().map(|s| s.to_string()).collect();
        self.stages.push(stage);
        self
    }

    /// The cost-described [`GraphShape`] of this pipeline for post-hoc
    /// virtual-time replay ([`crate::sim::graph::replay`]): same stage
    /// names, item counts, and dependency edges as the
    /// [`GraphSpec`] that [`Pipeline::run`] submits, with each item
    /// costed at `per_item` virtual seconds (uniform — the coarse model;
    /// apps with skewed per-item costs export precise shapes themselves,
    /// e.g. [`crate::apps::cc::iteration_shape`]). Replaying the shape
    /// on a modelled machine predicts what dag dispatch buys this
    /// pipeline beyond the host it actually ran on.
    pub fn to_shape(&self, per_item: f64) -> GraphShape {
        let mut shape = GraphShape::new(&self.name);
        for stage in &self.stages {
            shape.add(
                NodeModel::new(
                    &stage.name,
                    Workload::uniform(&stage.name, stage.items, per_item),
                )
                .after_all(stage.after.iter().map(String::as_str)),
            );
        }
        shape
    }

    /// The [`GraphSpec`] this pipeline submits in `graph=dag` mode:
    /// same stage names, item counts, and dependency edges, every node
    /// sharing `config`. Exposed so multi-tenant drivers can submit
    /// many pipelines through one [`Session`](crate::sched::Session)
    /// ([`Session::run_all`](crate::sched::Session::run_all)) instead
    /// of one blocking [`Pipeline::run`] per thread.
    pub fn to_graph_spec(&self, config: &Arc<SchedConfig>) -> GraphSpec<'_> {
        let mut spec = GraphSpec::new(&self.name);
        for stage in &self.stages {
            let body = &stage.body;
            let node = NodeSpec::new(&stage.name, stage.items)
                .with_shared_config(Arc::clone(config))
                .after_all(stage.after.iter().map(String::as_str));
            spec.add(node, move |w, r| body(w, r));
        }
        spec
    }

    /// Execute the pipeline on the engine; panics on an invalid stage
    /// graph (cycle, unknown or duplicate stage name) — see
    /// [`Pipeline::try_run`] for the fallible form. A stage-body panic
    /// is resumed on this thread.
    pub fn run(&self, vee: &Vee) -> PipelineReport {
        self.try_run(vee)
            .unwrap_or_else(|e| panic!("pipeline '{}': {e}", self.name))
    }

    /// Execute the pipeline, reporting invalid stage graphs as
    /// [`GraphError`]s instead of panicking.
    pub fn try_run(&self, vee: &Vee) -> Result<PipelineReport, GraphError> {
        match vee.executor() {
            Some(exec) if vee.graph_mode() == GraphMode::Dag => {
                let spec = self.to_graph_spec(&vee.sched);
                let graph = exec.run_graph(spec)?;
                Ok(report_from_graph(graph))
            }
            _ => {
                // Barrier mode (or a one-shot engine): serialize the
                // stages in dependency order — a full barrier between
                // consecutive stages, validated by the same toposort
                // that guards the dag path.
                let meta: Vec<(String, Vec<String>)> = self
                    .stages
                    .iter()
                    .map(|s| (s.name.clone(), s.after.clone()))
                    .collect();
                let order = toposort(&meta)?.order;
                let t0 = Instant::now();
                let mut reports: Vec<Option<SchedReport>> =
                    (0..self.stages.len()).map(|_| None).collect();
                for idx in order {
                    let stage = &self.stages[idx];
                    reports[idx] = Some(vee.execute(stage.items, &stage.body));
                }
                let wall_time = t0.elapsed().as_secs_f64();
                let stages = self
                    .stages
                    .iter()
                    .zip(reports)
                    .map(|(s, r)| {
                        (s.name.clone(), r.expect("every stage executed"))
                    })
                    .collect();
                Ok(PipelineReport {
                    pipeline: self.name.clone(),
                    stages,
                    wall_time,
                })
            }
        }
    }
}

/// Map a fully-completed [`GraphReport`] (e.g. from
/// [`Session::run_all`](crate::sched::Session::run_all) over
/// [`Pipeline::to_graph_spec`] specs) back into the pipeline's report
/// shape. Panics if a node did not complete — callers that resumed the
/// graph's panic (as `run_all`/`run_graph` do) never see that.
pub fn report_from_graph(graph: GraphReport) -> PipelineReport {
    let stages = graph
        .nodes
        .into_iter()
        .map(|n| {
            let report = n
                .report
                .expect("graph settled without panic, so every node completed");
            (n.name, report)
        })
        .collect();
    PipelineReport {
        pipeline: graph.graph,
        stages,
        wall_time: graph.makespan,
    }
}

/// Per-stage scheduling reports for one pipeline run (stage insertion
/// order), plus the measured wall-clock of the whole run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub pipeline: String,
    pub stages: Vec<(String, SchedReport)>,
    /// Measured wall-clock seconds for the whole pipeline.
    pub wall_time: f64,
}

impl PipelineReport {
    /// Wall-clock time of the run. (Formerly the sum of per-stage
    /// makespans, which over-reports once stages overlap; that sum is
    /// now [`PipelineReport::serial_time`].)
    pub fn total_time(&self) -> f64 {
        self.wall_time
    }

    /// Sum of per-stage makespans — what a full barrier after every
    /// stage would cost; `serial_time() / total_time()` estimates the
    /// overlap win of dag dispatch.
    pub fn serial_time(&self) -> f64 {
        self.stages.iter().map(|(_, r)| r.makespan).sum()
    }

    pub fn stage(&self, name: &str) -> Option<&SchedReport> {
        self.stages.iter().find(|(n, _)| n == name).map(|(_, r)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedConfig;
    use crate::topology::Topology;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn barrier_vee() -> Vee {
        Vee::new(
            Topology::symmetric("t", 1, 4, 1.0, 1.0),
            SchedConfig::default(),
        )
        .with_graph_mode(GraphMode::Barrier)
    }

    #[test]
    fn stages_run_in_order_with_barriers() {
        // linear chain through the graph API preserves barrier semantics
        let vee = Vee::host_default();
        let a_done = AtomicUsize::new(0);
        let saw_a_complete = AtomicUsize::new(1);
        let pipeline = Pipeline::new("test")
            .stage("a", 1000, |_w, r| {
                a_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage("b", 500, |_w, _r| {
                // barrier semantics: stage a fully done before b starts
                if a_done.load(Ordering::SeqCst) != 1000 {
                    saw_a_complete.store(0, Ordering::SeqCst);
                }
            });
        let report = pipeline.run(&vee);
        assert_eq!(saw_a_complete.load(Ordering::SeqCst), 1);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stage("a").unwrap().total_items(), 1000);
        assert_eq!(report.stage("b").unwrap().total_items(), 500);
        assert!(report.total_time() > 0.0);
        assert!(report.serial_time() > 0.0);
    }

    #[test]
    fn branching_pipeline_respects_dependencies() {
        let vee = Vee::host_default();
        let a_done = AtomicUsize::new(0);
        let deps_ok = AtomicUsize::new(1);
        let b_done = AtomicUsize::new(0);
        let c_done = AtomicUsize::new(0);
        let pipeline = Pipeline::new("diamond")
            .stage("a", 400, |_w, r| {
                a_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage_after("b", 200, &["a"], |_w, r| {
                if a_done.load(Ordering::SeqCst) != 400 {
                    deps_ok.store(0, Ordering::SeqCst);
                }
                b_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage_after("c", 300, &["a"], |_w, r| {
                if a_done.load(Ordering::SeqCst) != 400 {
                    deps_ok.store(0, Ordering::SeqCst);
                }
                c_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage_after("d", 100, &["b", "c"], |_w, _r| {
                if b_done.load(Ordering::SeqCst) != 200
                    || c_done.load(Ordering::SeqCst) != 300
                {
                    deps_ok.store(0, Ordering::SeqCst);
                }
            });
        let report = pipeline.run(&vee);
        assert_eq!(deps_ok.load(Ordering::SeqCst), 1);
        assert_eq!(report.stages.len(), 4);
        // report keeps insertion order even though b/c may run either way
        let names: Vec<&str> =
            report.stages.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c", "d"]);
    }

    #[test]
    fn plain_stage_after_branches_joins_all_of_them() {
        // a → {b, c} (stage_after), then a plain stage() — it must wait
        // for BOTH open branches, not silently chain onto the last one.
        let vee = Vee::host_default();
        let b_done = AtomicUsize::new(0);
        let c_done = AtomicUsize::new(0);
        let join_ok = AtomicUsize::new(1);
        let pipeline = Pipeline::new("join")
            .stage("a", 100, |_w, _r| {})
            .stage_after("b", 250, &["a"], |_w, r| {
                b_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage_after("c", 350, &["a"], |_w, r| {
                c_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage("join", 50, |_w, _r| {
                if b_done.load(Ordering::SeqCst) != 250
                    || c_done.load(Ordering::SeqCst) != 350
                {
                    join_ok.store(0, Ordering::SeqCst);
                }
            });
        let join_deps = &pipeline.stages.last().unwrap().after;
        assert!(join_deps.contains(&"b".to_string()));
        assert!(join_deps.contains(&"c".to_string()));
        assert!(!join_deps.contains(&"a".to_string()), "a is not a leaf");
        pipeline.run(&vee);
        assert_eq!(join_ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn duplicate_stage_names_are_rejected() {
        let pipeline = Pipeline::new("dup")
            .stage("step", 10, |_w, _r| {})
            .stage_after("step", 10, &[], |_w, _r| {});
        assert!(matches!(
            pipeline.try_run(&Vee::host_default()),
            Err(GraphError::DuplicateNode(_))
        ));
        assert!(matches!(
            pipeline.try_run(&barrier_vee()),
            Err(GraphError::DuplicateNode(_))
        ));
    }

    #[test]
    fn barrier_mode_matches_dag_results() {
        let run = |vee: &Vee| {
            let count = AtomicUsize::new(0);
            let pipeline = Pipeline::new("p")
                .stage("x", 700, |_w, r| {
                    count.fetch_add(r.len(), Ordering::Relaxed);
                })
                .stage_after("y", 300, &["x"], |_w, r| {
                    count.fetch_add(r.len(), Ordering::Relaxed);
                });
            let report = pipeline.run(vee);
            (count.load(Ordering::Relaxed), report.stages.len())
        };
        assert_eq!(run(&Vee::host_default()), (1000, 2));
        assert_eq!(run(&barrier_vee()), (1000, 2));
    }

    #[test]
    fn cyclic_pipeline_is_an_error_in_both_modes() {
        let pipeline = Pipeline::new("bad")
            .stage_after("a", 10, &["b"], |_w, _r| {})
            .stage_after("b", 10, &["a"], |_w, _r| {});
        assert!(matches!(
            pipeline.try_run(&Vee::host_default()),
            Err(GraphError::Cycle(_))
        ));
        assert!(matches!(
            pipeline.try_run(&barrier_vee()),
            Err(GraphError::Cycle(_))
        ));
    }

    #[test]
    fn empty_pipeline_runs() {
        let report = Pipeline::new("empty").run(&Vee::host_default());
        assert!(report.stages.is_empty());
        assert_eq!(report.serial_time(), 0.0);
    }

    #[test]
    fn to_shape_mirrors_submitted_graph() {
        use crate::sim::{self, CostModel};
        use crate::topology::Topology;
        let pipeline = Pipeline::new("p")
            .stage("a", 400, |_w, _r| {})
            .stage_after("b", 200, &["a"], |_w, _r| {})
            .stage_after("c", 300, &["a"], |_w, _r| {})
            .stage_after("d", 100, &["b", "c"], |_w, _r| {});
        let shape = pipeline.to_shape(1e-6);
        assert_eq!(shape.name, "p");
        assert_eq!(
            shape.node_names().collect::<Vec<_>>(),
            vec!["a", "b", "c", "d"]
        );
        assert!((shape.total_cost() - 1000.0 * 1e-6).abs() < 1e-12);
        // the emitted shape replays with the same dependency semantics
        // the executor dispatched: b and c overlap after a
        let out = sim::replay(
            &shape,
            &Topology::broadwell20(),
            &SchedConfig::default(),
            &CostModel::recorded(),
            GraphMode::Dag,
        )
        .unwrap();
        let (b, c) = (out.node("b").unwrap(), out.node("c").unwrap());
        assert_eq!(b.start, c.start, "both branches released by a");
        assert!(out.node("d").unwrap().start >= b.finish.min(c.finish));
    }
}
