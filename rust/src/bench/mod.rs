//! Figure harness: regenerates every figure of the paper's evaluation
//! (§4) on the modelled machines, printing the same series the paper
//! plots. Used by `cargo bench` targets and the `daphne-sched figure`
//! CLI subcommand.
//!
//! The paper's absolute times came from real 20/56-core Xeons; here the
//! DES (calibrated in host-seconds, DESIGN.md §3) reproduces the
//! *shape*: who wins, by roughly what factor, where behaviour flips.

pub mod calibration;
pub mod figures;

pub use calibration::AppCosts;
pub use figures::{FigureId, FigureParams, Row};
