//! # daphne-sched
//!
//! Reproduction of **DaphneSched: A Scheduler for Integrated Data Analysis
//! Pipelines** (Eleliemy & Ciorba, 2023) as a three-layer rust + JAX +
//! Pallas stack.
//!
//! ## Execution model: one resident pool, many jobs
//!
//! Like the DAPHNE runtime it reproduces (paper Fig. 2), the crate keeps
//! its worker pool **persistent**: [`sched::Executor`] spawns one OS
//! thread per topology place when it is created and parks them between
//! jobs. Work is *submitted*, not spawned —
//! [`sched::Executor::submit`] takes a [`sched::JobSpec`] (item count +
//! optional per-job [`config::SchedConfig`]) and returns a
//! [`sched::JobHandle`] whose `wait()` yields the
//! [`sched::SchedReport`]. Several in-flight jobs — even with different
//! partitioning schemes or queue layouts — are multiplexed over the same
//! workers; borrowed-body jobs go through [`sched::Executor::scope`] /
//! [`sched::Executor::run`]. Above single jobs sits the **task-graph
//! API** ([`sched::graph`]): a [`sched::GraphSpec`] of named nodes with
//! explicit `after(...)` dependency edges, submitted via
//! [`sched::Executor::submit_graph`] — the executor dispatches a node
//! the moment its in-edges complete, so independent branches overlap on
//! the same resident workers (cyclic specs are rejected up front; a
//! node panic cancels its dependents only).
//!
//! Above graphs sits the **multi-tenant session API**
//! ([`sched::session`]): [`sched::Executor::session`] yields a
//! [`sched::Session`] whose `submit_graph` attaches
//! [`sched::SubmitOpts`] (priority, weight, tag) and whose
//! `submit_all`/`run_all` fuse a batch of pipelines into one merged
//! scheduling horizon; the executor's cross-job pick policy
//! ([`sched::TenancyPolicy`]: FIFO, weighted-fair over tags, or strict
//! priority with aging — CLI `policy=`) decides which tenant each free
//! worker serves, and [`sched::JobHandle::cancel`] /
//! [`sched::GraphHandle::cancel`] drop a tenant's undispatched work to
//! free the pool. The DES mirrors the policies
//! ([`sim::graph::replay_tenants`], CLI `figure tenancy` /
//! `tune tenancy`).
//!
//! The [`vee::Vee`] engine fronts one such executor: a pipeline is a
//! set of stages connected by dependency edges, submitted as one task
//! graph in the default `graph=dag` mode (or serialized with full
//! barriers under `graph=barrier`), so a 40-iteration connected-
//! components run spawns threads exactly once. The legacy
//! spawn-per-stage path survives as `executor=oneshot` in the CLI, for
//! A/B comparison (see `benches/micro.rs`).
//!
//! On a heterogeneous [`topology::Topology`] (CPU sockets plus
//! accelerator pools, e.g. [`topology::Topology::hetero56`]) the
//! executor partitions its workers into one pool per device class
//! ([`sched::placement`]); jobs and graph nodes carry a
//! [`sched::Placement`] routing them to a pool, the DES replays the
//! same pools in virtual time, and [`sched::autotune::tune_graph`]
//! tunes placement as a fourth per-node dimension (CLI
//! `figure hetero`, `tune graph=hetero`).
//!
//! ## Modules
//!
//! - [`sched`] — the paper's contribution: a task-based scheduler with
//!   eleven task-partitioning schemes, three queue layouts, and four
//!   victim-selection strategies for work-stealing, executed by the
//!   persistent job-submission [`sched::Executor`].
//! - [`sim`] — a discrete-event simulator that drives the *same* scheduler
//!   components in virtual time over a machine-topology model; this is how
//!   the paper's 20-core Broadwell and 56-core Cascade Lake experiments
//!   are reproduced on arbitrary hosts. [`sim::graph`] replays whole
//!   cost-described task graphs ([`sim::GraphShape`]) with
//!   dependency-aware dispatch, so DAG-overlap wins and per-node
//!   scheduling choices ([`sched::autotune::tune_graph`], CLI
//!   `tune graph=...`) are predictable on the modelled machines.
//! - [`matrix`], [`graph`] — the data substrates (dense / CSR matrices,
//!   synthetic Amazon-like co-purchase graphs; the data-graph spec is
//!   [`graph::SnapGraph`] — "GraphSpec" means the task graph).
//! - [`vee`] — the vectorized execution engine that turns (data, operator)
//!   into jobs on the resident pool, mirroring the DAPHNE runtime.
//! - [`dsl`] — a DaphneDSL-subset interpreter able to run the paper's
//!   Listings 1 and 2 verbatim.
//! - [`runtime`] — the PJRT runtime loading AOT-compiled JAX/Pallas HLO
//!   artifacts (`artifacts/*.hlo.txt`); Python never runs at runtime.
//!   Gated behind the `pjrt` cargo feature (needs the external `xla`
//!   crate).
//! - [`coordinator`] — the Fig. 5 distributed-memory extension
//!   (leader/worker over TCP); each worker daemon keeps one resident pool
//!   across coordinator connections.
//! - [`apps`] — the two evaluated IDA pipelines: connected components
//!   (Listing 1) and linear-regression training (Listing 2), each with a
//!   `run_with(&Vee, ..)` entry point for pool reuse across runs.
//! - [`serve`] — open-loop request serving on top of [`sched::Session`]:
//!   a seeded arrival trace of small request graphs (linreg inference,
//!   cc queries) at a target QPS over batch tenants, with per-request
//!   [`sched::AdmissionPolicy`] admission (`Open`/`Bounded`/`Shed`),
//!   streaming latency reservoirs, and SLO attainment reporting; the
//!   DES mirror is [`sim::serve`] (CLI `serve`, `figure serve`).
//! - [`obs`] — observability: lock-free per-worker trace rings
//!   ([`obs::trace`], CLI `trace=off|on|sampled:<n>`), Chrome
//!   trace-event export + [`obs::ObsSummary`] ([`obs::export`]), and
//!   the live [`obs::MetricsRegistry`] snapshotted during `serve`
//!   soaks ([`obs::live`]). The DES emits the same event stream in
//!   virtual time, making real and simulated timelines diffable.

pub mod apps;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod dsl;
pub mod graph;
pub mod matrix;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod topology;
pub mod util;
pub mod vee;
