//! The paper's two evaluated IDA pipelines (§4):
//!
//! - [`cc`] — connected components over a co-purchase graph (Listing 1):
//!   sparse, heavy-tailed row costs → dynamic partitioning wins.
//! - [`linreg`] — linear-regression model training (Listing 2): dense,
//!   uniform row costs → STATIC wins, scheduling overhead only hurts.

pub mod cc;
pub mod linreg;
