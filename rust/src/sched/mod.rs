//! DaphneSched — the paper's contribution (§3): a task-based scheduler
//! with two independent steps:
//!
//! 1. **Work partitioning** ([`partitioner`]): eleven self-scheduling
//!    techniques decide task granularity (variable-size tasks, Fig. 3b).
//! 2. **Work assignment** ([`queue`], [`victim`], [`worker`]):
//!    self-scheduling from a centralized queue, or work-stealing across
//!    per-core / per-NUMA-group queues with four victim-selection
//!    strategies.
//!
//! The novelty (contribution C.2) is that *stolen* work also follows the
//! chosen self-scheduling technique — a thief obtains the next chunk of
//! the victim's partition exactly as the owner would, so steal
//! granularity adapts instead of being a fixed constant.
//!
//! All components here are executor-agnostic: [`worker`] drives them with
//! real OS threads, [`crate::sim`] drives the same code in virtual time.

pub mod autotune;
pub mod metrics;
pub mod partitioner;
pub mod queue;
pub mod stealing;
pub mod task;
pub mod victim;
pub mod worker;

pub use metrics::{SchedReport, WorkerStats};
pub use partitioner::{ChunkCalc, Partitioner, Scheme};
pub use queue::{QueueLayout, TaskSource};
pub use task::TaskRange;
pub use victim::VictimStrategy;
pub use worker::ThreadPool;
