//! # daphne-sched
//!
//! Reproduction of **DaphneSched: A Scheduler for Integrated Data Analysis
//! Pipelines** (Eleliemy & Ciorba, 2023) as a three-layer rust + JAX +
//! Pallas stack.
//!
//! The crate provides:
//!
//! - [`sched`] — the paper's contribution: a task-based scheduler with
//!   eleven task-partitioning schemes, three queue layouts, and four
//!   victim-selection strategies for work-stealing.
//! - [`sim`] — a discrete-event simulator that drives the *same* scheduler
//!   components in virtual time over a machine-topology model; this is how
//!   the paper's 20-core Broadwell and 56-core Cascade Lake experiments
//!   are reproduced on arbitrary hosts.
//! - [`matrix`], [`graph`] — the data substrates (dense / CSR matrices,
//!   synthetic Amazon-like co-purchase graphs).
//! - [`vee`] — the vectorized execution engine that turns (data, operator)
//!   into tasks, mirroring the DAPHNE runtime.
//! - [`dsl`] — a DaphneDSL-subset interpreter able to run the paper's
//!   Listings 1 and 2 verbatim.
//! - [`runtime`] — the PJRT runtime loading AOT-compiled JAX/Pallas HLO
//!   artifacts (`artifacts/*.hlo.txt`); Python never runs at runtime.
//! - [`coordinator`] — the Fig. 5 distributed-memory extension
//!   (leader/worker over TCP).
//! - [`apps`] — the two evaluated IDA pipelines: connected components
//!   (Listing 1) and linear-regression training (Listing 2).

pub mod apps;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod dsl;
pub mod graph;
pub mod matrix;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod topology;
pub mod util;
pub mod vee;
