"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle here (pytest + hypothesis-style sweeps in
``python/tests/``). They intentionally mirror the DaphneDSL semantics of
the paper's Listings 1 and 2.
"""

import jax.numpy as jnp


def cc_propagate(g, c, c_row):
    """One neighbour-propagation step of connected components (Listing 1).

    ``u = max(rowMaxs(G * t(c)), c)`` — for each row i, the max component
    id among i's neighbours (``G[i, j] != 0`` selects ``c[j]``) combined
    with i's own current id.

    Args:
      g: ``[R, C]`` dense adjacency block (0 = no edge, 1 = edge).
      c: ``[C]`` current component ids of the column vertices.
      c_row: ``[R]`` current component ids of the row vertices.

    Returns:
      ``[R]`` updated ids for the row vertices.

    Matches DaphneDSL exactly: ``G * t(c)`` is an elementwise product with
    a broadcast row vector, so absent edges contribute 0. Component ids
    are >= 1, hence the 0 contribution never wins the max. This also makes
    zero-padding of partial blocks semantically inert.
    """
    prod = g * c[None, :]
    return jnp.maximum(jnp.max(prod, axis=1), c_row)


def colstats(x):
    """Column sums and sums of squares (Listing 2 lines 8-9).

    Returns ``(sum[C], sumsq[C])``; the caller accumulates across row
    blocks and finalises ``mean = sum/n``, ``std = sqrt(sumsq/n - mean^2)``.
    """
    return jnp.sum(x, axis=0), jnp.sum(x * x, axis=0)


def standardize(x, mean, std):
    """``(X - mean) / std`` with column-wise broadcast (Listing 2 line 10)."""
    return (x - mean[None, :]) / std[None, :]


def syrk(x):
    """``A = X^T X`` (Listing 2 line 12) for one row block.

    The full A is the sum of per-row-block partials; the rust VEE
    accumulates them, which is exactly how DAPHNE parallelises ``syrk``.
    """
    return x.T @ x


def gemv(x, y):
    """``b = X^T y`` (Listing 2 line 15) for one row block."""
    return x.T @ y
