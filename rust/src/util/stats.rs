//! Descriptive statistics used by metrics, benches and the DES reports.

/// Mean of a sample (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation — the paper's load-imbalance metric
/// (c.o.v. of per-worker finishing times).
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Min of a sample.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Max of a sample.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile by linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Jain's fairness index over non-negative allocations:
/// `(Σx)² / (n·Σx²)`, 1.0 = perfectly even, 1/n = one sample holds
/// everything. The multi-tenancy fairness metric of `figure tenancy`
/// (computed over per-tenant slowdowns).
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        1.0
    } else {
        s * s / (xs.len() as f64 * sq)
    }
}

/// Load-imbalance as max/mean of per-worker times (1.0 = perfectly even).
pub fn imbalance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        1.0
    } else {
        max(xs) / m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-12);
        assert!((cov(&xs) - 0.4472135954999579).abs() < 1e-12);
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
        assert!((jain_fairness(&[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-12);
        // one tenant hogging everything: index collapses to 1/n
        let skew = jain_fairness(&[1.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12);
        let mid = jain_fairness(&[1.0, 3.0]);
        assert!(mid > 0.25 && mid < 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(imbalance(&[]), 1.0);
    }

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert_eq!(imbalance(&[2.0, 2.0, 2.0]), 1.0);
        assert_eq!(imbalance(&[1.0, 3.0]), 1.5);
    }
}
