//! Matrix substrate: DAPHNE's dense and sparse (CSR) matrix data
//! structures, the pillars every task carries data in.

pub mod csr;
pub mod dense;
pub mod ops;

pub use csr::CsrMatrix;
pub use dense::DenseMatrix;
