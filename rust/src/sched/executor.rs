//! Persistent executor (paper §3, Fig. 2): the DAPHNE runtime keeps its
//! worker pool resident across vectorized operators — workers are
//! created once per topology and only *task descriptions* flow to them,
//! the same architectural move Canary makes for its cloud workers.
//!
//! This module is the job-submission API around that pool:
//!
//! - [`Executor`] — spawns one OS thread per topology place at
//!   construction; threads park on a condvar between jobs instead of
//!   being torn down (the seed executor paid a full `thread::scope`
//!   spawn/join per pipeline stage).
//! - [`JobSpec`] + [`Executor::submit`] → [`JobHandle`] — one *job* is
//!   one scheduled parallel region (`total` items partitioned by a
//!   [`SchedConfig`]); each job carries its own config, so one resident
//!   pool runs STATIC and GSS jobs back-to-back — or concurrently.
//! - [`Executor::scope`] — structured submission of jobs whose bodies
//!   borrow stack data (the common case for matrix kernels); the scope
//!   blocks until every job submitted through it has completed.
//! - [`Executor::run`] — submit one borrowed-body job and wait; this is
//!   what [`crate::vee::Vee::execute`] calls per vectorized operator.
//!
//! Multiple in-flight jobs are multiplexed over the same workers: each
//! job owns a job-scoped [`TaskSource`] tagged with a monotonically
//! increasing sequence id, workers drain jobs in FIFO submission order,
//! and a worker that exhausts one job's source (its steal round found
//! every queue empty — sources never refill) moves on to the next job
//! rather than blocking. A job completes when its executed-item counter
//! reaches `total`; because every item is handed out exactly once and
//! counted only after its task body returns, completion implies no body
//! is still running — and `finalize` drops the body before publishing
//! completion, which together make borrowed-body jobs sound.
//!
//! One metrics caveat vs the retired join-everything executor: a worker
//! whose *final* steal round over an already-empty source is still in
//! progress when the last item completes flushes that round's
//! `queue_wait`/`failed_steals` tail after the report snapshot; item,
//! task, busy and successful-steal counts are always exact.
//!
//! On a heterogeneous topology the executor partitions its workers into
//! one pool per device class at spawn ([`super::placement`]): each job
//! resolves its [`Placement`] to a pool before enqueueing, its task
//! source is built over that pool's sub-topology, and only that pool's
//! workers scan the job — so victim selection can never steal across a
//! pool boundary, and CPU and accelerator jobs overlap on disjoint
//! workers. A CPU-only topology is the one-pool special case with
//! today's exact behaviour.
//!
//! Which job a free worker serves next is the executor's pluggable
//! cross-job pick policy ([`TenancyPolicy`], see [`super::session`]):
//! FIFO drains jobs in submission order exactly as before; the `Fair`
//! and `Priority` policies re-evaluate the pick every few executed
//! tasks, so concurrent tenants interleave at task granularity. Every
//! job carries a [`Tenancy`] (priority, weight, tag) attached at
//! submission — [`Session`](super::Session) submissions set it, plain
//! [`Executor::submit`] uses the neutral default. Dependent graph nodes
//! enter the same policy-ordered run queue the moment their in-edges
//! complete, so the policy governs dependent-enqueue order too.
//!
//! Jobs may carry an internal completion hook (`on_done`), invoked
//! exactly once after the job's completion is published — this is how
//! the task-graph layer ([`super::graph`], [`Executor::submit_graph`])
//! dispatches dependent nodes the moment their in-edges complete,
//! without a coordinator thread.
//!
//! Cancellation ([`JobHandle::cancel`], reused by the graph layer)
//! rides the panic-abort machinery: the job stops handing out tasks,
//! its source is drained (drained items are counted but never run), and
//! completion publishes normally with no panic payload — waiters
//! unblock, the run-queue slot frees, and the pool moves on to the next
//! tenant. Task bodies already executing always finish.
//!
//! Do not submit-and-wait from *inside* a task body: a body that blocks
//! on another job of the same executor can deadlock the pool.

use std::fmt;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::elastic::ElasticPools;
use super::metrics::{SchedReport, WorkerStats};
use super::partitioner::PartitionerOptions;
use super::placement::{DevicePools, Placement, ResolveMode};
use super::queue::{self, TaskSource};
use super::ranks;
use super::session::{Tenancy, TenancyPolicy};
use super::stealing;
use super::task::TaskRange;
use super::victim::VictimSelector;
use crate::config::SchedConfig;
use crate::obs::trace::{self, TraceKind, NO_JOB, OBS_CONTROL_WORKER};
use crate::topology::Topology;
use crate::util::ordered::{OrderedCondvar, OrderedMutex};

pub(super) type Body = Box<dyn Fn(usize, TaskRange) + Send + Sync + 'static>;
pub(super) type PanicPayload = Box<dyn std::any::Any + Send + 'static>;
/// Internal completion hook: invoked exactly once, after the job's
/// completion has been published (body already dropped), on whichever
/// thread finalized the job. Used by the task-graph dispatcher.
pub(super) type DoneCallback = Box<dyn FnOnce(&Arc<Job>) + Send>;

/// Description of one job: an item count plus optional per-job
/// scheduling overrides (`None` = the executor's default config) and a
/// device-pool [`Placement`] (`Any` = the default pool).
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub items: usize,
    pub config: Option<Arc<SchedConfig>>,
    pub placement: Placement,
}

impl JobSpec {
    pub fn new(items: usize) -> Self {
        JobSpec {
            name: "job".to_string(),
            items,
            config: None,
            placement: Placement::Any,
        }
    }

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// Override the executor's default scheduling for this job.
    pub fn with_config(mut self, config: SchedConfig) -> Self {
        self.config = Some(Arc::new(config));
        self
    }

    /// Like [`JobSpec::with_config`] but sharing an existing `Arc` (no
    /// per-job config clone — the hot path used by the VEE).
    pub fn with_shared_config(mut self, config: Arc<SchedConfig>) -> Self {
        self.config = Some(config);
        self
    }

    /// Constrain the job to a device pool. [`Executor::submit`] panics
    /// on a placement the executor's topology cannot satisfy (the graph
    /// API reports it as a [`GraphError`](super::GraphError) instead).
    ///
    /// Note: `Placement::Class(Gpu)` on a build without the `pjrt`
    /// feature degrades to the CPU pool, and a plain job's
    /// [`SchedReport`] has no field to carry that annotation — submit
    /// through the graph API ([`Executor::submit_graph`]) when the
    /// degradation must be observable
    /// ([`NodeReport::fallback`](super::NodeReport)).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }
}

/// One in-flight job: the job-scoped task source, the body, and the
/// completion state. Lives behind an `Arc` shared by the submitter and
/// every worker touching the job.
pub(super) struct Job {
    /// Sequence id (the epoch tag): total order of submission, used by
    /// workers to remember which jobs they have already exhausted.
    seq: u64,
    name: String,
    /// FNV-1a of `name`, precomputed at enqueue while tracing is
    /// enabled (0 otherwise) so dispatch-path trace records never hash
    /// or touch the string.
    name_hash: u64,
    total: usize,
    config: Arc<SchedConfig>,
    /// Device pool the job is scoped to: only that pool's workers scan
    /// this job, and the source's queues cover only that pool.
    pool: usize,
    source: Box<dyn TaskSource>,
    /// The task body. Taken and dropped by `finalize` *before* the
    /// completion event is published: workers can only call it while
    /// `executed < total`, and a scoped submitter may free the `'env`
    /// data it borrows (or that its drop glue touches) as soon as
    /// completion is observed — so it must never outlive that point,
    /// even though worker threads keep `Arc<Job>` clones around.
    body: OrderedMutex<Option<Body>>,
    start: Instant,
    /// Items whose body has *returned* (or that were drained after an
    /// abort). Reaching `total` is the completion event.
    executed: AtomicUsize,
    /// Set when a body panicked: stop handing out this job's tasks.
    aborted: AtomicBool,
    panic: OrderedMutex<Option<PanicPayload>>,
    /// Set when the job was cancelled: the abort drain ran with no
    /// panic payload, so waiters complete normally and the task-graph
    /// layer reports the node `Cancelled` instead of `Failed`.
    cancelled: AtomicBool,
    /// Tenancy attached at submission (see [`super::session`]): what
    /// the cross-job pick policy weighs this job by.
    tenancy: Tenancy,
    /// Nanoseconds after `tenancy.arrived` at which a worker last
    /// pulled a task of this job (0 = never served). Priority aging
    /// measures waiting as time since last service, so a job the pool
    /// is actively serving never out-ages a late high-priority arrival.
    served_ns: AtomicU64,
    /// Nanoseconds after `start` at which a worker *first* pulled a task
    /// of this job (0 = never served yet). Written once; the published
    /// report splits end-to-end latency into queueing delay
    /// (admission → first dispatch) and service time from it.
    first_served_ns: AtomicU64,
    /// Per-worker counters, flushed before each item-count publish so
    /// the finalizer's snapshot covers every executed task. (Only the
    /// tail of a concurrent worker's final empty steal round — its
    /// `queue_wait`/`failed_steals` — can land after the snapshot; see
    /// the module docs.)
    stats: Vec<OrderedMutex<WorkerStats>>,
    done: OrderedMutex<Option<SchedReport>>,
    done_cv: OrderedCondvar,
    /// Completion hook (see [`DoneCallback`]); `None` for plain jobs.
    on_done: OrderedMutex<Option<DoneCallback>>,
}

impl Job {
    /// Snapshot of the published report; `Some` once the job completed.
    pub(super) fn cloned_report(&self) -> Option<SchedReport> {
        self.done.lock().unwrap().clone()
    }

    /// Whether the job was cancelled (see [`cancel_job`]). A flag, not
    /// an outcome: a job racing into finalization can complete every
    /// item despite it, so outcome labels also check
    /// [`Job::fully_executed`].
    pub(super) fn was_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Whether `report` shows every item of this job actually executed
    /// (nothing was drained) — the authoritative "nothing was lost"
    /// signal for cancellation labelling.
    pub(super) fn fully_executed(&self, report: &SchedReport) -> bool {
        report.total_items() == self.total
    }

    /// Take the recorded panic payload, if any (first caller wins).
    pub(super) fn take_panic(&self) -> Option<PanicPayload> {
        self.panic.lock().unwrap().take()
    }

    /// Record a trace event for this job through the lock-free trace
    /// API, carrying the job's precomputed hashes — for sibling modules
    /// (the graph layer) whose hook points sit on the dispatch path.
    pub(super) fn record_trace(&self, kind: TraceKind, worker: usize) {
        trace::record(kind, worker, self.seq, self.name_hash, self.tenancy.tag_hash);
    }
}

struct RunState {
    /// Live jobs that still have (or may have) unclaimed tasks, in
    /// submission (seq) order; the pick policy chooses among them.
    jobs: Vec<Arc<Job>>,
    /// Cross-job pick policy (see [`super::session`]).
    policy: TenancyPolicy,
    next_seq: u64,
    shutdown: bool,
}

pub(super) struct Shared {
    topo: Arc<Topology>,
    /// Per-device-class worker pools (built once at spawn). On a
    /// CPU-only topology this is a single pool covering every worker.
    pub(super) pools: DevicePools,
    /// Runtime-resizable worker↔pool assignment overlay (see
    /// [`super::elastic`]): the dispatch path reads it with relaxed
    /// atomic loads only; `Session::lend`/`reclaim`/`resize_pool`
    /// mutate it under its own ranked lease lock.
    pub(super) elastic: ElasticPools,
    queue: OrderedMutex<RunState>,
    work_cv: OrderedCondvar,
}

/// The persistent worker pool. Threads are spawned once, here, and
/// parked between jobs; `Drop` drains remaining jobs and joins them.
pub struct Executor {
    shared: Arc<Shared>,
    default_config: Arc<SchedConfig>,
    threads: Vec<JoinHandle<()>>,
    jobs_completed: Arc<AtomicUsize>,
}

impl Executor {
    /// Spawn one worker per place in `topo` with the default FIFO
    /// cross-job policy. This is the only point in the crate that
    /// creates scheduler worker threads.
    pub fn new(topo: Arc<Topology>, default_config: Arc<SchedConfig>) -> Self {
        Executor::new_with_policy(topo, default_config, TenancyPolicy::Fifo)
    }

    /// [`Executor::new`] with an explicit cross-job pick policy.
    pub fn new_with_policy(
        topo: Arc<Topology>,
        default_config: Arc<SchedConfig>,
        policy: TenancyPolicy,
    ) -> Self {
        let pools = DevicePools::new(&topo);
        let elastic = ElasticPools::new(&pools);
        let shared = Arc::new(Shared {
            topo: Arc::clone(&topo),
            pools,
            elastic,
            queue: OrderedMutex::new(
                ranks::RUN_QUEUE,
                RunState {
                    jobs: Vec::new(),
                    policy,
                    next_seq: 0,
                    shutdown: false,
                },
            ),
            work_cv: OrderedCondvar::new(),
        });
        let jobs_completed = Arc::new(AtomicUsize::new(0));
        let threads = (0..topo.n_cores())
            .map(|w| {
                let shared = Arc::clone(&shared);
                let completed = Arc::clone(&jobs_completed);
                std::thread::Builder::new()
                    .name(format!("daphne-worker-{w}"))
                    .spawn(move || worker_main(w, &shared, &completed))
                    .expect("spawn scheduler worker")
            })
            .collect();
        Executor { shared, default_config, threads, jobs_completed }
    }

    /// Executor for the host topology with the given default config.
    pub fn host(default_config: SchedConfig) -> Self {
        Executor::new(Topology::host_shared(), Arc::new(default_config))
    }

    pub fn n_workers(&self) -> usize {
        self.threads.len()
    }

    pub fn topology(&self) -> &Topology {
        &self.shared.topo
    }

    pub fn default_config(&self) -> &Arc<SchedConfig> {
        &self.default_config
    }

    /// Jobs finalized by this pool since construction (observability;
    /// also lets tests assert pool reuse across many jobs).
    pub fn jobs_completed(&self) -> usize {
        self.jobs_completed.load(Ordering::Relaxed)
    }

    /// The cross-job pick policy currently in effect.
    pub fn policy(&self) -> TenancyPolicy {
        self.shared.queue.lock().unwrap().policy
    }

    /// Live jobs currently in the run queue (dispatched, not yet
    /// finalized) — the backlog admission control bounds.
    pub fn backlog(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Live jobs in the run queue belonging to `tag` — the per-tenant
    /// backlog [`AdmissionPolicy`](super::AdmissionPolicy) decisions
    /// are made against.
    pub fn tag_backlog(&self, tag: &str) -> usize {
        self.shared
            .queue
            .lock()
            .unwrap()
            .jobs
            .iter()
            .filter(|j| &*j.tenancy.tag == tag)
            .count()
    }

    /// Switch the cross-job pick policy. Takes effect at each worker's
    /// next pick — jobs already being drained under a FIFO stint finish
    /// their stint first.
    pub fn set_policy(&self, policy: TenancyPolicy) {
        self.shared.queue.lock().unwrap().policy = policy;
    }

    /// Submit an owned-body job; the returned handle may outlive any
    /// stack frame (the job keeps running if the handle is dropped).
    pub fn submit<F>(&self, spec: JobSpec, body: F) -> JobHandle<'static>
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'static,
    {
        self.submit_tenant(spec, Tenancy::default(), body)
    }

    /// Owned-body submission with explicit tenancy (the
    /// [`super::Session`] job path).
    pub(super) fn submit_tenant<F>(
        &self,
        spec: JobSpec,
        tenancy: Tenancy,
        body: F,
    ) -> JobHandle<'static>
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'static,
    {
        let job = self.enqueue(spec, tenancy, Box::new(body));
        JobHandle {
            job,
            shared: Arc::clone(&self.shared),
            completed: Arc::clone(&self.jobs_completed),
            _env: PhantomData,
        }
    }

    /// Structured submission for jobs whose bodies borrow the caller's
    /// data: every job submitted through the [`Scope`] is awaited before
    /// `scope` returns (mirrors `std::thread::scope`). The first body
    /// panic is resumed on the calling thread.
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> R,
    {
        let scope = Scope {
            exec: self,
            pending: OrderedMutex::new(ranks::SCOPE_PENDING, Vec::new()),
            _scope: PhantomData,
            _env: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        // Completion barrier: no body can run past this point, which is
        // what makes the 'env lifetime transmute in `Scope::submit`
        // sound.
        let pending = std::mem::take(&mut *scope.pending.lock().unwrap());
        let mut job_panic = None;
        for job in pending {
            let mut g = job.done.lock().unwrap();
            while g.is_none() {
                g = job.done_cv.wait(g).unwrap();
            }
            drop(g);
            if job_panic.is_none() {
                job_panic = job.panic.lock().unwrap().take();
            }
        }
        match result {
            Err(p) => resume_unwind(p),
            Ok(r) => {
                if let Some(p) = job_panic {
                    resume_unwind(p);
                }
                r
            }
        }
    }

    /// Submit one borrowed-body job and block until it completes — the
    /// per-operator entry point used by the VEE.
    pub fn run<F>(&self, spec: JobSpec, body: F) -> SchedReport
    where
        F: Fn(usize, TaskRange) + Send + Sync,
    {
        self.scope(|s| s.submit(spec, &body).wait())
    }

    fn enqueue(&self, spec: JobSpec, tenancy: Tenancy, body: Body) -> Arc<Job> {
        let config = spec
            .config
            .unwrap_or_else(|| Arc::clone(&self.default_config));
        // Plain jobs have no error channel for an unsatisfiable
        // placement (the graph path validates and returns GraphError
        // before dispatching anything); panic with the resolution error.
        let res = self
            .shared
            .pools
            .resolve(&spec.placement, ResolveMode::Execute)
            .unwrap_or_else(|e| panic!("job '{}': {e}", spec.name));
        enqueue_raw(
            &self.shared,
            &self.jobs_completed,
            spec.name,
            spec.items,
            config,
            res.pool,
            tenancy,
            body,
            None,
        )
    }

    /// The per-device-class worker pools this executor dispatches over.
    pub fn pools(&self) -> &DevicePools {
        &self.shared.pools
    }

    /// The elastic worker↔pool assignment overlay (pool widths, lease
    /// state, resize epoch). Mutate it through
    /// [`Session`](super::Session) — `lend`/`reclaim`/`resize_pool` —
    /// which also records the resize trace events and wakes the pool.
    pub fn elastic(&self) -> &ElasticPools {
        &self.shared.elastic
    }

    /// Live non-moldable jobs currently queued on `pool` — the donor-
    /// pressure signal: while this is non-zero the pool must not lend
    /// workers away, and existing leases should snap back.
    pub fn pool_backlog(&self, pool: usize) -> usize {
        self.shared
            .queue
            .lock()
            .unwrap()
            .jobs
            .iter()
            .filter(|j| j.pool == pool && !j.tenancy.moldable)
            .count()
    }


    /// Shared pool state (handed to the task-graph dispatcher so node
    /// completion hooks can enqueue dependents without an `&Executor`).
    pub(super) fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    pub(super) fn completed_counter(&self) -> &Arc<AtomicUsize> {
        &self.jobs_completed
    }
}

/// Create and enqueue one job on the pool behind `shared`. This is the
/// single submission point: [`Executor::submit`]/[`Scope::submit`] call
/// it with `on_done: None`; the task-graph dispatcher
/// ([`super::graph`]) calls it from node completion hooks, which is why
/// it is a free function over `&Shared` rather than a method. `pool` is
/// the already-resolved device pool: the task source is built over that
/// pool's sub-topology, so its queues — and therefore every local pull
/// and steal — cover only that pool's workers.
#[allow(clippy::too_many_arguments)]
pub(super) fn enqueue_raw(
    shared: &Shared,
    completed: &AtomicUsize,
    name: String,
    items: usize,
    config: Arc<SchedConfig>,
    pool: usize,
    tenancy: Tenancy,
    body: Body,
    on_done: Option<DoneCallback>,
) -> Arc<Job> {
    let opts = PartitionerOptions {
        stages: config.stages,
        pls_swr: config.pls_swr,
        seed: config.seed,
    };
    let source = queue::build_source(
        config.layout,
        config.scheme,
        items,
        &shared.pools.pool(pool).topo,
        &opts,
    );
    // Stats are pool-local (one slot per pool worker, indexed by the
    // worker's local id): the report's per_worker then matches the DES
    // replay of the same placed node, instead of padding cov()/
    // imbalance() with permanently-idle foreign-pool slots.
    let n = shared.pools.pool(pool).topo.n_cores();
    let name_hash = if trace::enabled() { trace::fnv1a(&name) } else { 0 };
    let mut q = shared.queue.lock().unwrap();
    let seq = q.next_seq;
    q.next_seq += 1;
    let job = Arc::new(Job {
        seq,
        name,
        name_hash,
        total: items,
        config,
        pool,
        source,
        body: OrderedMutex::new(ranks::JOB_BODY, Some(body)),
        start: Instant::now(),
        executed: AtomicUsize::new(0),
        aborted: AtomicBool::new(false),
        cancelled: AtomicBool::new(false),
        tenancy,
        served_ns: AtomicU64::new(0),
        first_served_ns: AtomicU64::new(0),
        panic: OrderedMutex::new(ranks::JOB_PANIC, None),
        stats: (0..n)
            .map(|_| {
                OrderedMutex::new(ranks::JOB_STATS, WorkerStats::default())
            })
            .collect(),
        done: OrderedMutex::new(ranks::JOB_DONE, None),
        done_cv: OrderedCondvar::new(),
        on_done: OrderedMutex::new(ranks::JOB_ON_DONE, on_done),
    });
    trace::record(TraceKind::Enqueue, OBS_CONTROL_WORKER, seq, name_hash, job.tenancy.tag_hash);
    if job.total == 0 {
        // Nothing to schedule: complete inline without waking the pool.
        drop(q);
        let report = make_report(&job);
        publish_completion(&job, report, completed);
    } else {
        q.jobs.push(Arc::clone(&job));
        drop(q);
        shared.work_cv.notify_all();
    }
    // Snap-back: an arrival on a pool that lent workers away reclaims
    // them immediately — this is what guarantees a `Placement::Class`-
    // pinned node never waits on an emptied home pool (borrowed
    // workers are never eligible for it, so its pool must be restored
    // the moment it is enqueued).
    if shared.elastic.reclaim_if_lent(pool) > 0 {
        publish_pool_widths(shared);
    }
    job
}

/// Publish the pool widths after an elastic mutation: update the
/// `obs::live` gauges, record one [`TraceKind::Resize`] event per pool
/// (pool id in the name-hash slot, new width in the tag-hash slot —
/// the Chrome-trace exporter turns these into a counter track), and
/// wake every parked worker so it re-reads its assignment. The empty
/// lock/unlock of the run-queue mutex is load-bearing: a worker that
/// read the *old* assignment under the queue lock is either still
/// holding it (we cannot acquire until it releases, and it will be
/// notified once it waits) or already waiting (the notify reaches it)
/// — no lost wakeup either way.
pub(super) fn publish_pool_widths(shared: &Shared) {
    let widths = shared.elastic.widths();
    crate::obs::live::metrics().set_pool_widths(&widths);
    for (p, width) in widths.iter().enumerate() {
        trace::record(TraceKind::Resize, OBS_CONTROL_WORKER, NO_JOB, p as u64, *width as u64);
    }
    let q = shared.queue.lock().unwrap();
    drop(q);
    shared.work_cv.notify_all();
}

/// The one completion-publish sequence, shared by `finalize` and the
/// zero-item fast path in `enqueue_raw`. Order is load-bearing:
///
/// 1. drop the body — a scoped submitter may free the `'env` data it
///    borrows the moment completion is observed;
/// 2. bump the pool's completed counter;
/// 3. publish the report and wake waiters;
/// 4. invoke the `on_done` hook with **no lock held** (it may enqueue
///    dependent jobs; an if-let scrutinee would keep the mutex guard
///    alive across the call, so the hook is taken out first).
fn publish_completion(
    job: &Arc<Job>,
    report: SchedReport,
    completed: &AtomicUsize,
) {
    drop(job.body.lock().unwrap().take());
    completed.fetch_add(1, Ordering::Relaxed);
    {
        let mut done = job.done.lock().unwrap();
        *done = Some(report);
        job.done_cv.notify_all();
    }
    let cb = job.on_done.lock().unwrap().take();
    if let Some(cb) = cb {
        cb(job);
    }
}

/// Cancel one job: stop handing out its tasks and drain the unclaimed
/// remainder so the completion counter still reaches `total` (drained
/// items are counted but never run) — the panic-abort path without a
/// payload. Idempotent: only the first caller drains; an
/// already-finished job is left entirely untouched. Task bodies
/// already executing finish normally, and the worker that counts the
/// final item finalizes the job exactly as usual, so waiters observe an
/// ordinary completion with a partial item count.
pub(super) fn cancel_job(
    job: &Arc<Job>,
    shared: &Shared,
    completed: &AtomicUsize,
) {
    {
        // Checked and flagged under the completion lock, so a job whose
        // completion already published is never flagged. (A job racing
        // *into* finalization can still see the flag, which is why
        // completion-labelling treats "every item executed" as
        // authoritative over the flag — see `record_done` and
        // [`JobHandle::was_cancelled`].)
        let done = job.done.lock().unwrap();
        if done.is_some() {
            return; // already complete: nothing to drain or free
        }
        if job.cancelled.swap(true, Ordering::AcqRel) {
            return;
        }
    }
    trace::record(TraceKind::Cancel, OBS_CONTROL_WORKER, job.seq, job.name_hash, job.tenancy.tag_hash);
    job.aborted.store(true, Ordering::Release);
    // worker id 0 is valid in every pool; the `stolen` attribution of
    // a drained (never-run) pull is irrelevant
    let drained = drain_source(job, 0);
    complete_items(job, drained, shared, completed);
}

/// Pull every unclaimed task out of `job`'s source without running it —
/// the shared drain of the panic-abort and cancellation paths. Returns
/// the number of items drained; `w` must be a valid pool-local worker
/// id for the source. Items already pulled by workers are untouched
/// (they are counted by their workers when their bodies return).
fn drain_source(job: &Job, w: usize) -> usize {
    let source = &*job.source;
    let mut drained = 0usize;
    for q in 0..source.n_queues() {
        while let Some(pull) = source.pull_from(q, w) {
            drained += pull.task.len();
        }
    }
    debug_assert!(source.is_exhausted(), "drain must empty the source");
    drained
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl fmt::Debug for Executor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Executor")
            .field("topology", &self.shared.topo.name)
            .field("workers", &self.threads.len())
            .field("jobs_completed", &self.jobs_completed())
            .finish()
    }
}

/// Submission scope for borrowed-body jobs (see [`Executor::scope`]).
pub struct Scope<'scope, 'env: 'scope> {
    exec: &'scope Executor,
    pending: OrderedMutex<Vec<Arc<Job>>>,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Submit a job whose body may borrow data living at least `'env`.
    pub fn submit<F>(&'scope self, spec: JobSpec, body: F) -> JobHandle<'scope>
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'env,
    {
        let boxed: Box<dyn Fn(usize, TaskRange) + Send + Sync + 'env> =
            Box::new(body);
        // SOUNDNESS: lifetime-only transmute ('env erased to 'static);
        // vtable and layout are unchanged. `Executor::scope` blocks
        // until this job's completion event. Before that event is
        // published, `finalize` both (a) proves no call is in flight
        // (items are counted only after their call returns, and
        // completion requires all of them) and (b) takes and DROPS this
        // box — so neither a call through the closure nor its drop glue
        // can happen after 'env ends, even though workers hold
        // `Arc<Job>` clones longer.
        let boxed: Body = unsafe { std::mem::transmute(boxed) };
        let job = self.exec.enqueue(spec, Tenancy::default(), boxed);
        self.pending.lock().unwrap().push(Arc::clone(&job));
        JobHandle {
            job,
            shared: Arc::clone(&self.exec.shared),
            completed: Arc::clone(&self.exec.jobs_completed),
            _env: PhantomData,
        }
    }
}

/// Handle to one submitted job.
#[must_use = "a JobHandle should be waited on (the job itself keeps running)"]
pub struct JobHandle<'a> {
    job: Arc<Job>,
    shared: Arc<Shared>,
    completed: Arc<AtomicUsize>,
    _env: PhantomData<&'a ()>,
}

impl JobHandle<'_> {
    pub fn name(&self) -> &str {
        &self.job.name
    }

    pub fn is_finished(&self) -> bool {
        self.job.done.lock().unwrap().is_some()
    }

    /// Cancel the job: undispatched tasks are dropped (freeing the pool
    /// for other tenants), tasks already executing finish, and
    /// [`JobHandle::wait`] returns the usual report with a partial item
    /// count. Idempotent; a no-op on an already-finished job.
    pub fn cancel(&self) {
        cancel_job(&self.job, &self.shared, &self.completed);
    }

    /// Whether cancellation actually cost this job work: the cancel
    /// flag was raised and the job did not (or has not yet) executed
    /// every item. A cancel that raced a natural completion — all
    /// items ran, nothing was drained — reports `false`.
    pub fn was_cancelled(&self) -> bool {
        self.job.was_cancelled()
            && !self
                .job
                .cloned_report()
                .is_some_and(|r| self.job.fully_executed(&r))
    }

    /// Block until the job completes; resumes the body's panic if one
    /// occurred.
    pub fn wait(self) -> SchedReport {
        let mut g = self.job.done.lock().unwrap();
        while g.is_none() {
            g = self.job.done_cv.wait(g).unwrap();
        }
        let report = g.clone().unwrap();
        drop(g);
        if let Some(p) = self.job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
        report
    }
}

// ---------------------------------------------------------------------------
// worker side
// ---------------------------------------------------------------------------

/// Tasks a non-FIFO stint executes between cross-job re-picks: small
/// enough that a late high-priority tenant preempts within a few task
/// lengths, large enough that the global run-queue mutex and the stint
/// setup (victim selector, body handle) amortize over several tasks
/// even when contending tags would otherwise alternate every pick.
/// Public so stress tests can size workloads to straddle the re-pick
/// boundary exactly.
pub const POLICY_REPICK_STRIDE: usize = 8;

/// The park/dispatch loop run by every pool thread: pick a job *of
/// this worker's device pool* not yet exhausted for this worker under
/// the run queue's [`TenancyPolicy`], work it for a stint, repeat; park
/// when nothing is left. Under FIFO a stint drains the job's source
/// (the classic behaviour); under `Fair`/`Priority` the pick is
/// re-evaluated every [`POLICY_REPICK_STRIDE`] executed tasks and the
/// stint yields the moment another job wins it — that is what lets a
/// late high-priority (or under-served) tenant interleave within a few
/// task lengths instead of waiting for a whole drain. A worker never
/// touches a job placed on a foreign pool — the pool boundary is
/// enforced here and by the pool-scoped task source, not by
/// victim-selection policy.
///
/// Elasticity rides the same loop: the worker's pool is re-read from
/// the [`ElasticPools`] overlay on every pick (two relaxed loads), so a
/// lend/reclaim takes effect at the next pick; a worker parked out by
/// `resize_pool` (`!is_active`) skips picking entirely and waits. On a
/// *foreign* pool (assignment ≠ home) only moldable jobs are eligible —
/// pinned work never runs on borrowed workers.
fn worker_main(w: usize, shared: &Shared, completed: &AtomicUsize) {
    let home = shared.pools.pool_of(w);
    // Jobs whose source this worker has already found empty. Sources
    // never refill, so membership is permanent; entries are garbage-
    // collected once the job leaves the run queue.
    let mut exhausted: Vec<u64> = Vec::new();
    loop {
        let (job, reeval) = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                exhausted.retain(|s| q.jobs.iter().any(|j| j.seq == *s));
                let my_pool = shared.elastic.assignment_of(w);
                if shared.elastic.is_active(w) {
                    if let Some(job) = pick_job(&q, my_pool, home, &exhausted) {
                        let reeval = q.policy != TenancyPolicy::Fifo;
                        break (job, reeval);
                    }
                }
                if q.shutdown {
                    return;
                }
                trace::record(TraceKind::Park, w, NO_JOB, 0, 0);
                q = shared.work_cv.wait(q).unwrap();
                trace::record(TraceKind::Unpark, w, NO_JOB, 0, 0);
            }
        };
        let r = reeval.then_some(exhausted.as_slice());
        if run_job_stint(w, &job, shared, completed, r) {
            exhausted.push(job.seq);
        }
    }
}

/// The cross-job pick: choose the next job for a worker of `my_pool`
/// among the live jobs it has not yet drained, under the queue's
/// policy. Ties always break towards the older submission (lower seq),
/// so every policy is deterministic given the same queue state. Runs
/// under the run-queue mutex — once per *task* under the non-FIFO
/// policies — so it allocates nothing on the FIFO and Priority paths
/// and only one small per-tag aggregate on the Fair path.
///
/// `home` is the worker's immutable home pool: on a borrowed worker
/// (`my_pool != home`, see [`super::elastic`]) only *moldable* jobs are
/// eligible, which is what keeps pinned work off foreign workers under
/// resizing.
fn pick_job(
    q: &RunState,
    my_pool: usize,
    home: usize,
    exhausted: &[u64],
) -> Option<Arc<Job>> {
    let mut eligible = q.jobs.iter().filter(|j| {
        j.pool == my_pool
            && (my_pool == home || j.tenancy.moldable)
            && !exhausted.contains(&j.seq)
    });
    // Fast path for the common uncontended case (and for the per-task
    // re-pick inside non-FIFO stints): a lone eligible job needs no
    // arbitration under any policy.
    let first = eligible.next()?;
    if eligible.clone().next().is_none() {
        return Some(Arc::clone(first));
    }
    let mut eligible = std::iter::once(first).chain(eligible);
    match q.policy {
        // `jobs` is seq-ordered, so the first eligible is the oldest.
        TenancyPolicy::Fifo => eligible.next().cloned(),
        TenancyPolicy::Priority => {
            let now = Instant::now();
            // waiting = time since the job was last served (its whole
            // queueing time if never served): aging that resets on
            // service, so strict priority stays decisive between
            // actively-contending jobs while a starved one still rises
            let eff = |j: &Job| -> i64 {
                let since_arrival = now
                    .saturating_duration_since(j.tenancy.arrived)
                    .as_secs_f64();
                let served = j.served_ns.load(Ordering::Relaxed) as f64 * 1e-9;
                j.tenancy.effective_priority(since_arrival - served)
            };
            eligible
                .max_by(|a, b| {
                    eff(a)
                        .cmp(&eff(b))
                        // max_by keeps the later element on ties, so
                        // reverse the seq order to prefer the older job
                        .then_with(|| b.seq.cmp(&a.seq))
                })
                .cloned()
        }
        TenancyPolicy::Fair => {
            // Weighted fair share over tags, stateless: serve the tag
            // with the least executed-items-per-weight among the live
            // jobs of this pool. Finished jobs leave the queue, so the
            // share resets as tenants come and go — fairness is over
            // the *current* contenders. Aggregates cover every live
            // pool job (including ones this worker already drained),
            // exactly as the DES twin aggregates over all active pool
            // jobs — only the *candidates* are restricted to jobs this
            // worker can still serve. One aggregation pass keeps the
            // selection O(jobs · tags), not O(jobs²).
            let mut tags: Vec<(&Arc<str>, u64, u64)> = Vec::new();
            for j in q.jobs.iter().filter(|j| j.pool == my_pool) {
                let items = j.executed.load(Ordering::Relaxed) as u64;
                match tags.iter_mut().find(|(t, _, _)| **t == j.tenancy.tag)
                {
                    Some(entry) => {
                        entry.1 += items;
                        entry.2 = entry.2.max(j.tenancy.weight);
                    }
                    None => {
                        tags.push((&j.tenancy.tag, items, j.tenancy.weight))
                    }
                }
            }
            let served = |j: &Job| -> f64 {
                match tags.iter().find(|(t, _, _)| **t == j.tenancy.tag) {
                    Some((_, items, weight)) => {
                        *items as f64 / (*weight).max(1) as f64
                    }
                    // Unreachable: the candidates are a subset of the
                    // aggregated pool jobs. A panic here would unwind a
                    // worker thread while it holds the run-queue mutex
                    // (poisoning every later submit), so degrade to
                    // "least served" instead of unwrapping.
                    None => {
                        debug_assert!(false, "live pool job's tag missing from aggregate");
                        0.0
                    }
                }
            };
            eligible
                .min_by(|a, b| {
                    served(a)
                        .total_cmp(&served(b))
                        .then_with(|| a.seq.cmp(&b.seq))
                })
                .cloned()
        }
    }
}

/// One worker's stint on one job: the seed's worker loop (local pull,
/// then a steal round under the configured victim selection), ending
/// when the job-scoped source is exhausted, the job aborts, or —
/// under a non-FIFO policy (`reeval` = the worker's exhausted-seq
/// list) — the per-task pick re-evaluation prefers another job. The
/// re-evaluation happens *in place*, so a stint that keeps winning the
/// pick keeps its victim selector and body handle instead of paying a
/// full stint teardown per task. Returns whether the job is exhausted
/// *for this worker* — only then may the caller stop re-picking it.
fn run_job_stint(
    w: usize,
    job: &Arc<Job>,
    shared: &Shared,
    completed: &AtomicUsize,
    reeval: Option<&[u64]>,
) -> bool {
    let source = &*job.source;
    // Everything about this job is pool-local: the source was built
    // over the pool's sub-topology and the stats vector has one slot
    // per pool worker, so both are indexed by the worker's *local* id
    // (bodies still receive the global id). A *borrowed* worker (its
    // elastic assignment differs from its home pool — then the job is
    // necessarily moldable) has no slot of its own in a foreign pool,
    // so it folds onto a resident slot: sources and stats slots are
    // mutex/atomic-protected, so sharing a slot is safe, and the fold
    // keeps `per_worker` the same shape the DES models.
    let topo = &shared.pools.pool(job.pool).topo;
    let lw = shared.pools.local_of(w) % topo.n_cores();
    debug_assert_eq!(shared.elastic.assignment_of(w), job.pool);
    debug_assert!(
        shared.pools.pool_of(w) == job.pool || job.tenancy.moldable,
        "non-moldable job dispatched to a borrowed worker"
    );
    let config = &job.config;

    // One handle to the body for this stint. SAFETY of later derefs: the
    // body is freed only by `finalize`, which runs only once
    // `executed == total`; every task this stint executes was pulled —
    // and is counted only after its call returns — before that point can
    // be reached, so the pointee is alive for every call made here.
    let body_ptr: *const (dyn Fn(usize, TaskRange) + Send + Sync) = {
        let guard = job.body.lock().unwrap();
        match guard.as_ref() {
            Some(body) => &**body as *const _,
            // Job already finalized (its Arc lingered in our run-queue
            // snapshot): nothing left to do.
            None => return true,
        }
    };

    let mut selector = config.layout.steals().then(|| {
        let queue_socket: Vec<usize> = (0..source.n_queues())
            .map(|q| queue_socket_of(source, q, topo))
            .collect();
        VictimSelector::new(
            config.victim,
            source.queue_of(lw),
            topo.socket_of(lw.min(topo.n_cores() - 1)),
            queue_socket,
            config.seed ^ (w as u64).wrapping_mul(0x9E37_79B9),
        )
    });

    // Deltas since the last flush into `job.stats[w]`.
    let mut local = WorkerStats::default();
    let mut since_repick = 0usize;
    let exhausted = loop {
        if job.aborted.load(Ordering::Acquire) {
            break true;
        }
        // Elastic re-homing takes effect at chunk granularity: a worker
        // whose assignment moved (lend / reclaim) or that was parked
        // out (`resize_pool`) yields the stint before the next pull —
        // the task it is mid-way through always finishes, and the
        // unclaimed remainder stays in the source for the pool's
        // other workers, so nothing is lost or re-run.
        if shared.elastic.assignment_of(w) != job.pool
            || !shared.elastic.is_active(w)
        {
            break false;
        }
        let t0 = Instant::now();
        let mut steal_misses = 0usize;
        let pull = source.pull_local(lw).or_else(|| {
            let selector = selector.as_mut()?;
            let out = stealing::steal_round(source, selector, lw);
            steal_misses = out.attempts - usize::from(out.pull.is_some());
            out.pull
        });
        local.failed_steals += steal_misses;
        local.queue_wait += t0.elapsed().as_secs_f64();

        let Some(pull) = pull else {
            // the round found nothing at all: one FailedSteal event
            // (WorkerStats keeps the exact per-attempt miss count)
            if steal_misses > 0 {
                trace::record(TraceKind::FailedSteal, w, job.seq, job.name_hash, job.tenancy.tag_hash);
            }
            break true;
        };
        // reset the job's priority-aging clock: it is being served now
        job.served_ns.store(
            job.tenancy.arrived.elapsed().as_nanos() as u64,
            Ordering::Relaxed,
        );
        // first dispatch ends the queueing-delay window (write-once; a
        // racing second writer only ever stores a near-identical value)
        if job.first_served_ns.load(Ordering::Relaxed) == 0 {
            job.first_served_ns.store(
                (job.start.elapsed().as_nanos() as u64).max(1),
                Ordering::Relaxed,
            );
            trace::record(TraceKind::Dispatch, w, job.seq, job.name_hash, job.tenancy.tag_hash);
        }
        if pull.stolen {
            local.steals += 1;
            local.stolen_items += pull.task.len();
            trace::record(TraceKind::Steal, w, job.seq, job.name_hash, job.tenancy.tag_hash);
        }

        let t1 = Instant::now();
        trace::record(TraceKind::TaskStart, w, job.seq, job.name_hash, job.tenancy.tag_hash);
        // SAFETY: see `body_ptr` above — a pulled, not-yet-counted task
        // keeps `executed < total`, so the body cannot have been freed.
        let outcome =
            catch_unwind(AssertUnwindSafe(|| unsafe { (*body_ptr)(w, pull.task) }));
        trace::record(TraceKind::TaskEnd, w, job.seq, job.name_hash, job.tenancy.tag_hash);
        local.busy += t1.elapsed().as_secs_f64();
        local.tasks += 1;
        local.items += pull.task.len();

        // Publish stats before counting items: whoever observes
        // `executed == total` snapshots every worker's slot.
        flush_stats(&mut local, &job.stats[lw]);
        if let Err(payload) = outcome {
            abort_job(job, payload, lw, shared, completed);
        }
        complete_items(job, pull.task.len(), shared, completed);
        if let Some(exhausted_seqs) = reeval {
            // non-FIFO policy: every [`POLICY_REPICK_STRIDE`] tasks,
            // yield the stint if the pick now prefers another job (or
            // this one left the run queue)
            since_repick += 1;
            if since_repick >= POLICY_REPICK_STRIDE {
                since_repick = 0;
                crate::obs::live::note_repick();
                let next = {
                    let q = shared.queue.lock().unwrap();
                    pick_job(&q, job.pool, shared.pools.pool_of(w), exhausted_seqs)
                        .map(|j| j.seq)
                };
                if next != Some(job.seq) {
                    break false;
                }
            }
        }
    };
    flush_stats(&mut local, &job.stats[lw]);
    exhausted
}

fn flush_stats(delta: &mut WorkerStats, slot: &OrderedMutex<WorkerStats>) {
    let mut s = slot.lock().unwrap();
    s.tasks += delta.tasks;
    s.items += delta.items;
    s.busy += delta.busy;
    s.queue_wait += delta.queue_wait;
    s.steals += delta.steals;
    s.failed_steals += delta.failed_steals;
    s.stolen_items += delta.stolen_items;
    *delta = WorkerStats::default();
}

/// Count `n` items as finished; the worker that brings the counter to
/// `total` finalizes the job.
fn complete_items(
    job: &Arc<Job>,
    n: usize,
    shared: &Shared,
    completed: &AtomicUsize,
) {
    if n == 0 {
        return;
    }
    let prev = job.executed.fetch_add(n, Ordering::AcqRel);
    if prev + n == job.total {
        finalize(job, shared, completed);
    }
}

fn make_report(job: &Job) -> SchedReport {
    SchedReport {
        scheme: job.config.scheme.name().to_string(),
        layout: job.config.layout.name().to_string(),
        victim: job.config.victim.name().to_string(),
        makespan: job.start.elapsed().as_secs_f64(),
        queue_delay: job.first_served_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        per_worker: job.stats.iter().map(|s| s.lock().unwrap().clone()).collect(),
    }
}

fn finalize(job: &Arc<Job>, shared: &Shared, completed: &AtomicUsize) {
    let report = make_report(job);
    {
        let mut q = shared.queue.lock().unwrap();
        q.jobs.retain(|j| j.seq != job.seq);
    }
    // No body call can be in flight here (every pulled task is counted
    // only after its call returns), which is what makes step 1 of
    // `publish_completion` — dropping the body before the completion
    // event becomes observable — sound.
    publish_completion(job, report, completed);
}

/// A task body panicked: record the payload, stop handing out tasks,
/// and drain the source so `executed` can still reach `total` (drained
/// items are counted but never run) — waiters unblock instead of
/// hanging, and the panic is resumed on the waiting thread. `w` is the
/// draining worker's pool-local id (sources are pool-scoped).
fn abort_job(
    job: &Arc<Job>,
    payload: PanicPayload,
    w: usize,
    shared: &Shared,
    completed: &AtomicUsize,
) {
    {
        let mut p = job.panic.lock().unwrap();
        if p.is_none() {
            *p = Some(payload);
        }
    }
    job.aborted.store(true, Ordering::Release);
    let drained = drain_source(job, w);
    complete_items(job, drained, shared, completed);
}

/// NUMA domain a queue is homed on: for per-core layouts it is the
/// owner's socket, for per-group layouts the group index, for the
/// centralized layout socket 0.
fn queue_socket_of(source: &dyn TaskSource, q: usize, topo: &Topology) -> usize {
    if source.n_queues() == topo.n_cores() {
        topo.socket_of(q)
    } else if source.n_queues() == topo.sockets {
        q
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sched::placement::PoolId;
    use crate::sched::queue::QueueLayout;
    use crate::sched::victim::VictimStrategy;
    use crate::topology::DeviceClass;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    fn host4() -> Arc<Topology> {
        Arc::new(Topology::symmetric("test4", 2, 2, 1.5, 1.0))
    }

    fn hetero4() -> Arc<Topology> {
        Arc::new(Topology::heterogeneous(
            "h",
            1,
            2,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 2, 2.0)],
        ))
    }

    fn exec(config: SchedConfig) -> Executor {
        Executor::new(host4(), Arc::new(config))
    }

    const LAYOUTS: [QueueLayout; 4] = [
        QueueLayout::Centralized { atomic: false },
        QueueLayout::Centralized { atomic: true },
        QueueLayout::PerGroup,
        QueueLayout::PerCore,
    ];

    fn coverage(exec: &Executor, spec: JobSpec) {
        let total = spec.items;
        let hits: Vec<AtomicUsize> =
            (0..total).map(|_| AtomicUsize::new(0)).collect();
        let report = exec.run(spec, |_w, range| {
            for i in range.iter() {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(report.total_items(), total);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} ran != once");
        }
    }

    #[test]
    fn small_borrowed_body_job_is_exactly_once() {
        // Miri-sized: exercises the `Scope::submit` lifetime transmute,
        // the borrowed-body completion barrier, and the ordered-lock
        // ranks on the full submit → dispatch → finalize path.
        let e = exec(SchedConfig::default());
        coverage(&e, JobSpec::new(64));
        assert_eq!(e.jobs_completed(), 1);
    }

    #[test]
    fn small_owned_body_job_is_exactly_once() {
        // Miri-sized twin of `owned_body_submit_and_wait`.
        let e = exec(SchedConfig::default().with_scheme(Scheme::Gss));
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let h = e.submit(JobSpec::new(48).named("small"), move |_w, r| {
            c.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(h.wait().total_items(), 48);
        assert_eq!(count.load(Ordering::Relaxed), 48);
    }

    #[test]
    fn small_cancel_of_a_finished_job_is_a_no_op() {
        // Miri-sized: the cancel-vs-completed race's settled side.
        let e = exec(SchedConfig::default());
        let h = e.submit(JobSpec::new(16), |_w, _r| {});
        while !h.is_finished() {
            std::thread::yield_now();
        }
        h.cancel();
        assert!(!h.was_cancelled(), "cancel after completion costs nothing");
        assert_eq!(h.wait().total_items(), 16);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items across layouts")]
    fn consecutive_jobs_reuse_the_pool() {
        for layout in LAYOUTS {
            let cfg = SchedConfig::default()
                .with_scheme(Scheme::Gss)
                .with_layout(layout)
                .with_victim(VictimStrategy::SeqPri);
            let e = exec(cfg);
            for total in [5_000, 1, 7_777] {
                coverage(&e, JobSpec::new(total));
            }
            assert_eq!(e.jobs_completed(), 3, "{layout:?}");
            assert_eq!(e.n_workers(), 4);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 1000-item jobs")]
    fn one_pool_runs_static_and_gss_back_to_back() {
        let e = exec(SchedConfig::default());
        let r1 = e.run(JobSpec::new(1000), |_w, _r| {});
        let r2 = e.run(
            JobSpec::new(1000).with_config(
                SchedConfig::default()
                    .with_scheme(Scheme::Gss)
                    .with_layout(QueueLayout::PerCore),
            ),
            |_w, _r| {},
        );
        assert_eq!(r1.scheme, "STATIC");
        assert_eq!(r1.layout, "CENTRAL");
        assert_eq!(r2.scheme, "GSS");
        assert_eq!(r2.layout, "PERCORE");
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 12 × 2000-item jobs")]
    fn many_jobs_never_respawn_workers() {
        let e = exec(SchedConfig::default().with_scheme(Scheme::Fac2));
        let seen: Mutex<HashSet<std::thread::ThreadId>> =
            Mutex::new(HashSet::new());
        for _ in 0..12 {
            e.run(JobSpec::new(2_000), |_w, _r| {
                seen.lock().unwrap().insert(std::thread::current().id());
            });
        }
        let distinct = seen.lock().unwrap().len();
        assert!(
            distinct <= e.n_workers(),
            "12 jobs used {distinct} distinct threads on a {}-worker pool",
            e.n_workers()
        );
        assert_eq!(e.jobs_completed(), 12);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items across layouts")]
    fn concurrent_jobs_multiplex_with_full_coverage() {
        for layout in LAYOUTS {
            let cfg = SchedConfig::default()
                .with_scheme(Scheme::Tss)
                .with_layout(layout);
            let e = exec(cfg);
            let a: Vec<AtomicUsize> =
                (0..6_000).map(|_| AtomicUsize::new(0)).collect();
            let b: Vec<AtomicUsize> =
                (0..4_321).map(|_| AtomicUsize::new(0)).collect();
            e.scope(|s| {
                let ha = s.submit(JobSpec::new(a.len()).named("a"), |_w, r| {
                    for i in r.iter() {
                        a[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                let hb = s.submit(JobSpec::new(b.len()).named("b"), |_w, r| {
                    for i in r.iter() {
                        b[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert_eq!(ha.wait().total_items(), a.len());
                assert_eq!(hb.wait().total_items(), b.len());
            });
            for (i, h) in a.iter().chain(b.iter()).enumerate() {
                assert_eq!(
                    h.load(Ordering::Relaxed),
                    1,
                    "{layout:?}: slot {i} ran != once"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items")]
    fn submitters_on_separate_threads_share_one_pool() {
        let e = exec(SchedConfig::default().with_scheme(Scheme::Mfsc));
        let e = &e;
        std::thread::scope(|s| {
            for n in [3_000usize, 5_000] {
                s.spawn(move || coverage(e, JobSpec::new(n)));
            }
        });
        assert_eq!(e.jobs_completed(), 2);
    }

    #[test]
    fn zero_item_job_completes_immediately() {
        let e = exec(SchedConfig::default());
        let r = e.run(JobSpec::new(0), |_w, _r| panic!("must not run"));
        assert_eq!(r.total_items(), 0);
        assert_eq!(e.jobs_completed(), 1);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 9999-item job")]
    fn owned_body_submit_and_wait() {
        let e = exec(SchedConfig::default().with_scheme(Scheme::Gss));
        let count = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&count);
        let handle = e.submit(JobSpec::new(9_999).named("owned"), move |_w, r| {
            c.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(handle.name(), "owned");
        let report = handle.wait();
        assert_eq!(report.total_items(), 9_999);
        assert_eq!(count.load(Ordering::Relaxed), 9_999);
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items")]
    fn body_panic_propagates_and_pool_survives() {
        let e = exec(SchedConfig::default().with_scheme(Scheme::Fac2));
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            e.run(JobSpec::new(1_000), |_w, r| {
                if r.start == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "body panic must propagate to the waiter");
        // the pool must still execute subsequent jobs correctly
        coverage(&e, JobSpec::new(2_500));
    }

    #[test]
    fn executor_partitions_workers_into_class_pools_at_spawn() {
        let e = Executor::new(hetero4(), Arc::new(SchedConfig::default()));
        assert_eq!(e.n_workers(), 4, "one thread per place, all classes");
        let pools = e.pools();
        assert_eq!(pools.n_pools(), 2);
        assert_eq!(pools.pool(0).class, DeviceClass::Cpu);
        assert_eq!(pools.pool(0).members, vec![0, 1]);
        assert_eq!(pools.pool(1).class, DeviceClass::Gpu);
        assert_eq!(pools.pool(1).members, vec![2, 3]);
    }

    /// Worker ids a job's body observed.
    fn workers_used(
        e: &Executor,
        spec: JobSpec,
        items: usize,
    ) -> HashSet<usize> {
        let seen = Mutex::new(HashSet::new());
        let r = e.run(spec, |w, _r| {
            seen.lock().unwrap().insert(w);
        });
        assert_eq!(r.total_items(), items);
        seen.into_inner().unwrap()
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 4000-item jobs × 5 rounds")]
    fn pinned_jobs_never_run_on_a_foreign_pool() {
        let e = Executor::new(
            hetero4(),
            Arc::new(
                SchedConfig::default()
                    .with_scheme(Scheme::Fac2)
                    .with_layout(QueueLayout::PerCore),
            ),
        );
        for _ in 0..5 {
            let cpu = workers_used(
                &e,
                JobSpec::new(4_000)
                    .with_placement(Placement::Class(DeviceClass::Cpu)),
                4_000,
            );
            assert!(
                cpu.is_subset(&HashSet::from([0, 1])),
                "cpu-pinned job ran on {cpu:?}"
            );
            // Pool(id) pins strictly on every build (Class(Gpu) would
            // degrade to the CPU pool without the pjrt feature).
            let gpu = workers_used(
                &e,
                JobSpec::new(4_000)
                    .with_placement(Placement::Pool(PoolId(1))),
                4_000,
            );
            assert!(
                gpu.is_subset(&HashSet::from([2, 3])),
                "gpu-pool job ran on {gpu:?}"
            );
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: 5000-item job")]
    fn unplaced_jobs_use_the_cpu_pool_on_hetero_topologies() {
        let e = Executor::new(hetero4(), Arc::new(SchedConfig::default()));
        let used = workers_used(&e, JobSpec::new(5_000), 5_000);
        assert!(
            used.is_subset(&HashSet::from([0, 1])),
            "Placement::Any must mean the default (CPU) pool, got {used:?}"
        );
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items")]
    fn pools_overlap_concurrent_jobs_with_full_coverage() {
        let e = Executor::new(
            hetero4(),
            Arc::new(SchedConfig::default().with_scheme(Scheme::Gss)),
        );
        let a: Vec<AtomicUsize> =
            (0..6_000).map(|_| AtomicUsize::new(0)).collect();
        let b: Vec<AtomicUsize> =
            (0..4_000).map(|_| AtomicUsize::new(0)).collect();
        e.scope(|s| {
            let ha = s.submit(
                JobSpec::new(a.len())
                    .named("cpu")
                    .with_placement(Placement::Class(DeviceClass::Cpu)),
                |w, r| {
                    assert!(w < 2, "cpu node on worker {w}");
                    for i in r.iter() {
                        a[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            let hb = s.submit(
                JobSpec::new(b.len())
                    .named("accel")
                    .with_placement(Placement::Pool(PoolId(1))),
                |w, r| {
                    assert!(w >= 2, "accel node on worker {w}");
                    for i in r.iter() {
                        b[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            assert_eq!(ha.wait().total_items(), a.len());
            assert_eq!(hb.wait().total_items(), b.len());
        });
        for (i, h) in a.iter().chain(b.iter()).enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "slot {i} ran != once");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: recovery job after the panic")]
    fn unsatisfiable_placement_on_plain_submit_panics_with_context() {
        let e = exec(SchedConfig::default());
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            e.run(
                JobSpec::new(10)
                    .named("fpga-job")
                    .with_placement(Placement::Class(DeviceClass::Fpga)),
                |_w, _r| {},
            );
        }));
        let msg = result.unwrap_err();
        let msg = msg
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("class:fpga"), "panic message was '{msg}'");
        // the pool survives
        coverage(&e, JobSpec::new(500));
    }

    #[test]
    #[cfg_attr(miri, ignore = "heavy: thousands of items × 3 policies")]
    fn every_policy_preserves_exactly_once_execution() {
        use crate::sched::session::SubmitOpts;
        for policy in TenancyPolicy::ALL {
            let e = Executor::new_with_policy(
                host4(),
                Arc::new(SchedConfig::default().with_scheme(Scheme::Gss)),
                policy,
            );
            assert_eq!(e.policy(), policy);
            let session = e.session();
            let a: Arc<Vec<AtomicUsize>> =
                Arc::new((0..5_000).map(|_| AtomicUsize::new(0)).collect());
            let b: Arc<Vec<AtomicUsize>> =
                Arc::new((0..3_333).map(|_| AtomicUsize::new(0)).collect());
            let a2 = Arc::clone(&a);
            let b2 = Arc::clone(&b);
            let ha = session.submit(
                JobSpec::new(a.len()).named("a"),
                SubmitOpts::new().tag("ta").priority(1).weight(3),
                move |_w, r| {
                    for i in r.iter() {
                        a2[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            let hb = session.submit(
                JobSpec::new(b.len()).named("b"),
                SubmitOpts::new().tag("tb"),
                move |_w, r| {
                    for i in r.iter() {
                        b2[i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            assert_eq!(ha.wait().total_items(), 5_000, "{policy:?}");
            assert_eq!(hb.wait().total_items(), 3_333, "{policy:?}");
            for (i, h) in a.iter().chain(b.iter()).enumerate() {
                assert_eq!(
                    h.load(Ordering::Relaxed),
                    1,
                    "{policy:?}: slot {i} ran != once"
                );
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "spin-gates on all four workers")]
    fn cancelling_a_queued_job_frees_the_pool() {
        use std::sync::atomic::AtomicBool;
        let e = exec(SchedConfig::default());
        let gate = Arc::new(AtomicBool::new(false));
        let entered = Arc::new(AtomicUsize::new(0));
        let (g, n) = (Arc::clone(&gate), Arc::clone(&entered));
        // one item per worker; every body blocks until released
        let blocker = e.submit(JobSpec::new(4).named("blocker"), move |_w, _r| {
            n.fetch_add(1, Ordering::SeqCst);
            while !g.load(Ordering::Acquire) {
                std::thread::yield_now();
            }
        });
        while entered.load(Ordering::SeqCst) < 4 {
            std::thread::yield_now();
        }
        // queued behind the blocker: nothing of it can have dispatched
        let ran = Arc::new(AtomicUsize::new(0));
        let r2 = Arc::clone(&ran);
        let victim = e.submit(JobSpec::new(10_000).named("victim"), move |_w, r| {
            r2.fetch_add(r.len(), Ordering::Relaxed);
        });
        victim.cancel();
        assert!(victim.was_cancelled());
        // the cancelled job completes (drained) while the pool is still
        // fully occupied by the blocker
        let report = victim.wait();
        assert_eq!(report.total_items(), 0, "every item was drained, not run");
        assert_eq!(ran.load(Ordering::Relaxed), 0);
        gate.store(true, Ordering::Release);
        assert_eq!(blocker.wait().total_items(), 4);
        // cancel is idempotent on finished jobs, and the pool survives
        coverage(&e, JobSpec::new(2_000));
    }

    #[test]
    fn report_names_follow_job_config() {
        let e = exec(SchedConfig::default());
        let r = e.run(
            JobSpec::new(100).with_config(
                SchedConfig::default()
                    .with_scheme(Scheme::Pss)
                    .with_layout(QueueLayout::PerCore)
                    .with_victim(VictimStrategy::RndPri),
            ),
            |_w, _r| {},
        );
        assert_eq!(r.scheme, "PSS");
        assert_eq!(r.layout, "PERCORE");
        assert_eq!(r.victim, "RNDPRI");
    }
}
