//! Bounded per-worker ring buffers of scheduler trace events.
//!
//! Design constraints (see the module doc of [`crate::obs`]):
//!
//! - **No locks, no allocations on the record path.** Each worker owns
//!   one lane of fixed-size slots; a record is one relaxed
//!   `fetch_add` on the lane head plus five relaxed/release stores.
//!   Submission-side events (enqueue, admit/shed, cancel) from
//!   non-worker threads go to a dedicated *control lane*
//!   ([`OBS_CONTROL_WORKER`]).
//! - **Off is one branch.** [`record`] loads a global `AtomicU8` mode
//!   with `Relaxed` and returns; nothing else is touched. The mode is
//!   set once by [`enable`] (CLI `trace=off|on|sampled:<n>`).
//! - **Bounded.** A lane holds [`DEFAULT_CAPACITY`] slots by default
//!   and overwrites its oldest events when full — tracing can never
//!   grow memory under an unbounded soak.
//!
//! Strings never cross the record path: job/node names and tenant tags
//! are carried as FNV-1a hashes ([`fnv1a`]). Tags are interned
//! submission-side ([`intern_tag`], called from `Tenancy::from_opts`,
//! off the dispatch path) so the exporter can resolve them back.
//!
//! Harvesting ([`drain`]) is cooperative, not synchronized: it is meant
//! to run at quiescence (after `wait()`/`join()` of everything traced).
//! A drain racing an in-flight record can observe a torn slot; the
//! release-store on the packed kind word keeps the *fields* of a
//! published slot consistent, and unpublished slots read as empty.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::config::TraceMode;

/// Worker id used for submission-side events recorded by threads that
/// are not pool workers (enqueue, admission, cancellation). Maps to the
/// last lane; any out-of-range worker id clamps there too.
pub const OBS_CONTROL_WORKER: usize = usize::MAX;

/// Job id for events that have no job in scope (park/unpark). Exempt
/// from `sampled:<n>` filtering.
pub const NO_JOB: u64 = u64::MAX;

/// Default ring capacity per lane, in events.
pub const DEFAULT_CAPACITY: usize = 4096;

/// What happened. The discriminants are the packed wire code inside a
/// ring slot (0 is reserved for "empty slot").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum TraceKind {
    /// A job entered the run queue (submission side).
    Enqueue = 1,
    /// A worker acquired the first chunk of a job — the end of its
    /// queueing-delay window.
    Dispatch = 2,
    /// A worker began executing one chunk.
    TaskStart = 3,
    /// ...and finished it.
    TaskEnd = 4,
    /// The acquired chunk was stolen from another worker's queue.
    Steal = 5,
    /// A steal round found nothing.
    FailedSteal = 6,
    /// A worker parked on the run-queue condvar.
    Park = 7,
    /// ...and woke up.
    Unpark = 8,
    /// A graph node completed (all items executed, status recorded).
    NodeComplete = 9,
    /// An arrival passed admission.
    Admit = 10,
    /// An arrival was rejected by admission.
    Shed = 11,
    /// A job was cancelled.
    Cancel = 12,
    /// A pool's width changed (elastic lend / reclaim / resize): the
    /// pool id rides the `name_hash` slot and the new width the
    /// `tag_hash` slot — the exporter turns these into a per-pool
    /// counter track.
    Resize = 13,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Enqueue => "enqueue",
            TraceKind::Dispatch => "dispatch",
            TraceKind::TaskStart => "task_start",
            TraceKind::TaskEnd => "task_end",
            TraceKind::Steal => "steal",
            TraceKind::FailedSteal => "failed_steal",
            TraceKind::Park => "park",
            TraceKind::Unpark => "unpark",
            TraceKind::NodeComplete => "node_complete",
            TraceKind::Admit => "admit",
            TraceKind::Shed => "shed",
            TraceKind::Cancel => "cancel",
            TraceKind::Resize => "resize",
        }
    }

    fn from_code(code: u8) -> Option<TraceKind> {
        Some(match code {
            1 => TraceKind::Enqueue,
            2 => TraceKind::Dispatch,
            3 => TraceKind::TaskStart,
            4 => TraceKind::TaskEnd,
            5 => TraceKind::Steal,
            6 => TraceKind::FailedSteal,
            7 => TraceKind::Park,
            8 => TraceKind::Unpark,
            9 => TraceKind::NodeComplete,
            10 => TraceKind::Admit,
            11 => TraceKind::Shed,
            12 => TraceKind::Cancel,
            13 => TraceKind::Resize,
            _ => return None,
        })
    }
}

/// One harvested event. `ts_ns` is nanoseconds since [`enable`] for
/// real runs, or virtual seconds × 1e9 for DES emission
/// ([`record_at`]); `worker` is the lane index (the control lane
/// reports as the highest index).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub ts_ns: u64,
    pub worker: u32,
    pub kind: TraceKind,
    /// Engine-local job/node id (executor job seq; DES global node
    /// index). Not comparable across engines — match on `name_hash`.
    pub job: u64,
    /// FNV-1a of the job/node name (0 = unnamed).
    pub name_hash: u64,
    /// FNV-1a of the tenant tag (0 = anonymous); resolvable back to the
    /// tag string via [`tag_name`] when it was interned.
    pub tag_hash: u64,
}

/// One ring slot: five atomics, single-writer in practice (one worker
/// per lane), published by the release-store of `packed`.
struct Slot {
    /// `kind as u64 | (worker as u64) << 8`; 0 = empty.
    packed: AtomicU64,
    ts_ns: AtomicU64,
    job: AtomicU64,
    name_hash: AtomicU64,
    tag_hash: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            packed: AtomicU64::new(0),
            ts_ns: AtomicU64::new(0),
            job: AtomicU64::new(0),
            name_hash: AtomicU64::new(0),
            tag_hash: AtomicU64::new(0),
        }
    }
}

/// One worker's ring: a head counter and a fixed slot array.
struct Lane {
    head: AtomicUsize,
    slots: Vec<Slot>,
}

impl Lane {
    fn with_capacity(capacity: usize) -> Lane {
        Lane {
            head: AtomicUsize::new(0),
            slots: (0..capacity).map(|_| Slot::empty()).collect(),
        }
    }

    fn record(&self, ts_ns: u64, worker: u32, kind: TraceKind, job: u64, name_hash: u64, tag_hash: u64) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        let slot = &self.slots[idx];
        slot.ts_ns.store(ts_ns, Ordering::Relaxed);
        slot.job.store(job, Ordering::Relaxed);
        slot.name_hash.store(name_hash, Ordering::Relaxed);
        slot.tag_hash.store(tag_hash, Ordering::Relaxed);
        let packed = kind as u64 | (worker as u64) << 8;
        slot.packed.store(packed, Ordering::Release);
    }

    /// Pop every published event in ring order (oldest first) and reset
    /// the lane. Meant to run at quiescence; see the module doc.
    fn drain(&self, out: &mut Vec<TraceEvent>) {
        let head = self.head.swap(0, Ordering::Relaxed);
        let cap = self.slots.len();
        let n = head.min(cap);
        let start = if head > cap { head % cap } else { 0 };
        for k in 0..n {
            let slot = &self.slots[(start + k) % cap];
            let packed = slot.packed.swap(0, Ordering::Acquire);
            let Some(kind) = TraceKind::from_code((packed & 0xFF) as u8) else {
                continue;
            };
            out.push(TraceEvent {
                ts_ns: slot.ts_ns.load(Ordering::Relaxed),
                worker: (packed >> 8) as u32,
                kind,
                job: slot.job.load(Ordering::Relaxed),
                name_hash: slot.name_hash.load(Ordering::Relaxed),
                tag_hash: slot.tag_hash.load(Ordering::Relaxed),
            });
        }
    }
}

/// All lanes: one per worker plus the trailing control lane.
pub(crate) struct TraceBuffer {
    lanes: Vec<Lane>,
}

impl TraceBuffer {
    pub(crate) fn new(workers: usize, capacity: usize) -> TraceBuffer {
        let capacity = capacity.max(16);
        TraceBuffer {
            lanes: (0..workers + 1).map(|_| Lane::with_capacity(capacity)).collect(),
        }
    }

    fn record(&self, ts_ns: u64, worker: usize, kind: TraceKind, job: u64, name_hash: u64, tag_hash: u64) {
        let lane = worker.min(self.lanes.len() - 1);
        self.lanes[lane].record(ts_ns, lane as u32, kind, job, name_hash, tag_hash);
    }

    fn drain(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for lane in &self.lanes {
            lane.drain(&mut out);
        }
        // Stable by timestamp: intra-lane order is preserved for ties.
        out.sort_by_key(|e| e.ts_ns);
        out
    }
}

// Mode codes for the one-relaxed-load gate.
const MODE_OFF: u8 = 0;
const MODE_ON: u8 = 1;
const MODE_SAMPLED: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_OFF);
static SAMPLE_N: AtomicU64 = AtomicU64::new(1);
static BUFFER: OnceLock<TraceBuffer> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
static TAGS: OnceLock<Mutex<BTreeMap<u64, String>>> = OnceLock::new();

/// FNV-1a over the bytes of `s` — the hash carried in place of strings
/// on the record path (no allocation, one pass).
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash `tag` and remember the mapping so [`tag_name`] (and the
/// exporter) can resolve it back. Takes a plain `Mutex` — callers are
/// submission-side (`Tenancy::from_opts`), never the dispatch path.
/// The empty (anonymous) tag interns as 0.
pub fn intern_tag(tag: &str) -> u64 {
    if tag.is_empty() {
        return 0;
    }
    let h = fnv1a(tag);
    let map = TAGS.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut m = map.lock().unwrap_or_else(|e| e.into_inner());
    m.entry(h).or_insert_with(|| tag.to_string());
    h
}

/// Resolve an interned tag hash back to its string.
pub fn tag_name(hash: u64) -> Option<String> {
    let map = TAGS.get()?;
    let m = map.lock().unwrap_or_else(|e| e.into_inner());
    m.get(&hash).cloned()
}

/// Turn tracing on (or off) for this process. Lanes are sized here —
/// call before creating the executor, with its worker count; events
/// from higher worker ids clamp into the control lane. Idempotent on
/// the buffer: the first call sizes the lanes for the process lifetime.
pub fn enable(mode: TraceMode, workers: usize, capacity: usize) {
    EPOCH.get_or_init(Instant::now);
    BUFFER.get_or_init(|| TraceBuffer::new(workers.max(1), capacity));
    match mode {
        TraceMode::Off => MODE.store(MODE_OFF, Ordering::Relaxed),
        TraceMode::On => MODE.store(MODE_ON, Ordering::Relaxed),
        TraceMode::Sampled(n) => {
            SAMPLE_N.store(n.max(1) as u64, Ordering::Relaxed);
            MODE.store(MODE_SAMPLED, Ordering::Relaxed);
        }
    }
}

/// Is any tracing active? One relaxed load — cheap enough to guard
/// hash precomputation at call sites.
#[inline]
pub fn enabled() -> bool {
    MODE.load(Ordering::Relaxed) != MODE_OFF
}

/// Record one event at the current wall-clock offset. When tracing is
/// off this is a relaxed load and a branch; it never locks and never
/// allocates. `worker` is the recording worker's pool index
/// ([`OBS_CONTROL_WORKER`] from submission-side threads).
#[inline]
pub fn record(kind: TraceKind, worker: usize, job: u64, name_hash: u64, tag_hash: u64) {
    if MODE.load(Ordering::Relaxed) == MODE_OFF {
        return;
    }
    record_slow(None, kind, worker, job, name_hash, tag_hash);
}

/// Record one event at an explicit virtual timestamp — the DES
/// emission path (`sim::graph`), so real and simulated runs produce
/// one diffable stream. Same gate and sampling as [`record`].
#[inline]
pub fn record_at(ts_ns: u64, kind: TraceKind, worker: usize, job: u64, name_hash: u64, tag_hash: u64) {
    if MODE.load(Ordering::Relaxed) == MODE_OFF {
        return;
    }
    record_slow(Some(ts_ns), kind, worker, job, name_hash, tag_hash);
}

#[cold]
fn record_slow(
    ts_ns: Option<u64>,
    kind: TraceKind,
    worker: usize,
    job: u64,
    name_hash: u64,
    tag_hash: u64,
) {
    if MODE.load(Ordering::Relaxed) == MODE_SAMPLED
        && job != NO_JOB
        && job % SAMPLE_N.load(Ordering::Relaxed) != 0
    {
        return;
    }
    let Some(buf) = BUFFER.get() else { return };
    let ts = ts_ns.unwrap_or_else(|| {
        EPOCH.get().map(|e| e.elapsed().as_nanos() as u64).unwrap_or(0)
    });
    buf.record(ts, worker, kind, job, name_hash, tag_hash);
    crate::obs::live::metrics().count_kind(kind);
}

/// Harvest and clear every lane, oldest-first per lane, merged by
/// timestamp. Run at quiescence (see the module doc).
pub fn drain() -> Vec<TraceEvent> {
    BUFFER.get().map(|b| b.drain()).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global MODE/BUFFER are deliberately not exercised here: lib
    // unit tests share one process, and a globally-enabled trace would
    // capture events from concurrently running executor tests. The
    // ring mechanics are tested on standalone buffers; the global gate
    // is covered by the obs_trace_integration binary (own process).

    #[test]
    fn fnv1a_distinguishes_and_is_stable() {
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a("colstats"), fnv1a("stats"));
        assert_eq!(fnv1a("colstats"), fnv1a("colstats"));
    }

    #[test]
    fn lane_records_and_drains_in_order() {
        let buf = TraceBuffer::new(2, 16);
        buf.record(10, 0, TraceKind::Enqueue, 1, 11, 0);
        buf.record(20, 0, TraceKind::Dispatch, 1, 11, 0);
        buf.record(15, 1, TraceKind::Park, NO_JOB, 0, 0);
        let evs = buf.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(
            evs.iter().map(|e| e.ts_ns).collect::<Vec<_>>(),
            vec![10, 15, 20],
            "merged by timestamp"
        );
        assert_eq!(evs[0].kind, TraceKind::Enqueue);
        assert_eq!(evs[2].kind, TraceKind::Dispatch);
        assert!(buf.drain().is_empty(), "drain clears the lanes");
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let buf = TraceBuffer::new(1, 16);
        for i in 0..20u64 {
            buf.record(i, 0, TraceKind::TaskStart, i, 0, 0);
        }
        let evs = buf.drain();
        assert_eq!(evs.len(), 16, "bounded at capacity");
        assert_eq!(evs.first().map(|e| e.ts_ns), Some(4), "oldest 4 overwritten");
        assert_eq!(evs.last().map(|e| e.ts_ns), Some(19));
    }

    #[test]
    fn out_of_range_worker_clamps_to_control_lane() {
        let buf = TraceBuffer::new(2, 16);
        buf.record(1, OBS_CONTROL_WORKER, TraceKind::Admit, 0, 0, 7);
        buf.record(2, 99, TraceKind::Shed, 1, 0, 7);
        let evs = buf.drain();
        assert_eq!(evs.len(), 2);
        // 2 workers -> lanes 0,1 and control lane 2
        assert!(evs.iter().all(|e| e.worker == 2));
    }

    #[test]
    fn tag_interning_round_trips() {
        let h = intern_tag("obs-test-tag");
        assert_eq!(h, fnv1a("obs-test-tag"));
        assert_eq!(tag_name(h).as_deref(), Some("obs-test-tag"));
        assert_eq!(intern_tag(""), 0, "anonymous tag is 0");
    }

    #[test]
    fn kind_codes_round_trip() {
        for kind in [
            TraceKind::Enqueue,
            TraceKind::Dispatch,
            TraceKind::TaskStart,
            TraceKind::TaskEnd,
            TraceKind::Steal,
            TraceKind::FailedSteal,
            TraceKind::Park,
            TraceKind::Unpark,
            TraceKind::NodeComplete,
            TraceKind::Admit,
            TraceKind::Shed,
            TraceKind::Cancel,
            TraceKind::Resize,
        ] {
            assert_eq!(TraceKind::from_code(kind as u8), Some(kind));
        }
        assert_eq!(TraceKind::from_code(0), None, "0 is the empty slot");
    }
}
