//! Trace-analysis acceptance (the PR 9 tentpole pins):
//!
//! 1. **Critical-path attribution**: a traced DES replay of the
//!    unbalanced diamond on the modelled 20-core machine yields an
//!    `obs::Analysis` whose attributed span sum lands within 5% of the
//!    measured makespan (in virtual time the chain tiles it exactly —
//!    a parent's `NodeComplete` and its dependent's `Enqueue` share a
//!    timestamp).
//! 2. **Trace-calibrated retuning**: the *true* workload is a skewed
//!    diamond (one branch 10x heavier than the tuner's assumed shape
//!    says). A traced replay of the truth feeds
//!    `CostModel::calibrate_from_trace`; `tune_graph_calibrated` on
//!    the assumed shape must then reproduce-or-beat plain assumed-cost
//!    `tune_graph` when both tuned assignments are replayed against
//!    the true shape on the modelled hetero56 machine.
//!
//! This suite owns its process, so arming the global trace gate is
//! safe (the lib unit tests deliberately never touch it).

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use daphne_sched::config::{GraphMode, SchedConfig, TraceMode};
use daphne_sched::obs::{trace, Analysis};
use daphne_sched::sched::autotune::{self, SearchSpace};
use daphne_sched::sched::{Placement, QueueLayout, Scheme, VictimStrategy};
use daphne_sched::sim::{
    self, CostModel, GraphShape, NodeModel, TraceCalibration,
};
use daphne_sched::topology::Topology;

const SEED: u64 = 42;
/// Items per diamond branch — small enough that the per-chunk
/// `TaskStart`/`TaskEnd` stream fits the trace rings with room to
/// spare.
const ITEMS: usize = 48;
const PER_ITEM: f64 = 1e-5;
/// The true workload's heavy-branch multiplier (what the assumed shape
/// gets wrong).
const SKEW: f64 = 10.0;

/// The diamond the tuner *assumes*: both branches equally cheap.
fn assumed_shape() -> GraphShape {
    GraphShape::new("skewed-diamond")
        .node(NodeModel::uniform("src", ITEMS, PER_ITEM))
        .node(NodeModel::uniform("lhs", ITEMS, PER_ITEM).after("src"))
        .node(NodeModel::uniform("rhs", ITEMS, PER_ITEM).after("src"))
        .node(
            NodeModel::uniform("sink", ITEMS, PER_ITEM)
                .after("lhs")
                .after("rhs"),
        )
}

/// The *true* workload: identical topology, but `rhs` is SKEW× heavier
/// per item.
fn true_shape() -> GraphShape {
    GraphShape::new("skewed-diamond")
        .node(NodeModel::uniform("src", ITEMS, PER_ITEM))
        .node(NodeModel::uniform("lhs", ITEMS, PER_ITEM).after("src"))
        .node(
            NodeModel::uniform("rhs", ITEMS, PER_ITEM * SKEW).after("src"),
        )
        .node(
            NodeModel::uniform("sink", ITEMS, PER_ITEM)
                .after("lhs")
                .after("rhs"),
        )
}

fn hetero_space(machine: &Topology) -> SearchSpace {
    SearchSpace {
        schemes: vec![Scheme::Static, Scheme::Gss],
        layouts: vec![QueueLayout::Centralized { atomic: false }],
        victims: vec![VictimStrategy::SeqPri],
        placements: SearchSpace::for_machine(machine).placements,
    }
}

/// Replay a tuned assignment against the TRUE workload — the measure
/// both tunings are judged by.
fn replay_on_truth(
    machine: &Topology,
    tuning: &autotune::GraphTuning,
) -> f64 {
    let configs: Vec<SchedConfig> =
        tuning.per_node.iter().map(|c| c.config.clone()).collect();
    let places: Vec<Placement> =
        tuning.per_node.iter().map(|c| c.placement).collect();
    sim::replay_placed(
        &true_shape(),
        machine,
        &configs,
        &places,
        &CostModel::recorded(),
        GraphMode::Dag,
    )
    .expect("the diamond replays on the hetero machine")
    .makespan()
}

/// One test function: the trace buffer is process-global, so both
/// halves must run sequentially in a single test.
#[test]
fn critical_path_attribution_and_calibrated_retuning() {
    trace::enable(TraceMode::On, 64, trace::DEFAULT_CAPACITY);
    let _ = trace::drain();

    // --- 1. critical-path attribution on the traced diamond replay ---
    let machine = Topology::broadwell20();
    let shape = GraphShape::unbalanced_diamond(10);
    let out = sim::replay(
        &shape,
        &machine,
        &SchedConfig::fine_grained().with_seed(SEED),
        &CostModel::daphne_like(),
        GraphMode::Dag,
    )
    .expect("the diamond is acyclic");
    let events = trace::drain();
    assert!(!events.is_empty(), "the DES replay must emit trace events");
    let analysis = Analysis::from_events(&events);
    assert!(
        !analysis.critical_path.is_empty(),
        "the replay must recover a critical path"
    );
    // acceptance pin: attributed span sum within 5% of the measured
    // makespan (exact in virtual time)
    let ratio = analysis.crit_ratio();
    assert!(
        (ratio - 1.0).abs() <= 0.05,
        "attributed {} of {} makespan ns (ratio {ratio})",
        analysis.attributed_ns,
        analysis.makespan_ns
    );
    // the trace's makespan is the replay's makespan (both virtual ns)
    let replayed_ns = out.makespan() * 1e9;
    assert!(
        (analysis.makespan_ns as f64 - replayed_ns).abs()
            <= 0.05 * replayed_ns,
        "trace makespan {} vs replayed {}",
        analysis.makespan_ns,
        replayed_ns
    );

    // --- 2. trace-calibrated retuning beats assumed-cost tuning ---
    let machine = Topology::hetero56();
    // trace the TRUE workload once (the "observed production run")
    let _ = sim::replay(
        &true_shape(),
        &machine,
        &SchedConfig::fine_grained().with_seed(SEED),
        &CostModel::recorded(),
        GraphMode::Dag,
    )
    .expect("the true diamond replays");
    let events = trace::drain();
    let cal: TraceCalibration =
        CostModel::calibrate_from_trace(&events);
    assert!(!cal.is_empty(), "the traced replay must yield calibration");
    // the calibration saw the skew the assumed shape misses
    let (lhs, rhs) = (
        cal.service_secs("lhs").expect("lhs measured"),
        cal.service_secs("rhs").expect("rhs measured"),
    );
    assert!(
        rhs > 3.0 * lhs,
        "calibration must surface the heavy branch: lhs {lhs} rhs {rhs}"
    );

    let space = hetero_space(&machine);
    let costs = CostModel::recorded();
    let assumed =
        autotune::tune_graph(&assumed_shape(), &machine, &costs, &space, SEED, 1)
            .expect("assumed tuning resolves");
    let (recosted, calibrated) = autotune::tune_graph_calibrated(
        &assumed_shape(),
        &machine,
        &costs,
        &space,
        SEED,
        1,
        &cal,
    )
    .expect("calibrated tuning resolves");
    // the recosted shape carries the measured skew into the oracle
    let heavy = recosted
        .nodes()
        .iter()
        .find(|n| n.name == "rhs")
        .expect("rhs survives recosting");
    assert!(
        heavy.workload.total_cost() > 3.0 * PER_ITEM * ITEMS as f64,
        "recosted rhs total {}",
        heavy.workload.total_cost()
    );

    // judged on the TRUE workload, calibration reproduces or beats the
    // assumed-cost tuning (acceptance pin)
    let assumed_makespan = replay_on_truth(&machine, &assumed);
    let calibrated_makespan = replay_on_truth(&machine, &calibrated);
    assert!(
        calibrated_makespan <= assumed_makespan * 1.01,
        "calibrated {calibrated_makespan}s must reproduce or beat \
         assumed {assumed_makespan}s on the true workload"
    );
}
