//! DaphneDSL lexer.

/// Tokens of the DaphneDSL subset.
#[derive(Debug, Clone, PartialEq)]
pub enum Token {
    Ident(String),
    /// `$name` CLI parameter reference.
    Param(String),
    Num(f64),
    Str(String),
    /// `while`
    While,
    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Assign,
    Plus,
    Minus,
    Star,
    Slash,
    Gt,
    Lt,
    Ge,
    Le,
    Eq,
    Ne,
    And,
    Or,
}

/// Lex a script; `#` starts a line comment.
pub fn lex(src: &str) -> Result<Vec<Token>, String> {
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' | '\r' | '\n' => i += 1,
            '#' => {
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
            }
            '(' => {
                out.push(Token::LParen);
                i += 1;
            }
            ')' => {
                out.push(Token::RParen);
                i += 1;
            }
            '{' => {
                out.push(Token::LBrace);
                i += 1;
            }
            '}' => {
                out.push(Token::RBrace);
                i += 1;
            }
            '[' => {
                out.push(Token::LBracket);
                i += 1;
            }
            ']' => {
                out.push(Token::RBracket);
                i += 1;
            }
            ',' => {
                out.push(Token::Comma);
                i += 1;
            }
            ';' => {
                out.push(Token::Semi);
                i += 1;
            }
            '+' => {
                out.push(Token::Plus);
                i += 1;
            }
            '-' => {
                out.push(Token::Minus);
                i += 1;
            }
            '*' => {
                out.push(Token::Star);
                i += 1;
            }
            '/' => {
                out.push(Token::Slash);
                i += 1;
            }
            '&' => {
                // accept & and &&
                i += if b.get(i + 1) == Some(&'&') { 2 } else { 1 };
                out.push(Token::And);
            }
            '|' => {
                i += if b.get(i + 1) == Some(&'|') { 2 } else { 1 };
                out.push(Token::Or);
            }
            '=' => {
                if b.get(i + 1) == Some(&'=') {
                    out.push(Token::Eq);
                    i += 2;
                } else {
                    out.push(Token::Assign);
                    i += 1;
                }
            }
            '!' => {
                if b.get(i + 1) == Some(&'=') {
                    out.push(Token::Ne);
                    i += 2;
                } else {
                    return Err(format!("lex: stray '!' at char {i}"));
                }
            }
            '>' => {
                if b.get(i + 1) == Some(&'=') {
                    out.push(Token::Ge);
                    i += 2;
                } else {
                    out.push(Token::Gt);
                    i += 1;
                }
            }
            '<' => {
                if b.get(i + 1) == Some(&'=') {
                    out.push(Token::Le);
                    i += 2;
                } else {
                    out.push(Token::Lt);
                    i += 1;
                }
            }
            '"' => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != '"' {
                    j += 1;
                }
                if j == b.len() {
                    return Err("lex: unterminated string".into());
                }
                out.push(Token::Str(b[start..j].iter().collect()));
                i = j + 1;
            }
            '$' => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                if j == start {
                    return Err(format!("lex: bare '$' at char {i}"));
                }
                out.push(Token::Param(b[start..j].iter().collect()));
                i = j;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                let mut j = i;
                while j < b.len()
                    && (b[j].is_ascii_digit() || b[j] == '.' || b[j] == 'e'
                        || b[j] == 'E'
                        || ((b[j] == '+' || b[j] == '-')
                            && matches!(b[j - 1], 'e' | 'E')))
                {
                    j += 1;
                }
                let text: String = b[start..j].iter().collect();
                let n = text
                    .parse::<f64>()
                    .map_err(|_| format!("lex: bad number '{text}'"))?;
                out.push(Token::Num(n));
                i = j;
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                let mut j = i;
                // idents may contain '.' (as.si64)
                while j < b.len()
                    && (b[j].is_alphanumeric() || b[j] == '_' || b[j] == '.')
                {
                    j += 1;
                }
                let word: String = b[start..j].iter().collect();
                out.push(match word.as_str() {
                    "while" => Token::While,
                    _ => Token::Ident(word),
                });
                i = j;
            }
            other => return Err(format!("lex: unexpected '{other}' at {i}")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_listing1_shapes() {
        let toks = lex(crate::dsl::LISTING_1_CC).unwrap();
        assert!(toks.contains(&Token::While));
        assert!(toks.contains(&Token::Param("f".into())));
        assert!(toks.contains(&Token::Ident("rowMaxs".into())));
        assert!(toks.contains(&Token::Ne));
        assert!(toks.contains(&Token::And));
        assert!(toks.contains(&Token::Le));
    }

    #[test]
    fn lexes_listing2_shapes() {
        let toks = lex(crate::dsl::LISTING_2_LINREG).unwrap();
        assert!(toks.contains(&Token::Ident("as.si64".into())));
        assert!(toks.contains(&Token::LBracket));
        assert!(toks.contains(&Token::Param("numCols".into())));
        assert!(toks.iter().any(|t| matches!(t, Token::Num(n) if *n == 0.001)));
    }

    #[test]
    fn comments_are_skipped() {
        let toks = lex("# hello\nx = 1; # trailing\n").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::Ident("x".into()),
                Token::Assign,
                Token::Num(1.0),
                Token::Semi
            ]
        );
    }

    #[test]
    fn operators_disambiguate() {
        assert_eq!(
            lex("a != b == c <= d >= e").unwrap(),
            vec![
                Token::Ident("a".into()),
                Token::Ne,
                Token::Ident("b".into()),
                Token::Eq,
                Token::Ident("c".into()),
                Token::Le,
                Token::Ident("d".into()),
                Token::Ge,
                Token::Ident("e".into()),
            ]
        );
    }

    #[test]
    fn errors_on_garbage() {
        assert!(lex("x = @").is_err());
        assert!(lex("\"unterminated").is_err());
        assert!(lex("x ! y").is_err());
        assert!(lex("$ alone").is_err());
    }
}
