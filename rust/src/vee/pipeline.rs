//! Pipelines: named sequences of vectorized operators with barriers.
//!
//! [`Pipeline::run`] submits one job per stage to the engine's resident
//! executor and waits between stages (the barrier); worker threads are
//! *not* respawned per stage.

use super::Vee;
use crate::sched::{SchedReport, TaskRange};

/// One vectorized operator: a name, an item count, and a body executed
/// over task ranges.
pub struct Stage<'a> {
    pub name: String,
    pub items: usize,
    #[allow(clippy::type_complexity)]
    pub body: Box<dyn Fn(usize, TaskRange) + Send + Sync + 'a>,
}

impl<'a> Stage<'a> {
    pub fn new<F>(name: &str, items: usize, body: F) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'a,
    {
        Stage { name: name.to_string(), items, body: Box::new(body) }
    }
}

/// A sequence of stages (barrier between each).
#[derive(Default)]
pub struct Pipeline<'a> {
    pub name: String,
    pub stages: Vec<Stage<'a>>,
}

impl<'a> Pipeline<'a> {
    pub fn new(name: &str) -> Self {
        Pipeline { name: name.to_string(), stages: Vec::new() }
    }

    pub fn stage<F>(mut self, name: &str, items: usize, body: F) -> Self
    where
        F: Fn(usize, TaskRange) + Send + Sync + 'a,
    {
        self.stages.push(Stage::new(name, items, body));
        self
    }

    pub fn run(&self, vee: &Vee) -> PipelineReport {
        let mut reports = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            let report = vee.execute(stage.items, &stage.body);
            reports.push((stage.name.clone(), report));
        }
        PipelineReport { pipeline: self.name.clone(), stages: reports }
    }
}

/// Per-stage scheduling reports for one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub pipeline: String,
    pub stages: Vec<(String, SchedReport)>,
}

impl PipelineReport {
    pub fn total_time(&self) -> f64 {
        self.stages.iter().map(|(_, r)| r.makespan).sum()
    }

    pub fn stage(&self, name: &str) -> Option<&SchedReport> {
        self.stages.iter().find(|(n, _)| n == name).map(|(_, r)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn stages_run_in_order_with_barriers() {
        let vee = Vee::host_default();
        let a_done = AtomicUsize::new(0);
        let saw_a_complete = AtomicUsize::new(1);
        let pipeline = Pipeline::new("test")
            .stage("a", 1000, |_w, r| {
                a_done.fetch_add(r.len(), Ordering::SeqCst);
            })
            .stage("b", 500, |_w, _r| {
                // barrier semantics: stage a fully done before b starts
                if a_done.load(Ordering::SeqCst) != 1000 {
                    saw_a_complete.store(0, Ordering::SeqCst);
                }
            });
        let report = pipeline.run(&vee);
        assert_eq!(saw_a_complete.load(Ordering::SeqCst), 1);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stage("a").unwrap().total_items(), 1000);
        assert_eq!(report.stage("b").unwrap().total_items(), 500);
        assert!(report.total_time() > 0.0);
    }
}
