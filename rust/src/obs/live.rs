//! Live scheduler metrics: a fixed registry of atomic counters,
//! snapshotted on an interval during `serve` soaks.
//!
//! The registry is a *struct of atomics*, not a dynamic map — there is
//! nothing to look up, lock, or allocate when a counter is bumped, so
//! it is safe to touch from anywhere. Two update disciplines coexist:
//!
//! - **Admission-side counters** (`admitted`, `shed`,
//!   `backlog_high_water`) are maintained unconditionally by
//!   `Session::try_submit_graph` and the serving loop — they are off
//!   the worker dispatch path and cost one relaxed RMW per *arrival*.
//! - **Dispatch-side counters** (`enqueued`, `completed`, `steals`,
//!   `failed_steals`, `parks`, `unparks`, `cancelled`, `repicks`) are
//!   bumped only while tracing is enabled, inside the trace-record
//!   slow path ([`MetricsRegistry::count_kind`]) or behind the same
//!   one-relaxed-load gate ([`note_repick`]) — `trace=off` leaves the
//!   dispatch path exactly as it was.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::obs::trace::TraceKind;

/// Process-global counter registry. All counters are cumulative since
/// process start; [`MetricsRegistry::snapshot`] turns them into plain
/// numbers, [`MetricsRegistry::reset`] zeroes them between soaks.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    /// Arrivals accepted by admission (`Session::try_submit_graph`).
    pub admitted: AtomicU64,
    /// Arrivals rejected by admission.
    pub shed: AtomicU64,
    /// High-water mark of the request tag's live-job backlog
    /// (`fetch_max` per arrival from the serving loop).
    pub backlog_high_water: AtomicU64,
    /// Jobs pushed to the run queue (trace-gated).
    pub enqueued: AtomicU64,
    /// Graph nodes completed (trace-gated).
    pub completed: AtomicU64,
    /// Jobs cancelled (trace-gated).
    pub cancelled: AtomicU64,
    /// Successful chunk steals (trace-gated).
    pub steals: AtomicU64,
    /// Steal rounds that found nothing (trace-gated).
    pub failed_steals: AtomicU64,
    /// Workers parked on the run-queue condvar (trace-gated).
    pub parks: AtomicU64,
    /// ...and woken (trace-gated).
    pub unparks: AtomicU64,
    /// Policy re-pick evaluations under non-FIFO policies
    /// (trace-gated; see `POLICY_REPICK_STRIDE`).
    pub repicks: AtomicU64,
    /// Elastic pool-width changes (trace-gated; one per pool per
    /// lend/reclaim/resize — see `crate::sched::elastic`).
    pub resizes: AtomicU64,
    /// Per-pool width gauges (maintained unconditionally by the elastic
    /// control plane — `set_pool_widths` — so `metrics_interval=`
    /// snapshots record every resize even with `trace=off`). Value 0 =
    /// pool absent or never published.
    pub pool_width: [AtomicU64; MAX_POOL_GAUGES],
}

/// Gauge slots for per-pool widths. Pools beyond this many (no built-in
/// topology has more than two) are simply not gauged.
pub const MAX_POOL_GAUGES: usize = 8;

static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-global registry.
pub fn metrics() -> &'static MetricsRegistry {
    REGISTRY.get_or_init(MetricsRegistry::default)
}

/// Bump `repicks` iff tracing is enabled — the dispatch-path re-pick
/// site has no trace event kind of its own, but the counter rides the
/// same one-relaxed-load gate.
#[inline]
pub fn note_repick() {
    if crate::obs::trace::enabled() {
        metrics().repicks.fetch_add(1, Ordering::Relaxed);
    }
}

impl MetricsRegistry {
    /// Dispatch-side counting, driven from the trace-record slow path
    /// (so it inherits the `trace=` gate). Admission kinds are counted
    /// at their submission sites instead — unconditionally — and are
    /// skipped here to avoid double counting.
    pub(crate) fn count_kind(&self, kind: TraceKind) {
        let counter = match kind {
            TraceKind::Enqueue => &self.enqueued,
            TraceKind::NodeComplete => &self.completed,
            TraceKind::Cancel => &self.cancelled,
            TraceKind::Steal => &self.steals,
            TraceKind::FailedSteal => &self.failed_steals,
            TraceKind::Park => &self.parks,
            TraceKind::Unpark => &self.unparks,
            TraceKind::Resize => &self.resizes,
            TraceKind::Dispatch
            | TraceKind::TaskStart
            | TraceKind::TaskEnd
            | TraceKind::Admit
            | TraceKind::Shed => return,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish the current pool widths (control-plane side, one relaxed
    /// store per pool — unconditional, so snapshots see widths even
    /// with tracing off). Pools beyond [`MAX_POOL_GAUGES`] are dropped.
    pub fn set_pool_widths(&self, widths: &[usize]) {
        for (slot, &w) in self.pool_width.iter().zip(widths) {
            slot.store(w as u64, Ordering::Relaxed);
        }
    }

    /// Plain-number snapshot at soak offset `t` seconds.
    pub fn snapshot(&self, t: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            t,
            admitted: self.admitted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            backlog_high_water: self.backlog_high_water.load(Ordering::Relaxed),
            enqueued: self.enqueued.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            failed_steals: self.failed_steals.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            unparks: self.unparks.load(Ordering::Relaxed),
            repicks: self.repicks.load(Ordering::Relaxed),
            resizes: self.resizes.load(Ordering::Relaxed),
            pool_width: {
                let mut w = [0u64; MAX_POOL_GAUGES];
                for (out, slot) in w.iter_mut().zip(&self.pool_width) {
                    *out = slot.load(Ordering::Relaxed);
                }
                w
            },
        }
    }

    /// Zero every counter (between soaks; counters are process-global).
    pub fn reset(&self) {
        for c in [
            &self.admitted,
            &self.shed,
            &self.backlog_high_water,
            &self.enqueued,
            &self.completed,
            &self.cancelled,
            &self.steals,
            &self.failed_steals,
            &self.parks,
            &self.unparks,
            &self.repicks,
            &self.resizes,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for slot in &self.pool_width {
            slot.store(0, Ordering::Relaxed);
        }
    }
}

/// One interval sample of the registry, appended to `ServeReport`
/// during soaks (`metrics_interval=` seconds; cumulative values).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    /// Wall-clock soak offset of the sample, in seconds.
    pub t: f64,
    pub admitted: u64,
    pub shed: u64,
    pub backlog_high_water: u64,
    pub enqueued: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub steals: u64,
    pub failed_steals: u64,
    pub parks: u64,
    pub unparks: u64,
    pub repicks: u64,
    pub resizes: u64,
    /// Per-pool width gauges at sample time (0 = pool absent).
    pub pool_width: [u64; MAX_POOL_GAUGES],
}

impl MetricsSnapshot {
    pub fn header() -> String {
        format!(
            "{:>7} {:>9} {:>6} {:>8} {:>9} {:>9} {:>7} {:>8} {:>7} {:>7} {:>7} {:>8}",
            "t(s)",
            "admitted",
            "shed",
            "backlog*",
            "enqueued",
            "completed",
            "steals",
            "fsteals",
            "parks",
            "repicks",
            "resizes",
            "widths"
        )
    }

    pub fn row(&self) -> String {
        format!(
            "{:>7.2} {:>9} {:>6} {:>8} {:>9} {:>9} {:>7} {:>8} {:>7} {:>7} {:>7} {:>8}",
            self.t,
            self.admitted,
            self.shed,
            self.backlog_high_water,
            self.enqueued,
            self.completed,
            self.steals,
            self.failed_steals,
            self.parks,
            self.repicks,
            self.resizes,
            self.widths_str()
        )
    }

    /// The non-empty prefix of the width gauges as one `a/b` token
    /// (`"-"` when no pool has published a width yet) — a single
    /// whitespace-free column so rows keep aligning with the header.
    pub fn widths_str(&self) -> String {
        let n = self
            .pool_width
            .iter()
            .rposition(|&w| w > 0)
            .map_or(0, |i| i + 1);
        if n == 0 {
            return "-".to_string();
        }
        self.pool_width[..n]
            .iter()
            .map(|w| w.to_string())
            .collect::<Vec<_>>()
            .join("/")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_kind_routes_dispatch_side_counters() {
        let reg = MetricsRegistry::default();
        reg.count_kind(TraceKind::Steal);
        reg.count_kind(TraceKind::Steal);
        reg.count_kind(TraceKind::FailedSteal);
        reg.count_kind(TraceKind::Park);
        reg.count_kind(TraceKind::Unpark);
        reg.count_kind(TraceKind::Enqueue);
        reg.count_kind(TraceKind::NodeComplete);
        reg.count_kind(TraceKind::Cancel);
        // admission kinds are counted at their submission sites
        reg.count_kind(TraceKind::Admit);
        reg.count_kind(TraceKind::Shed);
        let s = reg.snapshot(1.0);
        assert_eq!(s.steals, 2);
        assert_eq!(s.failed_steals, 1);
        assert_eq!(s.parks, 1);
        assert_eq!(s.unparks, 1);
        assert_eq!(s.enqueued, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!((s.admitted, s.shed), (0, 0));
    }

    #[test]
    fn reset_zeroes_everything() {
        let reg = MetricsRegistry::default();
        reg.admitted.fetch_add(3, Ordering::Relaxed);
        reg.backlog_high_water.fetch_max(9, Ordering::Relaxed);
        reg.count_kind(TraceKind::Steal);
        reg.reset();
        let s = reg.snapshot(0.0);
        assert_eq!((s.admitted, s.backlog_high_water, s.steals), (0, 0, 0));
    }

    #[test]
    fn snapshot_rows_align_with_header() {
        let reg = MetricsRegistry::default();
        let s = reg.snapshot(0.5);
        assert_eq!(
            MetricsSnapshot::header().split_whitespace().count(),
            s.row().split_whitespace().count()
        );
    }
}
