//! Regenerates Figures 10a and 10b: linear-regression training on dense
//! random data with the centralized queue — the workload where STATIC
//! wins and fine-grained dynamic schemes pay ~2x.
//!
//! ```sh
//! cargo bench --bench fig10_linreg
//! ```

use daphne_sched::bench::{figures, FigureId, FigureParams};

fn main() {
    let params = FigureParams::default();
    println!("workload: dense rand {} rows, 3 repetitions\n", params.lr_rows);
    let a = figures::print_figure(FigureId::Fig10a, &params);
    let b = figures::print_figure(FigureId::Fig10b, &params);

    let ratio = |rows: &[figures::Row], scheme: &str| {
        rows.iter().find(|r| r.scheme == scheme).unwrap().vs_static
    };
    println!("\npaper vs measured (slowdown vs STATIC):");
    println!(
        "  Fig 10a MFSC: paper ~2.0x   measured {:.2}x",
        ratio(&a, "MFSC")
    );
    println!(
        "  Fig 10a TSS:  paper 1.16x  measured {:.2}x",
        ratio(&a, "TSS")
    );
    println!(
        "  Fig 10a FISS: paper 1.24x  measured {:.2}x",
        ratio(&a, "FISS")
    );
    println!(
        "  Fig 10b TSS:  paper 1.50x  measured {:.2}x",
        ratio(&b, "TSS")
    );
    println!(
        "  Fig 10b FISS: paper 1.60x  measured {:.2}x",
        ratio(&b, "FISS")
    );
}
