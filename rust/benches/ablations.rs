//! Ablations from the paper's discussion:
//!
//! - §4: SS under central-queue locking "explodes" (why Figs 7-10 omit
//!   it);
//! - §5 future work: atomic operations instead of locks on the central
//!   queue — implemented here as `CentralAtomic` and compared.
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

use daphne_sched::bench::{figures, FigureParams};
use daphne_sched::topology::Topology;

fn main() {
    let params = FigureParams {
        iterations: Some(10),
        ..Default::default()
    };

    println!("== ablation 1: SS central-queue explosion (§4) ==");
    for (machine, t_ss, t_mfsc) in figures::ablation_ss(&params) {
        println!(
            "  {machine:<14} SS={t_ss:>9.3}s  MFSC={t_mfsc:>8.3}s  \
             ({:.0}x worse)",
            t_ss / t_mfsc
        );
    }

    println!("\n== ablation 2: locked vs atomic central queue (§5) ==");
    for machine in [Topology::broadwell20(), Topology::cascadelake56()] {
        println!("  {} ({} cores):", machine.name, machine.n_cores());
        for (scheme, locked, atomic) in
            figures::ablation_lock_vs_atomic(&machine, &params)
        {
            println!(
                "    {scheme:<6} locked={locked:>9.4}s atomic={atomic:>9.4}s \
                 speedup={:>5.2}x",
                locked / atomic
            );
        }
    }
}
