//! Regenerates Figures 8a/8b (Broadwell) and 9a/9b (Cascade Lake):
//! connected components with multiple work queues — PERCORE (globally
//! dealt chunks) and PERCPU (per-NUMA pre-partitioned blocks) — across
//! all four victim-selection strategies.
//!
//! ```sh
//! cargo bench --bench fig8_9_cc_multiqueue
//! ```

use daphne_sched::bench::{figures, FigureId, FigureParams};

fn main() {
    let params = FigureParams::default();
    println!(
        "workload: synthetic amazon ({} nodes), 3 repetitions\n",
        params.nodes
    );
    let a8 = figures::print_figure(FigureId::Fig8a, &params);
    let b8 = figures::print_figure(FigureId::Fig8b, &params);
    let _a9 = figures::print_figure(FigureId::Fig9a, &params);
    let b9 = figures::print_figure(FigureId::Fig9b, &params);

    // paper-shape checks
    let static_rank = |rows: &[figures::Row], victim: &str| {
        let mut v: Vec<&figures::Row> = rows
            .iter()
            .filter(|r| r.victim == Some(victim))
            .collect();
        v.sort_by(|x, y| x.time.total_cmp(&y.time));
        v.iter().position(|r| r.scheme == "STATIC").unwrap() + 1
    };
    println!("\npaper vs measured shape:");
    println!(
        "  Fig 8a PERCORE: paper says STATIC is lowest-performing; measured \
         STATIC rank {}/10 (SEQ)",
        static_rank(&a8, "SEQ")
    );
    println!(
        "  Fig 8b PERCPU:  paper says STATIC is highest-performing with \
         SEQPRI; measured rank {}/10 (SEQPRI)",
        static_rank(&b8, "SEQPRI")
    );
    println!(
        "  Fig 9b PERCPU:  paper says STATIC highest on Cascade Lake; \
         measured rank {}/10 (SEQPRI)",
        static_rank(&b9, "SEQPRI")
    );
}
