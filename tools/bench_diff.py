#!/usr/bin/env python3
"""Regression gate over BENCH_<name>.json bench reports (stdlib only).

Compares the figure rows of a candidate report (normally the CI's
BENCH_smoke.json) against a blessed baseline (BENCH_baseline.json at the
repo root), keyed by (scheme, victim, occurrence). The compared metric
is each row's `time` column — virtual-time DES makespans, so they are
deterministic for a fixed workload and the thresholds guard against
modelling regressions, not host noise.

Policy:
  * regression  > --fail (default 15%)  -> finding, exit 1
  * regression  > --warn (default  5%)  -> warning, exit 0
  * improvements and sub-threshold drift are reported, never fatal
  * row-set drift (a figure row added/removed/renamed) is a warning:
    the gate asks for a re-bless rather than failing refactors that
    legitimately reshape a figure

A baseline with `"provisional": true` downgrades every finding to a
warning (exit 0): the gate is armed but not yet enforcing, because the
blessed numbers were not produced by the canonical CI runner. Re-bless
with `--bless` from a trusted report to drop the flag.

Usage:
  python3 tools/bench_diff.py BENCH_baseline.json BENCH_smoke.json
  python3 tools/bench_diff.py --bless BENCH_smoke.json BENCH_baseline.json
"""
import argparse
import json
import sys

SCHEMA = "daphne-sched/bench/v1"


def load_report(path):
    with open(path) as f:
        d = json.load(f)
    if d.get("schema") != SCHEMA:
        sys.exit(f"bench_diff: {path}: schema {d.get('schema')!r} != {SCHEMA!r}")
    if not isinstance(d.get("figures"), list):
        sys.exit(f"bench_diff: {path}: missing figures rows")
    return d


def keyed_rows(report):
    """(scheme, victim, occurrence) -> row; occurrence disambiguates
    repeated (scheme, victim) pairs within one report."""
    seen = {}
    out = {}
    for row in report["figures"]:
        base = (row.get("scheme"), row.get("victim"))
        n = seen.get(base, 0)
        seen[base] = n + 1
        out[base + (n,)] = row
    return out


def key_str(key):
    scheme, victim, occ = key
    s = f"{scheme}/{victim if victim is not None else '-'}"
    return f"{s}#{occ}" if occ else s


def bless(candidate_path, baseline_path):
    d = load_report(candidate_path)
    d["provisional"] = False
    with open(baseline_path, "w") as f:
        json.dump(d, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_diff: blessed {candidate_path} -> {baseline_path} "
          f"({len(d['figures'])} rows)")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--warn", type=float, default=0.05,
                    help="warn above this relative regression (default 0.05)")
    ap.add_argument("--fail", type=float, default=0.15,
                    help="fail above this relative regression (default 0.15)")
    ap.add_argument("--bless", action="store_true",
                    help="write the first argument as the new baseline "
                         "named by the second, clearing `provisional`")
    args = ap.parse_args()
    if args.bless:
        bless(args.baseline, args.candidate)
        return 0

    base = load_report(args.baseline)
    cand = load_report(args.candidate)
    provisional = bool(base.get("provisional"))
    brows, crows = keyed_rows(base), keyed_rows(cand)

    warnings, failures = [], []
    for key in sorted(set(brows) - set(crows), key=key_str):
        warnings.append(f"row {key_str(key)} in baseline only (re-bless?)")
    for key in sorted(set(crows) - set(brows), key=key_str):
        warnings.append(f"row {key_str(key)} in candidate only (re-bless?)")

    compared = 0
    for key in sorted(set(brows) & set(crows), key=key_str):
        b, c = brows[key]["time"], crows[key]["time"]
        if not (b > 0.0):
            warnings.append(f"{key_str(key)}: baseline time {b} not positive")
            continue
        compared += 1
        delta = (c - b) / b
        line = f"{key_str(key)}: {b:.6g}s -> {c:.6g}s ({delta:+.1%})"
        if delta > args.fail:
            failures.append(line)
        elif delta > args.warn:
            warnings.append(line)
        elif delta < -args.warn:
            print(f"bench_diff: improvement {line}")

    for w in warnings:
        print(f"bench_diff: WARN {w}")
    for f in failures:
        print(f"bench_diff: FAIL {f}")
    verdict = "provisional baseline — findings downgraded" if provisional \
        else f"warn>{args.warn:.0%} fail>{args.fail:.0%}"
    print(f"bench_diff: {compared} rows compared, {len(warnings)} warning(s), "
          f"{len(failures)} failure(s) [{verdict}]")
    if failures and provisional:
        print("bench_diff: baseline is provisional; re-bless with "
              "`python3 tools/bench_diff.py --bless BENCH_smoke.json "
              "BENCH_baseline.json` once the numbers are trusted")
        return 0
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
