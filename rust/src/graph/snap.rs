//! SNAP edge-list IO: read the real Amazon co-purchase files
//! (`amazon0601.txt`-style: `#` comments, one `src\tdst` pair per line)
//! when available, and write the same format for interchange.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::matrix::CsrMatrix;

/// Read a SNAP-format edge list into CSR. Node ids are compacted to a
/// dense `0..n` range (SNAP files may skip ids).
pub fn read_edge_list(path: &Path) -> std::io::Result<CsrMatrix> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut raw_edges: Vec<(u32, u32)> = Vec::new();
    let mut max_id = 0u32;
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(a), Some(b)) = (it.next(), it.next()) else {
            continue;
        };
        let (Ok(a), Ok(b)) = (a.parse::<u32>(), b.parse::<u32>()) else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad edge line: {line}"),
            ));
        };
        max_id = max_id.max(a).max(b);
        raw_edges.push((a, b));
    }
    // compact ids
    let mut present = vec![false; max_id as usize + 1];
    for &(a, b) in &raw_edges {
        present[a as usize] = true;
        present[b as usize] = true;
    }
    let mut remap = vec![u32::MAX; max_id as usize + 1];
    let mut next = 0u32;
    for (id, &p) in present.iter().enumerate() {
        if p {
            remap[id] = next;
            next += 1;
        }
    }
    let edges: Vec<(u32, u32)> = raw_edges
        .into_iter()
        .map(|(a, b)| (remap[a as usize], remap[b as usize]))
        .collect();
    Ok(CsrMatrix::from_edges(next as usize, next as usize, &edges))
}

/// Write a CSR pattern as a SNAP-format edge list.
pub fn write_edge_list(g: &CsrMatrix, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# Directed graph: {} nodes {} edges", g.rows, g.nnz())?;
    writeln!(w, "# FromNodeId\tToNodeId")?;
    for r in 0..g.rows {
        for &c in g.row(r) {
            writeln!(w, "{r}\t{c}")?;
        }
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{amazon_like, SnapGraph};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("daphne_sched_snap_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_graph() {
        let g = amazon_like(&SnapGraph::small(300, 9));
        let path = tmp("roundtrip.txt");
        write_edge_list(&g, &path).unwrap();
        let h = read_edge_list(&path).unwrap();
        assert_eq!(g.rows, h.rows);
        assert_eq!(g.indices, h.indices);
        assert_eq!(g.indptr, h.indptr);
    }

    #[test]
    fn reads_snap_header_and_sparse_ids() {
        let path = tmp("snap_style.txt");
        std::fs::write(
            &path,
            "# Amazon style\n# FromNodeId\tToNodeId\n10\t20\n20\t40\n40\t10\n",
        )
        .unwrap();
        let g = read_edge_list(&path).unwrap();
        assert_eq!(g.rows, 3, "ids must be compacted");
        assert_eq!(g.nnz(), 3);
    }

    #[test]
    fn rejects_garbage_lines() {
        let path = tmp("bad.txt");
        std::fs::write(&path, "1\tnotanumber\n").unwrap();
        assert!(read_edge_list(&path).is_err());
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(read_edge_list(Path::new("/nonexistent/xyz.txt")).is_err());
    }
}
