//! Automatic selection of scheduling options — the paper's §5 future
//! work: "the multitude of scheduling options ... renders the offline or
//! online selection of the right scheduling option for an
//! application-system pair very challenging. We plan to extend
//! DaphneSched to support automatic selection."
//!
//! The tuner reuses the DES as an *offline oracle*: given the workload's
//! per-item cost profile (known after one profiled pass, or estimated
//! from data statistics like row nnz) and the machine model, it sweeps
//! candidate (scheme × layout × victim) configurations in virtual time
//! and returns the best — milliseconds of simulation instead of hours of
//! grid-running the real application.
//!
//! [`tune_graph`] lifts the search to whole task graphs: the oracle is
//! the virtual-time graph replay ([`crate::sim::graph::replay`]), the
//! search space is a *per-node* (scheme × layout × victim × placement)
//! assignment — placement joins as a fourth dimension on heterogeneous
//! machine models ([`SearchSpace::for_machine`]), routing nodes between
//! the CPU pool and accelerator pools — and the search is kept
//! polynomial by a greedy critical-path-first refinement: start every
//! node at the best single uniform configuration, then re-optimize one
//! node at a time in order of how late it finishes (critical-path nodes
//! first), accepting only assignments whose replayed makespan improves.
//! The result is therefore never worse than the best uniform
//! configuration.

use crate::config::{GraphMode, SchedConfig};
use crate::sched::graph::GraphError;
use crate::sched::placement::{DevicePools, Placement, ResolveMode};
use crate::sched::session::TenancyPolicy;
use crate::sched::{QueueLayout, Scheme, VictimStrategy};
use crate::sim::graph::{self as simgraph, GraphShape, TenantSpec};
use crate::sim::{self, CostModel, Workload};
use crate::topology::{DeviceClass, Topology};

/// One evaluated candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub config: SchedConfig,
    /// Predicted makespan, seconds (virtual).
    pub predicted: f64,
}

/// Search space for the tuner.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub schemes: Vec<Scheme>,
    pub layouts: Vec<QueueLayout>,
    pub victims: Vec<VictimStrategy>,
    /// Placement candidates for [`tune_graph`]'s fourth dimension.
    /// Empty (the default) = placement is *not* tuned: every node keeps
    /// the placement its shape declares. Non-empty = the tuner assigns
    /// each node a placement from this list (shape placements ignored),
    /// e.g. `[Any, Class(Gpu)]` from [`SearchSpace::for_machine`] on a
    /// GPU-bearing machine model. A candidate the machine cannot
    /// satisfy is a [`GraphError::NoSuchPool`] up front.
    pub placements: Vec<Placement>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            // SS excluded by default: the §4 explosion makes it never
            // competitive on a locked central queue.
            schemes: Scheme::FIGURES.to_vec(),
            layouts: vec![
                QueueLayout::Centralized { atomic: false },
                QueueLayout::Centralized { atomic: true },
                QueueLayout::PerGroup,
                QueueLayout::PerCore,
            ],
            victims: VictimStrategy::ALL.to_vec(),
            placements: Vec::new(),
        }
    }
}

impl SearchSpace {
    /// The default space extended with the placement dimension for a
    /// machine model: `Any` (the CPU pool) plus `Class(c)` for every
    /// accelerator class `topo` provides. On a CPU-only machine the
    /// placement list stays empty (nothing to tune).
    pub fn for_machine(topo: &Topology) -> Self {
        let accel: Vec<Placement> = topo
            .device_classes()
            .into_iter()
            .filter(|&c| c != DeviceClass::Cpu)
            .map(Placement::Class)
            .collect();
        SearchSpace {
            placements: if accel.is_empty() {
                Vec::new()
            } else {
                let mut p = vec![Placement::Any];
                p.extend(accel);
                p
            },
            ..SearchSpace::default()
        }
    }

    /// Enumerate the concrete configurations of this space. Centralized
    /// layouts ignore the victim dimension (enumerated once).
    pub fn configs(&self, seed: u64) -> Vec<SchedConfig> {
        let mut out = Vec::new();
        for &scheme in &self.schemes {
            for &layout in &self.layouts {
                let victims: &[VictimStrategy] = if layout.steals() {
                    &self.victims
                } else {
                    &[VictimStrategy::Seq]
                };
                for &victim in victims {
                    out.push(SchedConfig {
                        scheme,
                        layout,
                        victim,
                        seed,
                        stages: None,
                        pls_swr: 0.5,
                    });
                }
            }
        }
        out
    }
}

/// Sweep the space and return candidates sorted best-first.
///
/// `repeats` averages over seeds (the DES models OS interference, so a
/// single draw can be lucky). Centralized layouts ignore the victim
/// dimension (evaluated once).
pub fn tune(
    workload: &Workload,
    topo: &Topology,
    costs: &CostModel,
    space: &SearchSpace,
    seed: u64,
    repeats: usize,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    for config in space.configs(seed) {
        let mut total = 0.0;
        for r in 0..repeats.max(1) {
            let cfg = SchedConfig {
                seed: seed.wrapping_add(r as u64 * 0x9E37_79B9),
                ..config.clone()
            };
            total += sim::simulate(topo, &cfg, workload, costs).makespan();
        }
        out.push(Candidate {
            config,
            predicted: total / repeats.max(1) as f64,
        });
    }
    out.sort_by(|a, b| a.predicted.total_cmp(&b.predicted));
    out
}

/// Convenience: best configuration for a workload/machine pair.
pub fn best(
    workload: &Workload,
    topo: &Topology,
    costs: &CostModel,
    seed: u64,
) -> Candidate {
    tune(workload, topo, costs, &SearchSpace::default(), seed, 3)
        .into_iter()
        .next()
        .expect("non-empty search space")
}

/// One node's winner in a graph-level search.
#[derive(Debug, Clone)]
pub struct NodeChoice {
    pub name: String,
    pub config: SchedConfig,
    /// Device-pool placement chosen for (or kept by) this node.
    pub placement: Placement,
}

/// Result of [`tune_graph`].
#[derive(Debug, Clone)]
pub struct GraphTuning {
    /// Per-node configurations (and placements), in shape order.
    pub per_node: Vec<NodeChoice>,
    /// Replayed makespan of the per-node assignment (dag mode), seconds.
    pub predicted: f64,
    /// The best *single uniform* configuration from the sweep and its
    /// replayed makespan — the refinement's starting point, so
    /// `predicted <= uniform.predicted` always holds.
    pub uniform: Candidate,
    /// Placement the best uniform candidate used. `None` when placement
    /// was not a tuned dimension (the uniform sweep then ran over the
    /// shape's own, possibly per-node, placements — there is no single
    /// placement to report).
    pub uniform_placement: Option<Placement>,
}

impl GraphTuning {
    /// Fractional improvement of per-node selection over the best
    /// uniform configuration (0 = refinement found nothing better).
    pub fn refinement_gain(&self) -> f64 {
        if self.uniform.predicted > 0.0 {
            1.0 - self.predicted / self.uniform.predicted
        } else {
            0.0
        }
    }
}

/// Graph-level automatic selection: choose a (scheme × layout × victim
/// × placement) configuration *per node* of `shape`, using dag-mode
/// virtual-time replay ([`crate::sim::graph::replay_placed`]) as the
/// oracle. Placement participates only when `space.placements` is
/// non-empty (see [`SearchSpace::placements`] /
/// [`SearchSpace::for_machine`]); otherwise every node keeps the
/// placement its shape declares and the search is the classic
/// three-dimensional one.
///
/// Search strategy (polynomial in node count, not exponential):
///
/// 1. **Uniform sweep** — replay the whole graph once per candidate
///    (configuration × placement) applied to every node; keep the best.
/// 2. **Greedy critical-path-first refinement** — starting from the
///    best uniform assignment, re-optimize one node at a time (nodes on
///    the current critical path first, then the rest by descending
///    finish time), accepting a change only if the replayed makespan of
///    the *whole graph* improves. Repeat until a full pass finds no
///    improvement (at most `nodes` passes).
///
/// Because refinement starts at the best uniform configuration and only
/// ever accepts improvements, the returned assignment's makespan is
/// `<=` the best uniform candidate's — asserted by the acceptance tests.
pub fn tune_graph(
    shape: &GraphShape,
    topo: &Topology,
    costs: &CostModel,
    space: &SearchSpace,
    seed: u64,
    repeats: usize,
) -> Result<GraphTuning, GraphError> {
    // Validate (and toposort) once — the same Kahn pass as the executor
    // path; every oracle evaluation then replays against this order.
    let order = shape.toposorted()?;
    let pools = DevicePools::from_topology(topo);
    let n = shape.len();
    let reps = repeats.max(1);

    // Placement candidates, resolved to pools once. Empty `placements`
    // = keep the shape's own (still validated — same error surface as
    // submitting the shape).
    let resolve = |p: &Placement, node: &str| -> Result<usize, GraphError> {
        pools
            .resolve(p, ResolveMode::Model)
            .map(|r| r.pool)
            .map_err(|e| GraphError::NoSuchPool {
                node: node.to_string(),
                wanted: e.wanted,
            })
    };
    let tune_placement = !space.placements.is_empty();
    let placement_cands: Vec<(Placement, usize)> = if tune_placement {
        space
            .placements
            .iter()
            .map(|p| Ok((*p, resolve(p, "search space")?)))
            .collect::<Result<_, GraphError>>()?
    } else {
        Vec::new()
    };
    // The shape's own placements are resolved only when they are what
    // the tuner will actually use — with a non-empty placement space
    // every node's placement comes from the candidate list, so a shape
    // pinned to classes this machine lacks is still tunable. Resolution
    // goes through the same `resolve_pools` as replay, keeping the
    // tuner's error surface identical to the sim/executor paths.
    let shape_assign: Vec<(Placement, usize)> = if tune_placement {
        Vec::new()
    } else {
        let placements: Vec<Placement> =
            shape.nodes().iter().map(|n| n.placement).collect();
        let node_pool = simgraph::resolve_pools(shape, &pools, &placements)?;
        placements.into_iter().zip(node_pool).collect()
    };

    let eval = |assign: &[SchedConfig], node_pool: &[usize]| -> f64 {
        let mut total = 0.0;
        for r in 0..reps {
            let seeded: Vec<SchedConfig> = assign
                .iter()
                .map(|c| SchedConfig {
                    seed: seed.wrapping_add(r as u64 * 0x9E37_79B9),
                    ..c.clone()
                })
                .collect();
            total += simgraph::replay_ordered(
                shape,
                &pools,
                &seeded,
                node_pool,
                costs,
                GraphMode::Dag,
                &order,
            )
            .makespan();
        }
        total / reps as f64
    };

    // 1) uniform sweep over (configuration × placement); with a fixed
    // placement dimension the sweep runs over the shape's own (possibly
    // per-node) assignment and there is no uniform placement to report.
    let candidates = space.configs(seed);
    let mut uniform: Option<(Candidate, Option<(Placement, usize)>)> = None;
    if tune_placement {
        for config in &candidates {
            for &(placement, pool) in &placement_cands {
                let predicted =
                    eval(&vec![config.clone(); n], &vec![pool; n]);
                if uniform
                    .as_ref()
                    .is_none_or(|(u, _)| predicted < u.predicted)
                {
                    uniform = Some((
                        Candidate { config: config.clone(), predicted },
                        Some((placement, pool)),
                    ));
                }
            }
        }
    } else {
        let node_pool: Vec<usize> =
            shape_assign.iter().map(|&(_, p)| p).collect();
        for config in &candidates {
            let predicted = eval(&vec![config.clone(); n], &node_pool);
            if uniform
                .as_ref()
                .is_none_or(|(u, _)| predicted < u.predicted)
            {
                uniform = Some((
                    Candidate { config: config.clone(), predicted },
                    None,
                ));
            }
        }
    }
    let (uniform, uniform_place) = uniform.expect("non-empty search space");

    // 2) greedy critical-path-first refinement over both dimensions
    let mut assign = vec![uniform.config.clone(); n];
    let mut place: Vec<(Placement, usize)> = match uniform_place {
        Some(up) => vec![up; n],
        None => shape_assign.clone(),
    };
    let mut best = uniform.predicted;
    for _pass in 0..n {
        let mut improved = false;
        // Sweep order: current critical path first (latest finisher
        // first), then the off-path nodes by descending finish time.
        let node_pool: Vec<usize> = place.iter().map(|&(_, p)| p).collect();
        let outcome = simgraph::replay_ordered(
            shape,
            &pools,
            &assign,
            &node_pool,
            costs,
            GraphMode::Dag,
            &order,
        );
        let on_path = |i: usize| {
            outcome.critical_path.contains(&shape.nodes()[i].name)
        };
        let by_finish = simgraph::by_finish_desc(&outcome);
        let sweep: Vec<usize> = by_finish
            .iter()
            .filter(|&&i| on_path(i))
            .chain(by_finish.iter().filter(|&&i| !on_path(i)))
            .copied()
            .collect();
        for i in sweep {
            let saved_cfg = assign[i].clone();
            let saved_place = place[i];
            let node_places: &[(Placement, usize)] = if tune_placement {
                &placement_cands
            } else {
                std::slice::from_ref(&saved_place)
            };
            let mut winner: Option<(f64, SchedConfig, (Placement, usize))> =
                None;
            for config in &candidates {
                for &(placement, pool) in node_places {
                    if config.scheme == saved_cfg.scheme
                        && config.layout == saved_cfg.layout
                        && config.victim == saved_cfg.victim
                        && placement == saved_place.0
                    {
                        continue;
                    }
                    assign[i] = config.clone();
                    place[i] = (placement, pool);
                    let node_pool: Vec<usize> =
                        place.iter().map(|&(_, p)| p).collect();
                    let t = eval(&assign, &node_pool);
                    if t < best
                        && winner.as_ref().is_none_or(|(w, _, _)| t < *w)
                    {
                        winner =
                            Some((t, config.clone(), (placement, pool)));
                    }
                }
            }
            match winner {
                Some((t, config, placement)) => {
                    best = t;
                    assign[i] = config;
                    place[i] = placement;
                    improved = true;
                }
                None => {
                    assign[i] = saved_cfg;
                    place[i] = saved_place;
                }
            }
        }
        if !improved {
            break;
        }
    }

    Ok(GraphTuning {
        per_node: shape
            .nodes()
            .iter()
            .zip(assign.iter().zip(&place))
            .map(|(node, (config, &(placement, _)))| NodeChoice {
                name: node.name.clone(),
                config: config.clone(),
                placement,
            })
            .collect(),
        predicted: best,
        uniform,
        uniform_placement: uniform_place.map(|(p, _)| p),
    })
}

/// Calibrated graph tuning — the online-retuning loop closed: re-cost
/// `shape` from measured per-node service times
/// ([`crate::sim::TraceCalibration`], distilled from a real or DES
/// trace by `CostModel::calibrate_from_trace` or loaded from an
/// exported Chrome trace) and run [`tune_graph`] on the calibrated
/// shape. Returns the calibrated shape alongside the tuning so callers
/// can replay/validate the chosen assignment against the workloads the
/// tuner actually saw.
pub fn tune_graph_calibrated(
    shape: &GraphShape,
    topo: &Topology,
    costs: &CostModel,
    space: &SearchSpace,
    seed: u64,
    repeats: usize,
    cal: &sim::TraceCalibration,
) -> Result<(GraphShape, GraphTuning), GraphError> {
    let calibrated = shape.recosted(cal);
    let tuning = tune_graph(&calibrated, topo, costs, space, seed, repeats)?;
    Ok((calibrated, tuning))
}

/// One evaluated cross-job policy for a tenant mix.
#[derive(Debug, Clone)]
pub struct TenancyCandidate {
    pub policy: TenancyPolicy,
    /// Replayed p99 per-tenant slowdown (the tail-latency objective).
    pub p99_slowdown: f64,
    /// Jain fairness index over the replayed per-tenant slowdowns.
    pub fairness: f64,
    /// Replayed completion time of the whole mix.
    pub makespan: f64,
}

/// The tenancy-policy dimension of automatic selection: replay a
/// tenant mix ([`crate::sim::graph::replay_tenants`]) under every
/// [`TenancyPolicy`] and rank them by p99 tenant slowdown (ties by
/// fairness, descending) — milliseconds of simulation to choose the
/// `policy=` knob for a service's observed workload mix, the same
/// oracle move [`tune`] and [`tune_graph`] make for the per-job
/// dimensions.
pub fn tune_tenancy(
    tenants: &[TenantSpec],
    topo: &Topology,
    costs: &CostModel,
    default: &SchedConfig,
) -> Result<Vec<TenancyCandidate>, GraphError> {
    // policy-independent slowdown baselines, computed once
    let isolated =
        simgraph::isolated_makespans(tenants, topo, default, costs)?;
    let mut out = Vec::with_capacity(TenancyPolicy::ALL.len());
    for policy in TenancyPolicy::ALL {
        let sim = simgraph::replay_tenants_with(
            tenants, topo, default, costs, policy, &isolated,
        )?;
        out.push(TenancyCandidate {
            policy,
            p99_slowdown: sim.p99_slowdown(),
            fairness: sim.fairness(),
            makespan: sim.makespan,
        });
    }
    out.sort_by(|a, b| {
        a.p99_slowdown
            .total_cmp(&b.p99_slowdown)
            .then_with(|| b.fairness.total_cmp(&a.fairness))
    });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed_workload() -> Workload {
        // heavy tail at the end: dynamic schemes needed
        let per: Vec<f64> = (0..100_000)
            .map(|i| if i >= 50_000 { 9e-7 } else { 1e-8 })
            .collect();
        Workload::from_costs("skew", &per)
    }

    #[test]
    fn tuner_ranks_whole_space() {
        let w = Workload::uniform("u", 20_000, 1e-7);
        let topo = Topology::broadwell20();
        let ranked = tune(
            &w,
            &topo,
            &CostModel::recorded(),
            &SearchSpace::default(),
            1,
            1,
        );
        // 10 schemes x (2 central + 2 stealing x 4 victims) = 100
        assert_eq!(ranked.len(), 100);
        assert!(ranked.windows(2).all(|w| w[0].predicted <= w[1].predicted));
    }

    #[test]
    fn calibrated_tuning_recosts_measured_nodes() {
        // a shape whose assumed costs are wrong by 10x on one node;
        // after calibration the tuner sees (and predicts) the measured
        // magnitude while unmeasured nodes keep assumed costs
        let shape = GraphShape::new("cal")
            .node(simgraph::NodeModel::uniform("fast", 64, 1e-5))
            .node(
                simgraph::NodeModel::uniform("slow", 64, 1e-5)
                    .after("fast"),
            );
        let mut cal = sim::TraceCalibration::default();
        cal.insert("slow", 64.0 * 1e-4); // measured: 10x assumed
        let topo = Topology::broadwell20();
        let space = SearchSpace {
            schemes: vec![Scheme::Static, Scheme::Gss],
            layouts: vec![QueueLayout::Centralized { atomic: false }],
            victims: vec![VictimStrategy::Seq],
            placements: Vec::new(),
        };
        let costs = CostModel::recorded();
        let assumed =
            tune_graph(&shape, &topo, &costs, &space, 1, 1).expect("tunes");
        let (calibrated_shape, calibrated) = tune_graph_calibrated(
            &shape, &topo, &costs, &space, 1, 1, &cal,
        )
        .expect("tunes calibrated");
        let slow = calibrated_shape
            .nodes()
            .iter()
            .find(|n| n.name == "slow")
            .expect("slow node kept");
        assert!(
            (slow.workload.total_cost() - 64.0 * 1e-4).abs() < 1e-12,
            "slow recosted to the measured total"
        );
        let fast = calibrated_shape
            .nodes()
            .iter()
            .find(|n| n.name == "fast")
            .expect("fast node kept");
        assert!(
            (fast.workload.total_cost() - 64.0 * 1e-5).abs() < 1e-12,
            "unmeasured node keeps assumed costs"
        );
        assert!(
            calibrated.predicted > assumed.predicted,
            "the tuner now sees the measured (heavier) workload: \
             {} vs {}",
            calibrated.predicted,
            assumed.predicted
        );
    }

    #[test]
    fn picks_non_static_for_skewed_work() {
        let topo = Topology::broadwell20();
        let choice = best(
            &skewed_workload(),
            &topo,
            &CostModel::daphne_like(),
            1,
        );
        // STATIC parks the heavy half on half the workers; any sane
        // choice beats it clearly
        let static_cfg = SchedConfig::default();
        let static_time = sim::simulate(
            &topo,
            &static_cfg,
            &skewed_workload(),
            &CostModel::daphne_like(),
        )
        .makespan();
        assert!(
            choice.predicted < static_time,
            "tuned {:?} ({}) must beat default STATIC ({static_time})",
            choice.config.scheme,
            choice.predicted
        );
    }

    #[test]
    fn picks_cheap_config_for_uniform_work() {
        // uniform dense work: the winner must not be a fine-grained
        // locked-central config (those pay pure overhead, Fig. 10)
        let w = Workload::uniform("u", 200_000, 3e-8);
        let topo = Topology::broadwell20();
        let choice = best(&w, &topo, &CostModel::daphne_like(), 1);
        let fine_locked = SchedConfig::default().with_scheme(Scheme::Ss);
        let fine_time =
            sim::simulate(&topo, &fine_locked, &w, &CostModel::daphne_like())
                .makespan();
        assert!(choice.predicted < fine_time / 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = Workload::uniform("u", 10_000, 1e-7);
        let topo = Topology::cascadelake56();
        let a = best(&w, &topo, &CostModel::recorded(), 7);
        let b = best(&w, &topo, &CostModel::recorded(), 7);
        assert_eq!(a.config.scheme, b.config.scheme);
        assert_eq!(a.predicted, b.predicted);
    }

    fn small_space() -> SearchSpace {
        SearchSpace {
            schemes: vec![Scheme::Static, Scheme::Gss, Scheme::Mfsc],
            layouts: vec![
                QueueLayout::Centralized { atomic: false },
                QueueLayout::PerCore,
            ],
            victims: vec![VictimStrategy::Seq],
            placements: Vec::new(),
        }
    }

    #[test]
    fn graph_tuner_never_worse_than_best_uniform() {
        // The acceptance criterion: per-node selection's replayed
        // makespan is <= the best single uniform config from the sweep,
        // on the modelled 56-core machine.
        let topo = Topology::cascadelake56();
        let shape = GraphShape::unbalanced_diamond(28);
        let tuning = tune_graph(
            &shape,
            &topo,
            &CostModel::recorded(),
            &small_space(),
            1,
            1,
        )
        .unwrap();
        assert!(
            tuning.predicted <= tuning.uniform.predicted + 1e-12,
            "per-node {} must not lose to uniform {}",
            tuning.predicted,
            tuning.uniform.predicted
        );
        assert!(tuning.refinement_gain() >= 0.0);
        assert_eq!(tuning.per_node.len(), shape.len());
        // replaying the returned assignment reproduces the prediction
        // (repeats=1, so the eval seed equals the configs' own seed)
        let configs: Vec<SchedConfig> =
            tuning.per_node.iter().map(|c| c.config.clone()).collect();
        let replayed = crate::sim::graph::replay_with_configs(
            &shape,
            &topo,
            &configs,
            &CostModel::recorded(),
            GraphMode::Dag,
        )
        .unwrap()
        .makespan();
        assert!(
            (replayed - tuning.predicted).abs() / tuning.predicted < 1e-9,
            "replayed {replayed} vs predicted {}",
            tuning.predicted
        );
    }

    #[test]
    fn graph_tuner_deterministic_given_seed() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::unbalanced_diamond(10);
        let costs = CostModel::recorded();
        let a =
            tune_graph(&shape, &topo, &costs, &small_space(), 9, 1).unwrap();
        let b =
            tune_graph(&shape, &topo, &costs, &small_space(), 9, 1).unwrap();
        assert_eq!(a.predicted, b.predicted);
        assert_eq!(a.uniform.predicted, b.uniform.predicted);
        for (x, y) in a.per_node.iter().zip(&b.per_node) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.config.scheme, y.config.scheme);
            assert_eq!(x.config.layout, y.config.layout);
        }
    }

    #[test]
    fn for_machine_adds_placements_only_on_hetero_models() {
        let cpu_only = SearchSpace::for_machine(&Topology::broadwell20());
        assert!(cpu_only.placements.is_empty());
        let hetero = SearchSpace::for_machine(&Topology::hetero56());
        assert_eq!(
            hetero.placements,
            vec![
                Placement::Any,
                Placement::Class(crate::topology::DeviceClass::Gpu)
            ]
        );
    }

    #[test]
    fn placement_tuning_moves_work_onto_the_accelerator_when_it_wins() {
        use crate::sim::NodeModel;
        // Two equal heavy independent branches on a machine whose GPU
        // pool matches the CPU pool's throughput: keeping both on the
        // CPU pool serializes their demand; splitting across pools
        // halves the makespan. The tuner must discover the split.
        let topo = Topology::heterogeneous(
            "h",
            1,
            8,
            1.0,
            1.0,
            &[(crate::topology::DeviceClass::Gpu, 2, 4.0)],
        );
        let shape = crate::sim::GraphShape::new("split")
            .node(NodeModel::uniform("left", 4_000, 1e-6))
            .node(NodeModel::uniform("right", 4_000, 1e-6));
        let space = SearchSpace {
            schemes: vec![Scheme::Static, Scheme::Gss],
            layouts: vec![QueueLayout::Centralized { atomic: false }],
            victims: vec![VictimStrategy::Seq],
            placements: SearchSpace::for_machine(&topo).placements,
        };
        let costs = CostModel::recorded();
        let tuning =
            tune_graph(&shape, &topo, &costs, &space, 3, 1).unwrap();
        let placements: Vec<Placement> =
            tuning.per_node.iter().map(|c| c.placement).collect();
        assert!(
            placements.contains(&Placement::Class(
                crate::topology::DeviceClass::Gpu
            )),
            "tuner kept everything off the accelerator: {placements:?}"
        );
        assert!(
            tuning.predicted <= tuning.uniform.predicted + 1e-12,
            "placement refinement must never lose to uniform"
        );
        // the split beats the best all-on-one-pool uniform clearly
        assert!(
            tuning.predicted < tuning.uniform.predicted * 0.95,
            "split {} vs uniform {}",
            tuning.predicted,
            tuning.uniform.predicted
        );
    }

    #[test]
    fn tuned_placement_overrides_shape_pins_it_could_not_satisfy() {
        use crate::sim::NodeModel;
        // The shape pins a class this machine lacks; with a placement
        // space the tuner owns the placement dimension, so the pin is
        // ignored and tuning succeeds. Without one, the pin is kept —
        // and correctly rejected.
        let topo = Topology::broadwell20();
        let shape = crate::sim::GraphShape::new("s").node(
            NodeModel::uniform("n", 1_000, 1e-7)
                .on(crate::topology::DeviceClass::Fpga),
        );
        let costs = CostModel::recorded();
        let tunable = SearchSpace {
            placements: vec![Placement::Any],
            ..small_space()
        };
        let tuning =
            tune_graph(&shape, &topo, &costs, &tunable, 1, 1).unwrap();
        assert_eq!(tuning.per_node[0].placement, Placement::Any);
        assert!(matches!(
            tune_graph(&shape, &topo, &costs, &small_space(), 1, 1),
            Err(GraphError::NoSuchPool { .. })
        ));
    }

    #[test]
    fn unsatisfiable_space_placement_errors_up_front() {
        use crate::sim::NodeModel;
        let shape = crate::sim::GraphShape::new("s")
            .node(NodeModel::uniform("n", 100, 1e-6));
        let space = SearchSpace {
            placements: vec![Placement::Class(
                crate::topology::DeviceClass::Fpga,
            )],
            ..small_space()
        };
        assert!(matches!(
            tune_graph(
                &shape,
                &Topology::broadwell20(),
                &CostModel::recorded(),
                &space,
                1,
                1
            ),
            Err(GraphError::NoSuchPool { .. })
        ));
    }

    #[test]
    fn tenancy_tuner_prefers_a_policy_that_tames_the_tail() {
        // the tenancy figure's canonical bursty mix (heavy batch
        // pipelines with interactive tenants bursting in behind them),
        // so the tuner and the figure rank the same workload: FIFO
        // should not win on p99 slowdown
        let topo = Topology::symmetric("t8", 1, 8, 1.0, 1.0);
        let tenants = crate::bench::figures::tenancy_tenants(
            8,
            crate::config::ArrivalPattern::Burst,
            7,
        );
        let fine = SchedConfig::fine_grained();
        let ranked = tune_tenancy(
            &tenants,
            &topo,
            &CostModel::recorded(),
            &fine,
        )
        .unwrap();
        assert_eq!(ranked.len(), 3);
        assert!(
            ranked
                .windows(2)
                .all(|w| w[0].p99_slowdown <= w[1].p99_slowdown),
            "candidates must rank best-first"
        );
        assert_ne!(
            ranked[0].policy,
            TenancyPolicy::Fifo,
            "FIFO cannot win the bursty tail: {ranked:?}"
        );
    }

    #[test]
    fn graph_tuner_rejects_invalid_shapes() {
        use crate::sim::NodeModel;
        let topo = Topology::broadwell20();
        let cyclic = crate::sim::GraphShape::new("cycle")
            .node(NodeModel::uniform("a", 10, 1e-7).after("b"))
            .node(NodeModel::uniform("b", 10, 1e-7).after("a"));
        assert!(matches!(
            tune_graph(
                &cyclic,
                &topo,
                &CostModel::recorded(),
                &small_space(),
                1,
                1
            ),
            Err(GraphError::Cycle(_))
        ));
    }
}
