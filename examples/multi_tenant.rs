//! Multi-tenant sessions: two pipelines with different priorities on
//! ONE session of the resident pool, submitted from this thread.
//!
//! A long batch analytics pipeline and a short interactive query
//! contend for the same workers. Under the default FIFO policy the
//! interactive tenant queues behind the batch backlog; under
//! `TenancyPolicy::Priority` (or `Fair`) the executor's workers
//! re-evaluate the cross-job pick after every task, so the interactive
//! tenant's latency collapses while the batch pipeline barely moves.
//!
//! ```sh
//! cargo run --release --example multi_tenant
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use daphne_sched::config::SchedConfig;
use daphne_sched::sched::{
    Executor, GraphSpec, NodeSpec, SubmitOpts, TenancyPolicy,
};
use daphne_sched::topology::Topology;

/// A few tens of microseconds of work per item.
fn busy_item() {
    let mut x = 0u64;
    for i in 0..20_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(x);
}

/// The batch tenant: a three-stage chain, each stage a full sweep.
fn batch_pipeline(items: usize) -> GraphSpec<'static> {
    GraphSpec::new("batch-analytics")
        .node(NodeSpec::new("ingest", items), |_w, r| {
            for _ in r.iter() {
                busy_item();
            }
        })
        .node(NodeSpec::new("aggregate", items).after("ingest"), |_w, r| {
            for _ in r.iter() {
                busy_item();
            }
        })
        .node(NodeSpec::new("report", items).after("aggregate"), |_w, r| {
            for _ in r.iter() {
                busy_item();
            }
        })
}

/// The interactive tenant: one small scan, completion timestamped.
fn interactive_query(
    items: usize,
    done: Arc<Mutex<Option<Instant>>>,
) -> GraphSpec<'static> {
    GraphSpec::new("interactive-query").node(
        NodeSpec::new("scan", items),
        move |_w, r| {
            for _ in r.iter() {
                busy_item();
            }
            *done.lock().unwrap() = Some(Instant::now());
        },
    )
}

fn main() {
    // Per-item chunks on the atomic central queue: a fine preemption
    // quantum, so the pick policy — not chunk granularity — decides
    // who runs.
    let config = SchedConfig::fine_grained();
    let batch_items = 2_000;
    let query_items = 64;

    for policy in [TenancyPolicy::Fifo, TenancyPolicy::Priority] {
        let exec = Executor::new_with_policy(
            Arc::new(Topology::symmetric("demo", 1, 4, 1.0, 1.0)),
            Arc::new(config.clone()),
            policy,
        );
        let session = exec.session();
        let t0 = Instant::now();

        // tenant 1: the batch pipeline, priority 0
        let batch = session
            .submit_graph(
                batch_pipeline(batch_items),
                SubmitOpts::new().tag("batch"),
            )
            .expect("valid graph");

        // tenant 2: the interactive query, priority 2, submitted while
        // the batch work is already queued
        let done = Arc::new(Mutex::new(None));
        let query = session
            .submit_graph(
                interactive_query(query_items, Arc::clone(&done)),
                SubmitOpts::new().tag("interactive").priority(2),
            )
            .expect("valid graph");

        query.wait();
        let query_latency = done
            .lock()
            .unwrap()
            .expect("query ran")
            .duration_since(t0)
            .as_secs_f64();
        batch.wait();
        let batch_latency = t0.elapsed().as_secs_f64();

        println!("policy={:<9}", policy.name());
        println!("  interactive latency {:>9.3}ms", query_latency * 1e3);
        println!("  batch latency       {:>9.3}ms", batch_latency * 1e3);
    }

    // a demo counter just to show cancellation freeing the pool
    let exec = Executor::new_with_policy(
        Arc::new(Topology::symmetric("demo", 1, 4, 1.0, 1.0)),
        Arc::new(config),
        TenancyPolicy::Fifo,
    );
    let session = exec.session();
    let ran = Arc::new(AtomicUsize::new(0));
    let r = Arc::clone(&ran);
    let doomed = session
        .submit_graph(
            GraphSpec::new("doomed").node(
                NodeSpec::new("work", 1_000_000),
                move |_w, range| {
                    r.fetch_add(range.len(), Ordering::Relaxed);
                },
            ),
            SubmitOpts::new().tag("doomed"),
        )
        .expect("valid graph");
    doomed.cancel();
    doomed.join();
    println!(
        "cancelled tenant executed {} of 1000000 items before the pool freed",
        ran.load(Ordering::Relaxed)
    );
}
