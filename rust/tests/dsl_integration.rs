//! Integration: the paper's DaphneDSL listings end-to-end through the
//! lexer → parser → interpreter → VEE stack under non-default
//! scheduling configurations.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::collections::BTreeMap;

use daphne_sched::config::SchedConfig;
use daphne_sched::dsl::{self, run_script};
use daphne_sched::sched::{QueueLayout, Scheme, VictimStrategy};
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

fn params(pairs: &[(&str, &str)]) -> BTreeMap<String, String> {
    pairs
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

#[test]
fn listing1_converges_under_every_scheme() {
    let p = params(&[("f", "synthetic:amazon?nodes=300&seed=11")]);
    let mut baseline: Option<Vec<f32>> = None;
    for scheme in Scheme::ALL {
        let vee = Vee::new(
            Topology::symmetric("t", 1, 2, 1.0, 1.0),
            SchedConfig::default()
                .with_scheme(scheme)
                .with_layout(QueueLayout::PerCore)
                .with_victim(VictimStrategy::SeqPri),
        );
        let out = run_script(dsl::LISTING_1_CC, &p, &vee).unwrap();
        let labels = out.vars.get("c").unwrap();
        let daphne_sched::dsl::Value::Mat(m) = labels else { panic!() };
        match &baseline {
            None => baseline = Some(m.data.clone()),
            Some(b) => assert_eq!(&m.data, b, "{scheme:?} diverged"),
        }
        assert_eq!(out.num("diff"), Some(0.0), "{scheme:?} not converged");
    }
}

#[test]
fn listing1_scale_up_parameter() {
    // scaled graph = 2 disjoint copies: labels converge per copy
    let p = params(&[("f", "synthetic:amazon?nodes=200&seed=2&scale=2")]);
    let vee = Vee::host_default();
    let out = run_script(dsl::LISTING_1_CC, &p, &vee).unwrap();
    let m = out.mat("c").unwrap();
    assert_eq!(m.rows, 400);
    assert!(m.data[..200].iter().all(|&l| l == 200.0));
    assert!(m.data[200..].iter().all(|&l| l == 400.0));
}

#[test]
fn listing2_runs_under_stealing_config() {
    let vee = Vee::new(
        Topology::symmetric("t", 2, 2, 1.5, 1.0),
        SchedConfig::default()
            .with_scheme(Scheme::Tss)
            .with_layout(QueueLayout::PerGroup)
            .with_victim(VictimStrategy::RndPri),
    );
    let out = run_script(
        dsl::LISTING_2_LINREG,
        &params(&[("numRows", "3000"), ("numCols", "17")]),
        &vee,
    )
    .unwrap();
    let beta = out.mat("beta").unwrap();
    assert_eq!(beta.rows, 17); // 16 features + bias
    assert!(beta.data.iter().all(|b| b.is_finite()));
    assert!(out.scheduled_time() > 0.0);
    // A is (d+1)x(d+1) after cbind
    let a = out.mat("A").unwrap();
    assert_eq!((a.rows, a.cols), (17, 17));
}

#[test]
fn scheduled_reports_expose_scheme_names() {
    let vee = Vee::new(
        Topology::host(),
        SchedConfig::default().with_scheme(Scheme::Gss),
    );
    let out = run_script(
        dsl::LISTING_1_CC,
        &params(&[("f", "synthetic:amazon?nodes=300&seed=4")]),
        &vee,
    )
    .unwrap();
    assert!(!out.reports.is_empty());
    for (_, report) in &out.reports {
        assert_eq!(report.scheme, "GSS");
    }
}
