//! Integration: the Fig. 5 distributed coordinator over localhost TCP.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::net::TcpListener;

use daphne_sched::apps::cc;
use daphne_sched::config::SchedConfig;
use daphne_sched::coordinator::{worker, Leader};
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::matrix::CsrMatrix;
use daphne_sched::sched::Scheme;
use daphne_sched::topology::Topology;
use daphne_sched::vee::Vee;

/// Start `n` worker daemons on ephemeral ports; returns their addrs.
fn spawn_workers(n: usize, scheme: Scheme) -> Vec<std::net::SocketAddr> {
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap());
        let vee = Vee::new(
            Topology::symmetric("w", 1, 2, 1.0, 1.0),
            SchedConfig::default().with_scheme(scheme),
        );
        std::thread::spawn(move || {
            worker::serve(listener, vee, Some(1)).unwrap();
        });
    }
    addrs
}

#[test]
fn distributed_cc_matches_local() {
    let g = amazon_like(&SnapGraph::small(600, 13)).symmetrize();
    let local = cc::run_native(
        &g,
        &Topology::symmetric("t", 1, 2, 1.0, 1.0),
        &SchedConfig::default(),
        100,
    );

    let addrs = spawn_workers(3, Scheme::Gss);
    let mut leader = Leader::connect(&addrs).unwrap();
    assert_eq!(leader.n_workers(), 3);
    let dist = leader.cc_distributed(&g, 100).unwrap();
    leader.shutdown().unwrap();

    assert_eq!(dist.labels, local.labels);
    assert_eq!(dist.iterations, local.iterations);
    assert!(dist.scheduled_time > 0.0);
}

#[test]
fn distributed_cc_two_components() {
    // components {0,1,2} and {3,4} split across 2 workers
    let g = CsrMatrix::from_edges(
        5,
        5,
        &[(0, 1), (1, 0), (1, 2), (2, 1), (3, 4), (4, 3)],
    );
    let addrs = spawn_workers(2, Scheme::Static);
    let mut leader = Leader::connect(&addrs).unwrap();
    let dist = leader.cc_distributed(&g, 100).unwrap();
    leader.shutdown().unwrap();
    assert_eq!(dist.labels, vec![3.0, 3.0, 3.0, 5.0, 5.0]);
}

#[test]
fn script_shipping_runs_on_all_workers() {
    let addrs = spawn_workers(2, Scheme::Static);
    let mut leader = Leader::connect(&addrs).unwrap();
    let results = leader
        .run_script_all(
            "n = $n;\nresult = seq(1, n) + fill(1.0, n, 1);",
            &[("n".into(), "4".into())],
        )
        .unwrap();
    leader.shutdown().unwrap();
    assert_eq!(results.len(), 2);
    for r in results {
        assert_eq!(r.data, vec![2.0, 3.0, 4.0, 5.0]);
    }
}

#[test]
fn script_errors_propagate() {
    let addrs = spawn_workers(1, Scheme::Static);
    let mut leader = Leader::connect(&addrs).unwrap();
    let err = leader
        .run_script_all("result = nosuchfn(1);", &[])
        .unwrap_err();
    assert!(err.to_string().contains("worker error"), "{err}");
    leader.shutdown().unwrap();
}

#[test]
fn distribute_assigns_contiguous_blocks() {
    let g = amazon_like(&SnapGraph::small(103, 5)).symmetrize();
    let addrs = spawn_workers(4, Scheme::Static);
    let mut leader = Leader::connect(&addrs).unwrap();
    leader.distribute_sparse("G", &g).unwrap();
    let blocks = leader.blocks().to_vec();
    leader.shutdown().unwrap();
    assert_eq!(blocks.len(), 4);
    assert_eq!(blocks[0].0, 0);
    assert_eq!(blocks[3].1, 103);
    for w in blocks.windows(2) {
        assert_eq!(w[0].1, w[1].0, "blocks must be contiguous");
    }
    // 103 = 26 + 26 + 26 + 25
    assert_eq!(blocks[0], (0, 26));
    assert_eq!(blocks[3], (78, 103));
}
