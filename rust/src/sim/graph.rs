//! Virtual-time task-graph replay: the DES equivalent of
//! [`crate::sched::graph`].
//!
//! PR 2 made the real executor dependency-aware — independent graph
//! nodes overlap on the resident pool. But the DES could still only
//! model one flat job, so DAG-overlap wins were observable on the host
//! machine and nowhere else. This module closes that gap:
//!
//! - [`GraphShape`] / [`NodeModel`] mirror
//!   [`GraphSpec`](crate::sched::graph::GraphSpec) /
//!   [`NodeSpec`](crate::sched::graph::NodeSpec) but are
//!   *cost-described* instead of closure-bodied: each node carries a
//!   [`Workload`] (per-item virtual costs), an optional per-node
//!   [`SchedConfig`] override, and explicit `after(...)` edges.
//! - [`replay`] extends the [`super::engine`] event loop to many
//!   concurrently active jobs: each active node is a
//!   `JobSim` (the same real `TaskSource` + victim selectors +
//!   serialized queue horizons as a single-job simulation), and the
//!   worker event that retires a node's **last chunk** enqueues the
//!   node's ready dependents at the current virtual time — independent
//!   branches overlap on the modelled pool exactly as the real executor
//!   overlaps them. [`GraphMode::Barrier`] instead serializes the nodes
//!   in topological order (one full single-job simulation each), the
//!   A/B baseline.
//! - Shapes are validated by the *same*
//!   [`toposort`](crate::sched::graph::toposort) as the executor path,
//!   so cyclic / unknown-dependency / duplicate-name shapes are
//!   rejected with the same [`GraphError`]s the real submission would
//!   produce.
//! - When event tracing is enabled ([`crate::obs::trace`]) the replay
//!   stamps the same `TraceEvent` stream the real executor records —
//!   Enqueue / Dispatch / TaskStart / TaskEnd / Steal / NodeComplete
//!   (plus Admit / Shed under [`SimAdmission`]) at *virtual*
//!   timestamps via [`trace::record_at`] — so one seeded workload can
//!   be replayed on both engines and diffed event-for-event.
//!
//! Heterogeneous machines replay with the same pool semantics the real
//! executor dispatches: [`NodeModel`] carries a
//! [`Placement`], the modelled machine's places partition into
//! per-class [`DevicePools`] (accelerator speed factors folded into
//! each pool's sub-topology), and a worker only scans — and only
//! steals within — the active jobs of its own pool. `Placement::Class`
//! on a class the machine model lacks is the same
//! [`GraphError::NoSuchPool`] the executor returns.
//!
//! The replay is the oracle behind graph-level autotuning
//! ([`crate::sched::autotune::tune_graph`]): per-node configurations
//! (and, on heterogeneous machines, per-node placements) are evaluated
//! in virtual time on the modelled 20- and 56-core machines,
//! milliseconds per candidate instead of hours of grid runs.

use std::collections::BinaryHeap;

use super::engine::{Ev, JobSim, SimOutcome};
use super::model::{CostModel, TraceCalibration, Workload};
use crate::config::{GraphMode, SchedConfig};
use crate::obs::trace::{self, TraceKind, NO_JOB, OBS_CONTROL_WORKER};
use crate::sched::graph::{toposort, GraphError, TopoOrder};
use crate::sched::metrics::{SchedReport, WorkerStats};
use crate::sched::placement::{DevicePools, Placement, ResolveMode};
use crate::sched::session::{AdmissionPolicy, AGING_QUANTUM_SECS};
use crate::sched::TenancyPolicy;
use crate::topology::{DeviceClass, Topology};
use crate::util::stats;

/// Virtual seconds → integer nanoseconds for the shared trace stream
/// ([`crate::obs::trace`]): the DES stamps events with
/// [`trace::record_at`] so a simulated replay and a real run of the
/// same workload produce one diffable event sequence.
fn vns(t: f64) -> u64 {
    (t * 1e9).round() as u64
}

/// Cost model of one graph node: a name (unique within its shape), a
/// [`Workload`] of per-item virtual costs, an optional per-node
/// scheduling override, a device-pool [`Placement`], and the names of
/// the nodes it must run after. The cost-described sibling of
/// [`crate::sched::graph::NodeSpec`].
#[derive(Debug, Clone)]
pub struct NodeModel {
    pub name: String,
    pub workload: Workload,
    /// `None` = the replay's default config.
    pub config: Option<SchedConfig>,
    /// Which of the modelled machine's device pools runs this node
    /// (`Any` = the default/CPU pool). Replay resolves it in
    /// [`ResolveMode::Model`]: the machine model's pools are always
    /// honoured, regardless of what this build can execute.
    pub placement: Placement,
    /// Dependency edges by node name.
    pub after: Vec<String>,
}

impl NodeModel {
    pub fn new(name: &str, workload: Workload) -> Self {
        NodeModel {
            name: name.to_string(),
            workload,
            config: None,
            placement: Placement::Any,
            after: Vec::new(),
        }
    }

    /// Uniform per-item cost — the common case for dense operators.
    pub fn uniform(name: &str, items: usize, per_item: f64) -> Self {
        NodeModel::new(name, Workload::uniform(name, items, per_item))
    }

    /// Add one dependency edge: this node starts only after `dep`
    /// completes. Forward references resolve at replay.
    pub fn after(mut self, dep: &str) -> Self {
        self.after.push(dep.to_string());
        self
    }

    /// Add several dependency edges at once.
    pub fn after_all<'d>(
        mut self,
        deps: impl IntoIterator<Item = &'d str>,
    ) -> Self {
        self.after.extend(deps.into_iter().map(str::to_string));
        self
    }

    /// Override the replay's default scheduling for this node.
    pub fn with_config(mut self, config: SchedConfig) -> Self {
        self.config = Some(config);
        self
    }

    /// Pin this node to the pool of a device class on the modelled
    /// machine (sugar for [`NodeModel::with_placement`]). An absent
    /// class is a [`GraphError::NoSuchPool`] at replay — the same error
    /// the real submission would produce.
    pub fn on(self, class: DeviceClass) -> Self {
        self.with_placement(Placement::Class(class))
    }

    /// Constrain which modelled pool runs this node.
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }
}

/// A cost-described task graph: what [`crate::sched::graph::GraphSpec`]
/// is to the real executor, `GraphShape` is to the DES. Apps export
/// their real shapes (e.g. [`crate::apps::linreg::graph_shape`]) so the
/// replay models the same dependency structure the executor dispatches.
#[derive(Debug, Clone, Default)]
pub struct GraphShape {
    pub name: String,
    nodes: Vec<NodeModel>,
}

impl GraphShape {
    pub fn new(name: &str) -> Self {
        GraphShape { name: name.to_string(), nodes: Vec::new() }
    }

    /// Builder-style [`GraphShape::add`].
    pub fn node(mut self, node: NodeModel) -> Self {
        self.add(node);
        self
    }

    pub fn add(&mut self, node: NodeModel) {
        self.nodes.push(node);
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn nodes(&self) -> &[NodeModel] {
        &self.nodes
    }

    pub fn node_names(&self) -> impl Iterator<Item = &str> {
        self.nodes.iter().map(|n| n.name.as_str())
    }

    /// Total sequential cost of every node (virtual seconds on one
    /// baseline core).
    pub fn total_cost(&self) -> f64 {
        self.nodes.iter().map(|n| n.workload.total_cost()).sum()
    }

    /// Kahn-validated dispatch structure of this shape — the same
    /// [`toposort`] the executor path runs. The tuner computes it once
    /// and replays against it many times ([`replay_ordered`]).
    pub(crate) fn toposorted(&self) -> Result<TopoOrder, GraphError> {
        let meta: Vec<(String, Vec<String>)> = self
            .nodes
            .iter()
            .map(|n| (n.name.clone(), n.after.clone()))
            .collect();
        toposort(&meta)
    }

    /// Validate the dependency structure without running anything — the
    /// same [`toposort`] check every replay performs, so a shape that
    /// passes here never fails a later [`replay`].
    pub fn validate(&self) -> Result<(), GraphError> {
        self.toposorted().map(|_| ())
    }

    /// The A/B shape of the figures and acceptance tests: a root fans
    /// out into a heavy and a light branch (each `width` items wide, so
    /// each alone strands the rest of a `2*width`-core machine) that
    /// join into a small tail. Barrier mode pays
    /// `heavy + light` for the middle section; dag mode overlaps them
    /// and pays `max(heavy, light)`.
    pub fn unbalanced_diamond(width: usize) -> GraphShape {
        GraphShape::new("unbalanced-diamond")
            .node(NodeModel::uniform("prep", width * 64, 2e-6))
            .node(NodeModel::uniform("heavy", width, 4e-3).after("prep"))
            .node(NodeModel::uniform("light", width, 1e-3).after("prep"))
            .node(
                NodeModel::uniform("join", width * 16, 2e-6)
                    .after("heavy")
                    .after("light"),
            )
    }

    /// Apply measured per-node service totals from a
    /// [`TraceCalibration`]: every node the trace measured gets its
    /// workload rescaled to the measured total (per-item distribution
    /// preserved — see [`Workload::scaled_to`]); unmeasured nodes keep
    /// their assumed costs. This is how `tune_graph` re-tunes on
    /// observed rather than assumed workloads
    /// ([`crate::sched::autotune::tune_graph_calibrated`]).
    pub fn recosted(&self, cal: &TraceCalibration) -> GraphShape {
        let mut out = self.clone();
        for n in &mut out.nodes {
            if let Some(secs) = cal.service_secs(&n.name) {
                if secs > 0.0 {
                    n.workload = n.workload.scaled_to(secs);
                }
            }
        }
        out
    }
}

/// Outcome of one node inside a graph replay.
#[derive(Debug, Clone)]
pub struct NodeSimOutcome {
    pub name: String,
    /// Device class of the modelled pool that ran the node.
    pub device: DeviceClass,
    /// The node's own scheduling outcome; its `report.makespan` is the
    /// node's span (`finish - start`).
    pub outcome: SimOutcome,
    /// Virtual time the node became ready and started dispatching.
    pub start: f64,
    /// Virtual time the node's last item finished executing.
    pub finish: f64,
}

/// Result of one graph replay.
#[derive(Debug, Clone)]
pub struct GraphSimOutcome {
    pub graph: String,
    pub mode: GraphMode,
    /// Per-node outcomes, in shape order.
    pub nodes: Vec<NodeSimOutcome>,
    /// Virtual completion time of the whole graph.
    pub makespan: f64,
    /// Node names along the dependency chain that determines the
    /// makespan (root first). In barrier mode every node is on it.
    pub critical_path: Vec<String>,
}

impl GraphSimOutcome {
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// Sum of per-node spans — what a full barrier after every node
    /// would cost; `serial_time() / makespan()` estimates the overlap
    /// win of dag dispatch.
    pub fn serial_time(&self) -> f64 {
        self.nodes.iter().map(|n| n.outcome.report.makespan).sum()
    }

    pub fn node(&self, name: &str) -> Option<&NodeSimOutcome> {
        self.nodes.iter().find(|n| n.name == name)
    }

    pub fn total_steals(&self) -> usize {
        self.nodes.iter().map(|n| n.outcome.report.total_steals()).sum()
    }
}

/// Replay `shape` on the modelled machine under `mode`, resolving each
/// node's config as its own override or else `default`. Validation
/// (duplicate names, unknown dependencies, cycles) uses the same
/// [`toposort`] as [`crate::sched::Executor::submit_graph`], so a shape
/// is rejected with exactly the [`GraphError`] the real submission
/// would produce.
pub fn replay(
    shape: &GraphShape,
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
    mode: GraphMode,
) -> Result<GraphSimOutcome, GraphError> {
    let configs: Vec<SchedConfig> = shape
        .nodes
        .iter()
        .map(|n| n.config.clone().unwrap_or_else(|| default.clone()))
        .collect();
    let placements: Vec<Placement> =
        shape.nodes.iter().map(|n| n.placement).collect();
    replay_resolved(shape, topo, &configs, &placements, costs, mode)
}

/// Like [`replay`] but with an explicit per-node config assignment
/// (ignoring the shape's own overrides; placements stay the shape's) —
/// the evaluation entry point of graph-level autotuning, which owns the
/// assignment it is refining.
pub fn replay_with_configs(
    shape: &GraphShape,
    topo: &Topology,
    configs: &[SchedConfig],
    costs: &CostModel,
    mode: GraphMode,
) -> Result<GraphSimOutcome, GraphError> {
    let placements: Vec<Placement> =
        shape.nodes.iter().map(|n| n.placement).collect();
    replay_placed(shape, topo, configs, &placements, costs, mode)
}

/// Replay with both dimensions explicit: per-node configs *and*
/// per-node placements (the shape's own overrides for either are
/// ignored). What placement-aware autotuning replays its refined
/// assignments through.
pub fn replay_placed(
    shape: &GraphShape,
    topo: &Topology,
    configs: &[SchedConfig],
    placements: &[Placement],
    costs: &CostModel,
    mode: GraphMode,
) -> Result<GraphSimOutcome, GraphError> {
    assert_eq!(
        configs.len(),
        shape.nodes.len(),
        "one config per shape node"
    );
    assert_eq!(
        placements.len(),
        shape.nodes.len(),
        "one placement per shape node"
    );
    replay_resolved(shape, topo, configs, placements, costs, mode)
}

fn replay_resolved(
    shape: &GraphShape,
    topo: &Topology,
    configs: &[SchedConfig],
    placements: &[Placement],
    costs: &CostModel,
    mode: GraphMode,
) -> Result<GraphSimOutcome, GraphError> {
    let order = shape.toposorted()?;
    let pools = DevicePools::from_topology(topo);
    let node_pool = resolve_pools(shape, &pools, placements)?;
    Ok(replay_ordered(shape, &pools, configs, &node_pool, costs, mode, &order))
}

/// Resolve per-node placements against the modelled machine's pools —
/// [`ResolveMode::Model`], so a GPU pool of the model is honoured even
/// on a pjrt-less build. An unsatisfiable placement is the same
/// [`GraphError::NoSuchPool`] the real submission would produce.
pub(crate) fn resolve_pools(
    shape: &GraphShape,
    pools: &DevicePools,
    placements: &[Placement],
) -> Result<Vec<usize>, GraphError> {
    shape
        .nodes
        .iter()
        .zip(placements)
        .map(|(n, p)| {
            pools
                .resolve(p, ResolveMode::Model)
                .map(|r| r.pool)
                .map_err(|e| GraphError::NoSuchPool {
                    node: n.name.clone(),
                    wanted: e.wanted,
                })
        })
        .collect()
}

/// Replay against a precomputed [`TopoOrder`], pool partition, and
/// per-node pool assignment — the tuner's hot loop, which validates a
/// shape once and then evaluates thousands of per-node assignments
/// against the same order.
pub(crate) fn replay_ordered(
    shape: &GraphShape,
    pools: &DevicePools,
    configs: &[SchedConfig],
    node_pool: &[usize],
    costs: &CostModel,
    mode: GraphMode,
    order: &TopoOrder,
) -> GraphSimOutcome {
    match mode {
        GraphMode::Barrier => {
            replay_barrier(shape, pools, configs, node_pool, costs, order)
        }
        GraphMode::Dag => {
            replay_dag(shape, pools, configs, node_pool, costs, order)
        }
    }
}

/// Outcome of a node with no items: it completes the instant it becomes
/// ready, with no queue or worker activity — what the real executor's
/// inline zero-item completion costs. Used by *both* modes so that
/// empty synchronization-only nodes never skew a dag-vs-barrier
/// comparison.
fn empty_outcome(topo: &Topology, config: &SchedConfig) -> SimOutcome {
    SimOutcome {
        report: SchedReport {
            scheme: config.scheme.name().to_string(),
            layout: config.layout.name().to_string(),
            victim: config.victim.name().to_string(),
            makespan: 0.0,
            queue_delay: 0.0,
            per_worker: vec![WorkerStats::default(); topo.n_cores()],
        },
        queue_busy: Vec::new(),
        acquisitions: 0,
    }
}

/// Barrier baseline: one single-job simulation per node, serialized in
/// topological order — the virtual-time equivalent of `graph=barrier`.
/// Each node simulates on its resolved pool's sub-topology (the rest of
/// the machine idles through its span, as a full barrier would force).
fn replay_barrier(
    shape: &GraphShape,
    pools: &DevicePools,
    configs: &[SchedConfig],
    node_pool: &[usize],
    costs: &CostModel,
    order: &TopoOrder,
) -> GraphSimOutcome {
    let mut nodes: Vec<Option<NodeSimOutcome>> =
        (0..shape.nodes.len()).map(|_| None).collect();
    let mut t = 0.0;
    for &i in &order.order {
        let node = &shape.nodes[i];
        let pool = pools.pool(node_pool[i]);
        let out = if node.workload.items() == 0 {
            empty_outcome(&pool.topo, &configs[i])
        } else {
            super::engine::simulate(
                &pool.topo,
                &configs[i],
                &node.workload,
                costs,
            )
        };
        let span = out.makespan();
        nodes[i] = Some(NodeSimOutcome {
            name: node.name.clone(),
            device: pool.class,
            outcome: out,
            start: t,
            finish: t + span,
        });
        t += span;
    }
    GraphSimOutcome {
        graph: shape.name.clone(),
        mode: GraphMode::Barrier,
        critical_path: order
            .order
            .iter()
            .map(|&i| shape.nodes[i].name.clone())
            .collect(),
        nodes: nodes.into_iter().map(|n| n.expect("all simulated")).collect(),
        makespan: t,
    }
}

/// Dependency-aware replay: the engine's worker event loop over many
/// live `JobSim`s. A worker event first retires the chunk it was
/// executing; if that was its node's last outstanding chunk the node
/// completes *at this virtual time*, its ready dependents activate, and
/// parked workers wake — then the worker scans the active jobs *of its
/// own device pool* in activation order (own-queue probe + steal round
/// each, mirroring the executor's pool-scoped job multiplexing) for its
/// next chunk. Nodes placed on different pools therefore overlap on
/// disjoint modelled workers, with the accelerator pool's speed factor
/// applied through its sub-topology.
fn replay_dag(
    shape: &GraphShape,
    pools: &DevicePools,
    configs: &[SchedConfig],
    node_pool: &[usize],
    costs: &CostModel,
    order: &TopoOrder,
) -> GraphSimOutcome {
    let n_nodes = shape.nodes.len();
    let nw = pools.n_workers();
    let items: Vec<usize> =
        shape.nodes.iter().map(|n| n.workload.items()).collect();
    let mut pending: Vec<usize> = order.deps.iter().map(Vec::len).collect();
    let mut executed = vec![0usize; n_nodes];
    let mut start = vec![0f64; n_nodes];
    let mut finish = vec![0f64; n_nodes];
    let mut outcomes: Vec<Option<SimOutcome>> =
        (0..n_nodes).map(|_| None).collect();
    // Active jobs in activation order; workers scan this list FIFO
    // (skipping jobs placed on a foreign pool).
    let mut active: Vec<(usize, JobSim<'_>)> = Vec::new();
    let mut remaining = n_nodes;
    // What each worker is currently executing: (node, chunk len); the
    // chunk ends exactly at the worker's next heap event.
    let mut chunk: Vec<Option<(usize, usize)>> = vec![None; nw];
    // Park time of each idle worker, woken at the next activation.
    let mut parked: Vec<Option<f64>> = vec![None; nw];
    let mut makespan = 0f64;

    // Trace emission: the DES half of the shared event stream. Name
    // hashes are precomputed once per replay; every `record_at` sits
    // behind the same `enabled()` gate as the executor's hooks, so an
    // untraced replay pays one relaxed load up front and nothing per
    // event.
    let tracing = trace::enabled();
    let name_hash: Vec<u64> = if tracing {
        shape.nodes.iter().map(|n| trace::fnv1a(&n.name)).collect()
    } else {
        Vec::new()
    };
    // first-acquisition latch per node: Dispatch is recorded once
    let mut node_started = vec![false; n_nodes];

    // Activate every node in `ready` at virtual time `t`. Zero-item
    // nodes complete inline (worklist, so chains of them stay
    // iterative); the rest get a live JobSim over their pool's
    // sub-topology. Returns whether any job actually went live (only
    // then do parked workers need waking).
    macro_rules! activate {
        ($ready:expr, $t:expr) => {{
            let mut worklist: Vec<usize> = $ready;
            let mut went_live = false;
            while let Some(i) = worklist.pop() {
                start[i] = $t;
                if tracing {
                    trace::record_at(
                        vns($t),
                        TraceKind::Enqueue,
                        OBS_CONTROL_WORKER,
                        i as u64,
                        name_hash[i],
                        0,
                    );
                }
                if items[i] == 0 {
                    finish[i] = $t;
                    remaining -= 1;
                    outcomes[i] = Some(empty_outcome(
                        &pools.pool(node_pool[i]).topo,
                        &configs[i],
                    ));
                    if tracing {
                        // inline completion: terminal the instant it
                        // activates, before any dependent's Enqueue
                        trace::record_at(
                            vns($t),
                            TraceKind::NodeComplete,
                            OBS_CONTROL_WORKER,
                            i as u64,
                            name_hash[i],
                            0,
                        );
                    }
                    for &d in &order.dependents[i] {
                        pending[d] -= 1;
                        if pending[d] == 0 {
                            worklist.push(d);
                        }
                    }
                } else {
                    active.push((
                        i,
                        JobSim::new(
                            &pools.pool(node_pool[i]).topo,
                            &configs[i],
                            &shape.nodes[i].workload,
                            costs,
                        ),
                    ));
                    went_live = true;
                }
            }
            went_live
        }};
    }

    let roots: Vec<usize> =
        (0..n_nodes).filter(|&i| pending[i] == 0).collect();
    // no workers are parked yet, so the went-live flag is moot here
    let _ = activate!(roots, 0.0);

    let mut heap: BinaryHeap<Ev> = (0..nw).map(|w| Ev { t: 0.0, w }).collect();

    while let Some(Ev { t, w }) = heap.pop() {
        let mut now = t;
        let my_pool = pools.pool_of(w);
        let lw = pools.local_of(w);
        let my_topo = &pools.pool(my_pool).topo;

        // retire the chunk this event marks the end of
        if let Some((node, len)) = chunk[w].take() {
            executed[node] += len;
            if tracing {
                trace::record_at(
                    vns(t),
                    TraceKind::TaskEnd,
                    w,
                    node as u64,
                    name_hash[node],
                    0,
                );
            }
            if executed[node] == items[node] {
                // the node's last item finished right now: complete it,
                // release dependents, wake parked workers
                finish[node] = t;
                remaining -= 1;
                let pos = active
                    .iter()
                    .position(|(i, _)| *i == node)
                    .expect("completed node was active");
                let (_, job) = active.remove(pos);
                outcomes[node] = Some(job.into_outcome(t - start[node]));
                if tracing {
                    // before dependents release, like the executor's
                    // `record_done`: parent NodeComplete always trails
                    // into a child's Enqueue in the merged timeline
                    trace::record_at(
                        vns(t),
                        TraceKind::NodeComplete,
                        OBS_CONTROL_WORKER,
                        node as u64,
                        name_hash[node],
                        0,
                    );
                }
                let mut ready = Vec::new();
                for &d in &order.dependents[node] {
                    pending[d] -= 1;
                    if pending[d] == 0 {
                        ready.push(d);
                    }
                }
                if activate!(ready, t) {
                    for (w2, slot) in parked.iter_mut().enumerate() {
                        if let Some(p) = slot.take() {
                            heap.push(Ev { t: p.max(t), w: w2 });
                        }
                    }
                }
            }
        }

        if remaining == 0 {
            makespan = makespan.max(now);
            continue; // graph done; drain remaining worker events
        }

        // scan this pool's active jobs in activation order for the next
        // chunk (a foreign pool's sources are invisible to this worker,
        // exactly as in the real executor)
        let mut got: Option<(usize, crate::sched::queue::Pull)> = None;
        for (idx, (node, job)) in active.iter_mut().enumerate() {
            if node_pool[*node] != my_pool {
                continue;
            }
            if let Some(pull) = job.try_acquire(my_topo, lw, &mut now) {
                got = Some((idx, pull));
                break;
            }
        }
        match got {
            Some((idx, pull)) => {
                let (node, job) = &mut active[idx];
                if tracing {
                    let g = *node;
                    if !node_started[g] {
                        node_started[g] = true;
                        trace::record_at(
                            vns(now),
                            TraceKind::Dispatch,
                            w,
                            g as u64,
                            name_hash[g],
                            0,
                        );
                    }
                    if pull.stolen {
                        trace::record_at(
                            vns(now),
                            TraceKind::Steal,
                            w,
                            g as u64,
                            name_hash[g],
                            0,
                        );
                    }
                    trace::record_at(
                        vns(now),
                        TraceKind::TaskStart,
                        w,
                        g as u64,
                        name_hash[g],
                        0,
                    );
                }
                let exec = job.exec_time(my_topo, lw, &pull);
                chunk[w] = Some((*node, pull.task.len()));
                heap.push(Ev { t: now + exec, w });
            }
            None => {
                // every dealt chunk is in flight elsewhere: park until
                // the next node activates (drained sources never refill)
                makespan = makespan.max(now);
                parked[w] = Some(now);
            }
        }
    }

    let nodes: Vec<NodeSimOutcome> = outcomes
        .into_iter()
        .enumerate()
        .map(|(i, o)| NodeSimOutcome {
            name: shape.nodes[i].name.clone(),
            device: pools.pool(node_pool[i]).class,
            outcome: o.expect("remaining == 0 means every node completed"),
            start: start[i],
            finish: finish[i],
        })
        .collect();
    let makespan = nodes
        .iter()
        .map(|n| n.finish)
        .fold(makespan, f64::max);
    let critical_path = critical_path(shape, order, &nodes);
    GraphSimOutcome {
        graph: shape.name.clone(),
        mode: GraphMode::Dag,
        nodes,
        makespan,
        critical_path,
    }
}

/// Walk back from the last-finishing node through its latest-finishing
/// dependency to a root; returns names root-first.
fn critical_path(
    shape: &GraphShape,
    order: &TopoOrder,
    nodes: &[NodeSimOutcome],
) -> Vec<String> {
    if nodes.is_empty() {
        return Vec::new();
    }
    let mut i = (0..nodes.len())
        .max_by(|&a, &b| nodes[a].finish.total_cmp(&nodes[b].finish))
        .expect("non-empty");
    let mut rev = vec![i];
    while let Some(&d) = order.deps[i]
        .iter()
        .max_by(|&&a, &&b| nodes[a].finish.total_cmp(&nodes[b].finish))
    {
        rev.push(d);
        i = d;
    }
    rev.reverse();
    rev.into_iter().map(|i| shape.nodes[i].name.clone()).collect()
}

// ---------------------------------------------------------------------------
// multi-tenant replay (the DES mirror of `sched::session`)
// ---------------------------------------------------------------------------

/// One tenant in a multi-graph replay ([`replay_tenants`]): a pipeline
/// shape plus its virtual arrival time and the tenancy options its
/// real-executor submission would carry
/// ([`SubmitOpts`](crate::sched::SubmitOpts)).
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub shape: GraphShape,
    /// Virtual time at which this tenant submits its graph.
    pub arrival: f64,
    /// Priority level for [`TenancyPolicy::Priority`] (higher first).
    pub priority: i64,
    /// Share weight for [`TenancyPolicy::Fair`].
    pub weight: u64,
    /// Fair-share tag (empty = the anonymous tenant).
    pub tag: String,
}

impl TenantSpec {
    pub fn new(name: &str, shape: GraphShape, arrival: f64) -> Self {
        TenantSpec {
            name: name.to_string(),
            shape,
            arrival,
            priority: 0,
            weight: 1,
            tag: String::new(),
        }
    }

    pub fn priority(mut self, priority: i64) -> Self {
        self.priority = priority;
        self
    }

    pub fn weight(mut self, weight: u64) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn tag(mut self, tag: &str) -> Self {
        self.tag = tag.to_string();
        self
    }
}

/// Outcome of one tenant inside a [`replay_tenants`] run.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub name: String,
    pub tag: String,
    /// Virtual submission time.
    pub arrival: f64,
    /// Virtual time a worker first acquired a chunk of this tenant's
    /// graph (= `finish` for an all-empty graph): the end of the
    /// queueing-delay window, mirroring the executor's
    /// `SchedReport::queue_delay`.
    pub started: f64,
    /// Virtual time the tenant's last node finished.
    pub finish: f64,
    /// Makespan this tenant's graph replays to *alone* on the idle
    /// machine (dag mode) — the denominator of [`TenantOutcome::slowdown`].
    pub isolated: f64,
}

impl TenantOutcome {
    /// Submission-to-completion latency (queueing included).
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Admission → first dispatch: the queueing component of
    /// [`TenantOutcome::latency`].
    pub fn queueing_delay(&self) -> f64 {
        (self.started - self.arrival).max(0.0)
    }

    /// First dispatch → completion: the latency with the queueing
    /// delay stripped out.
    pub fn service_time(&self) -> f64 {
        (self.finish - self.started).max(0.0)
    }

    /// Latency normalized by the tenant's isolated makespan — the
    /// standard multi-tenancy metric (1.0 = as fast as an idle
    /// machine). A zero-cost tenant reports slowdown 1.0.
    pub fn slowdown(&self) -> f64 {
        if self.isolated > 0.0 {
            self.latency() / self.isolated
        } else {
            1.0
        }
    }
}

/// Result of one multi-tenant replay.
#[derive(Debug, Clone)]
pub struct TenancySimOutcome {
    pub policy: TenancyPolicy,
    /// Per-tenant outcomes, in spec order.
    pub tenants: Vec<TenantOutcome>,
    /// Virtual completion time of the whole workload.
    pub makespan: f64,
}

impl TenancySimOutcome {
    pub fn latencies(&self) -> Vec<f64> {
        self.tenants.iter().map(TenantOutcome::latency).collect()
    }

    pub fn slowdowns(&self) -> Vec<f64> {
        self.tenants.iter().map(TenantOutcome::slowdown).collect()
    }

    /// Median per-tenant slowdown.
    pub fn p50_slowdown(&self) -> f64 {
        stats::percentile(&self.slowdowns(), 50.0)
    }

    /// Tail (p99) per-tenant slowdown — what a policy is judged by
    /// under bursty arrivals.
    pub fn p99_slowdown(&self) -> f64 {
        stats::percentile(&self.slowdowns(), 99.0)
    }

    /// Jain's fairness index over per-tenant slowdowns (1.0 = every
    /// tenant slowed equally).
    pub fn fairness(&self) -> f64 {
        stats::jain_fairness(&self.slowdowns())
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantOutcome> {
        self.tenants.iter().find(|t| t.name == name)
    }
}

/// A live job of the multi-tenant event loop: one graph node's
/// [`JobSim`] plus the pick-policy bookkeeping.
struct ActiveJob<'w> {
    /// Global node index.
    node: usize,
    tenant: usize,
    pool: usize,
    /// Activation sequence (the FIFO key; ties in every policy break
    /// towards the older activation).
    seq: u64,
    /// Virtual time a worker last acquired a chunk of this job
    /// (initially the tenant's arrival). Priority aging measures
    /// waiting as `now - served_at` — the mirror of the executor's
    /// `Job::served_ns`.
    served_at: f64,
    sim: JobSim<'w>,
}

/// Replay many tenants' graphs over one modelled machine under a
/// cross-job pick policy — the virtual-time mirror of submitting each
/// shape through one [`Session`](crate::sched::Session) of an executor
/// running [`TenancyPolicy`] `policy`. Tenants arrive at their
/// [`TenantSpec::arrival`] offsets; each worker event retires its
/// chunk, completes/activates nodes exactly as [`replay`]'s dag mode,
/// and then scans its pool's active jobs *in policy order* (FIFO by
/// activation, priority with virtual-time aging, or weighted fair over
/// tags by executed items) for its next chunk. Per-node configs resolve
/// as the node's own override or else `default`; validation and
/// placement resolution match the executor path per graph.
pub fn replay_tenants(
    tenants: &[TenantSpec],
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
    policy: TenancyPolicy,
) -> Result<TenancySimOutcome, GraphError> {
    let isolated = isolated_makespans(tenants, topo, default, costs)?;
    replay_tenants_with(tenants, topo, default, costs, policy, &isolated)
}

/// Per-tenant isolated baselines: each shape's dag-mode makespan
/// replayed *alone* on the idle machine (the slowdown denominator).
/// Policy-independent — callers comparing several policies over one
/// tenant mix (the tenancy figure, [`tune_tenancy`]
/// ([`crate::sched::autotune::tune_tenancy`])) compute this once and
/// pass it to [`replay_tenants_with`] instead of re-replaying every
/// baseline per policy.
pub fn isolated_makespans(
    tenants: &[TenantSpec],
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
) -> Result<Vec<f64>, GraphError> {
    tenants
        .iter()
        .map(|t| {
            replay(&t.shape, topo, default, costs, GraphMode::Dag)
                .map(|o| o.makespan())
        })
        .collect()
}

/// [`replay_tenants`] with precomputed [`isolated_makespans`] (one
/// entry per tenant, same order).
pub fn replay_tenants_with(
    tenants: &[TenantSpec],
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
    policy: TenancyPolicy,
    isolated: &[f64],
) -> Result<TenancySimOutcome, GraphError> {
    replay_tenants_admitted(tenants, topo, default, costs, policy, isolated, None)
        .map(|(out, _)| out)
}

/// Admission applied to one tag's arrivals inside the tenant replay —
/// the DES mirror of the serving loop's
/// [`AdmissionPolicy`](crate::sched::AdmissionPolicy) check: at each
/// matching tenant's arrival, `backlog` is the number of
/// already-admitted same-tag tenants still unfinished at that virtual
/// instant, and `est_wait = backlog × est_cost` — identical inputs to
/// the real loop's decision, so accept/reject sequences agree.
pub struct SimAdmission {
    pub policy: AdmissionPolicy,
    pub tag: String,
    pub est_cost: f64,
}

/// [`replay_tenants_with`] plus per-arrival admission on one tag
/// ([`SimAdmission`]). Returns the outcome and one accept/reject
/// decision per tenant in spec order (non-matching tags are always
/// accepted). A rejected tenant activates nothing: it finishes at its
/// arrival with zero latency and must be counted as shed by the caller
/// ([`super::serve::replay_open_loop`]).
///
/// When tracing is enabled ([`crate::obs::trace`]) and `admission` is
/// `Some`, every arrival additionally records an `Admit`/`Shed` event
/// at its virtual arrival time — the mirror of
/// [`Session::try_submit_graph`](crate::sched::Session::try_submit_graph)
/// — so a real run and a replay of the same request stream can be
/// diffed decision-for-decision (the obs trace-agreement test pins
/// exactly this).
pub fn replay_tenants_admitted(
    tenants: &[TenantSpec],
    topo: &Topology,
    default: &SchedConfig,
    costs: &CostModel,
    policy: TenancyPolicy,
    isolated: &[f64],
    admission: Option<&SimAdmission>,
) -> Result<(TenancySimOutcome, Vec<bool>), GraphError> {
    assert_eq!(isolated.len(), tenants.len(), "one baseline per tenant");
    let pools = DevicePools::from_topology(topo);
    let nw = pools.n_workers();
    let nt = tenants.len();

    // Per-tenant validation: the same toposort the executor runs.
    let mut orders = Vec::with_capacity(nt);
    for t in tenants {
        orders.push(t.shape.toposorted()?);
    }

    // Flatten every tenant's nodes into one global index space.
    let mut base = Vec::with_capacity(nt); // tenant -> first global idx
    let mut node_tenant = Vec::new();
    let mut node_local = Vec::new();
    for (ti, t) in tenants.iter().enumerate() {
        base.push(node_tenant.len());
        for li in 0..t.shape.nodes.len() {
            node_tenant.push(ti);
            node_local.push(li);
        }
    }
    let n_nodes = node_tenant.len();
    let node_ref: Vec<&NodeModel> = node_tenant
        .iter()
        .zip(&node_local)
        .map(|(&ti, &li)| &tenants[ti].shape.nodes[li])
        .collect();
    let configs: Vec<SchedConfig> = (0..n_nodes)
        .map(|g| node_ref[g].config.clone().unwrap_or_else(|| default.clone()))
        .collect();
    let mut node_pool = Vec::with_capacity(n_nodes);
    for (ti, t) in tenants.iter().enumerate() {
        let placements: Vec<Placement> =
            t.shape.nodes.iter().map(|n| n.placement).collect();
        node_pool.extend(resolve_pools(&t.shape, &pools, &placements)?);
        debug_assert_eq!(node_pool.len(), base[ti] + t.shape.nodes.len());
    }
    let items: Vec<usize> =
        (0..n_nodes).map(|g| node_ref[g].workload.items()).collect();
    let mut pending: Vec<usize> = (0..n_nodes)
        .map(|g| orders[node_tenant[g]].deps[node_local[g]].len())
        .collect();
    let mut executed = vec![0usize; n_nodes];

    let mut t_remaining: Vec<usize> =
        tenants.iter().map(|t| t.shape.nodes.len()).collect();
    let mut t_finish: Vec<f64> = tenants.iter().map(|t| t.arrival).collect();
    // virtual time of each tenant's first chunk acquisition (the end of
    // its queueing-delay window); None = never served
    let mut t_started: Vec<Option<f64>> = vec![None; nt];
    // admission bookkeeping: which tenants have arrived, and each
    // arrival's accept/reject decision (non-matching tags always true)
    let mut released = vec![false; nt];
    let mut decisions = vec![true; nt];
    let mut remaining: usize = t_remaining.iter().sum();

    // Trace emission: hashes precomputed once per replay. Tenant tags
    // and graph names are interned (resolvable in the export), node
    // names are plain FNV-1a — exactly the real submission path's
    // convention, so per-node streams diff across engines by hash.
    let tracing = trace::enabled();
    let node_name_hash: Vec<u64> = if tracing {
        node_ref.iter().map(|n| trace::fnv1a(&n.name)).collect()
    } else {
        Vec::new()
    };
    let tenant_name_hash: Vec<u64> = if tracing {
        tenants.iter().map(|t| trace::intern_tag(&t.name)).collect()
    } else {
        Vec::new()
    };
    let tag_hash: Vec<u64> = if tracing {
        tenants.iter().map(|t| trace::intern_tag(&t.tag)).collect()
    } else {
        Vec::new()
    };
    // first-acquisition latch per node: Dispatch is recorded once
    let mut node_started = vec![false; n_nodes];

    let mut active: Vec<ActiveJob<'_>> = Vec::new();
    let mut next_seq = 0u64;
    // What each worker is currently executing: (global node, chunk len).
    let mut chunk: Vec<Option<(usize, usize)>> = vec![None; nw];
    let mut parked: Vec<Option<f64>> = vec![None; nw];
    let mut makespan = tenants.iter().map(|t| t.arrival).fold(0.0, f64::max);

    // Arrival queue, earliest first (ties by spec order for
    // determinism).
    let mut arrivals: Vec<usize> = (0..nt).collect();
    arrivals.sort_by(|&a, &b| {
        tenants[a]
            .arrival
            .total_cmp(&tenants[b].arrival)
            .then_with(|| a.cmp(&b))
    });
    let mut next_arrival = 0usize;

    // Activate the given global nodes at virtual time `t` (a worklist,
    // so chains of zero-item nodes stay iterative). Returns whether any
    // job went live.
    macro_rules! activate {
        ($ready:expr, $t:expr) => {{
            let mut worklist: Vec<usize> = $ready;
            let mut went_live = false;
            while let Some(g) = worklist.pop() {
                let (ti, li) = (node_tenant[g], node_local[g]);
                if tracing {
                    trace::record_at(
                        vns($t),
                        TraceKind::Enqueue,
                        OBS_CONTROL_WORKER,
                        g as u64,
                        node_name_hash[g],
                        tag_hash[ti],
                    );
                }
                if items[g] == 0 {
                    remaining -= 1;
                    t_remaining[ti] -= 1;
                    if t_remaining[ti] == 0 {
                        t_finish[ti] = $t;
                    }
                    if tracing {
                        // inline completion, before any dependent's
                        // Enqueue (mirrors `record_done` ordering)
                        trace::record_at(
                            vns($t),
                            TraceKind::NodeComplete,
                            OBS_CONTROL_WORKER,
                            g as u64,
                            node_name_hash[g],
                            tag_hash[ti],
                        );
                    }
                    for &d in &orders[ti].dependents[li] {
                        let dg = base[ti] + d;
                        pending[dg] -= 1;
                        if pending[dg] == 0 {
                            worklist.push(dg);
                        }
                    }
                } else {
                    active.push(ActiveJob {
                        node: g,
                        tenant: ti,
                        pool: node_pool[g],
                        seq: next_seq,
                        served_at: tenants[ti].arrival,
                        sim: JobSim::new(
                            &pools.pool(node_pool[g]).topo,
                            &configs[g],
                            &node_ref[g].workload,
                            costs,
                        ),
                    });
                    next_seq += 1;
                    went_live = true;
                }
            }
            went_live
        }};
    }

    let mut heap: BinaryHeap<Ev> = (0..nw).map(|w| Ev { t: 0.0, w }).collect();

    while let Some(Ev { t, w }) = heap.pop() {
        // Release every tenant whose arrival has passed; their roots
        // activate at the arrival time (work begins when a worker
        // frees, exactly as the executor's run queue would).
        while next_arrival < arrivals.len()
            && tenants[arrivals[next_arrival]].arrival <= t
        {
            let ti = arrivals[next_arrival];
            next_arrival += 1;
            released[ti] = true;
            // the admission check the real serving loop runs before
            // submitting: backlog = admitted same-tag tenants still
            // in flight at this virtual instant
            if let Some(adm) = admission {
                if tenants[ti].tag == adm.tag {
                    let backlog = (0..nt)
                        .filter(|&o| {
                            o != ti
                                && released[o]
                                && decisions[o]
                                && t_remaining[o] > 0
                                && tenants[o].tag == adm.tag
                        })
                        .count();
                    let est_wait = backlog as f64 * adm.est_cost;
                    if !adm.policy.admits(backlog, est_wait) {
                        // shed: nothing activates; the tenant is
                        // terminal at its own arrival
                        decisions[ti] = false;
                        remaining -= t_remaining[ti];
                        t_remaining[ti] = 0;
                        t_finish[ti] = tenants[ti].arrival;
                        if tracing {
                            trace::record_at(
                                vns(tenants[ti].arrival),
                                TraceKind::Shed,
                                OBS_CONTROL_WORKER,
                                NO_JOB,
                                tenant_name_hash[ti],
                                tag_hash[ti],
                            );
                        }
                        continue;
                    }
                }
            }
            if tracing && admission.is_some() {
                // with admission in play every arrival models a
                // `try_submit_graph` call, so accepts record Admit
                // (NO_JOB, like the real control-side events, so
                // sampled mode never drops an admission decision)
                trace::record_at(
                    vns(tenants[ti].arrival),
                    TraceKind::Admit,
                    OBS_CONTROL_WORKER,
                    NO_JOB,
                    tenant_name_hash[ti],
                    tag_hash[ti],
                );
            }
            let roots: Vec<usize> = (0..tenants[ti].shape.nodes.len())
                .filter(|&li| pending[base[ti] + li] == 0)
                .map(|li| base[ti] + li)
                .collect();
            if activate!(roots, tenants[ti].arrival) {
                for (w2, slot) in parked.iter_mut().enumerate() {
                    if let Some(p) = slot.take() {
                        heap.push(Ev { t: p.max(t), w: w2 });
                    }
                }
            }
        }

        let mut now = t;
        let my_pool = pools.pool_of(w);
        let lw = pools.local_of(w);

        // retire the chunk this event marks the end of
        if let Some((g, len)) = chunk[w].take() {
            executed[g] += len;
            if tracing {
                trace::record_at(
                    vns(t),
                    TraceKind::TaskEnd,
                    w,
                    g as u64,
                    node_name_hash[g],
                    tag_hash[node_tenant[g]],
                );
            }
            if executed[g] == items[g] {
                let ti = node_tenant[g];
                remaining -= 1;
                t_remaining[ti] -= 1;
                if t_remaining[ti] == 0 {
                    t_finish[ti] = t;
                }
                let pos = active
                    .iter()
                    .position(|a| a.node == g)
                    .expect("completed node was active");
                active.remove(pos);
                if tracing {
                    // before dependents release (`record_done` order)
                    trace::record_at(
                        vns(t),
                        TraceKind::NodeComplete,
                        OBS_CONTROL_WORKER,
                        g as u64,
                        node_name_hash[g],
                        tag_hash[ti],
                    );
                }
                let mut ready = Vec::new();
                for &d in &orders[ti].dependents[node_local[g]] {
                    let dg = base[ti] + d;
                    pending[dg] -= 1;
                    if pending[dg] == 0 {
                        ready.push(dg);
                    }
                }
                if activate!(ready, t) {
                    for (w2, slot) in parked.iter_mut().enumerate() {
                        if let Some(p) = slot.take() {
                            heap.push(Ev { t: p.max(t), w: w2 });
                        }
                    }
                }
            }
        }

        if remaining == 0 {
            makespan = makespan.max(now);
            continue; // workload done; drain remaining worker events
        }

        // policy-ordered scan of this pool's active jobs — the mirror
        // of the executor's `pick_job` comparator
        let order = scan_order(&active, tenants, &executed, my_pool, now, policy);
        let mut got: Option<(usize, crate::sched::queue::Pull)> = None;
        for k in order {
            let my_topo = &pools.pool(active[k].pool).topo;
            let aj = &mut active[k];
            if let Some(pull) = aj.sim.try_acquire(my_topo, lw, &mut now) {
                got = Some((k, pull));
                break;
            }
        }
        match got {
            Some((k, pull)) => {
                let my_topo = &pools.pool(active[k].pool).topo;
                let aj = &mut active[k];
                // reset the job's priority-aging clock: served now
                aj.served_at = now;
                if t_started[aj.tenant].is_none() {
                    t_started[aj.tenant] = Some(now);
                }
                if tracing {
                    let g = aj.node;
                    if !node_started[g] {
                        node_started[g] = true;
                        trace::record_at(
                            vns(now),
                            TraceKind::Dispatch,
                            w,
                            g as u64,
                            node_name_hash[g],
                            tag_hash[aj.tenant],
                        );
                    }
                    if pull.stolen {
                        trace::record_at(
                            vns(now),
                            TraceKind::Steal,
                            w,
                            g as u64,
                            node_name_hash[g],
                            tag_hash[aj.tenant],
                        );
                    }
                    trace::record_at(
                        vns(now),
                        TraceKind::TaskStart,
                        w,
                        g as u64,
                        node_name_hash[g],
                        tag_hash[aj.tenant],
                    );
                }
                let exec = aj.sim.exec_time(my_topo, lw, &pull);
                chunk[w] = Some((aj.node, pull.task.len()));
                heap.push(Ev { t: now + exec, w });
            }
            None if next_arrival < arrivals.len() => {
                // nothing runnable yet, but tenants are still due:
                // come back at the next arrival
                makespan = makespan.max(now);
                let ta = tenants[arrivals[next_arrival]].arrival;
                heap.push(Ev { t: ta.max(now), w });
            }
            None => {
                // park until the next activation
                makespan = makespan.max(now);
                parked[w] = Some(now);
            }
        }
    }

    let makespan = t_finish.iter().copied().fold(makespan, f64::max);
    let outcome = TenancySimOutcome {
        policy,
        tenants: tenants
            .iter()
            .enumerate()
            .map(|(ti, t)| TenantOutcome {
                name: t.name.clone(),
                tag: t.tag.clone(),
                arrival: t.arrival,
                started: t_started[ti].unwrap_or(t_finish[ti]),
                finish: t_finish[ti],
                isolated: isolated[ti],
            })
            .collect(),
        makespan,
    };
    Ok((outcome, decisions))
}

/// Policy-ordered indices into `active` for a worker of `my_pool` —
/// the DES twin of the executor's `pick_job`: FIFO by activation seq,
/// priority with one level of virtual-time aging per
/// [`AGING_QUANTUM_SECS`] *waited since last service* (the mirror of
/// `Job::served_ns` — an actively-served job never out-ages a late
/// high-priority arrival), weighted fair by executed-items-per-weight
/// over tags. Ties always break towards the older activation. Runs
/// once per worker event, so the sort keys are computed once per job
/// (not inside the comparator).
fn scan_order(
    active: &[ActiveJob<'_>],
    tenants: &[TenantSpec],
    executed: &[usize],
    my_pool: usize,
    now: f64,
    policy: TenancyPolicy,
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..active.len())
        .filter(|&k| active[k].pool == my_pool)
        .collect();
    match policy {
        TenancyPolicy::Fifo => {
            idx.sort_by_key(|&k| active[k].seq);
        }
        TenancyPolicy::Priority => {
            // one cached (effective priority) key per pool job; aging
            // counts only the time waited since the job's last service
            let mut keyed: Vec<(usize, i64)> = idx
                .iter()
                .map(|&k| {
                    let t = &tenants[active[k].tenant];
                    let aged = ((now - active[k].served_at).max(0.0)
                        / AGING_QUANTUM_SECS)
                        as i64;
                    (k, t.priority.saturating_add(aged))
                })
                .collect();
            keyed.sort_by(|a, b| {
                b.1.cmp(&a.1)
                    .then_with(|| active[a.0].seq.cmp(&active[b.0].seq))
            });
            idx = keyed.into_iter().map(|(k, _)| k).collect();
        }
        TenancyPolicy::Fair => {
            // per-tag (items, weight) aggregates over this pool's jobs,
            // one pass; then one cached key per pool job
            let mut tags: Vec<(&str, u64, u64)> = Vec::new();
            for &k in &idx {
                let t = &tenants[active[k].tenant];
                let items = executed[active[k].node] as u64;
                match tags.iter_mut().find(|(tag, _, _)| *tag == t.tag) {
                    Some(entry) => {
                        entry.1 += items;
                        entry.2 = entry.2.max(t.weight);
                    }
                    None => tags.push((&t.tag, items, t.weight)),
                }
            }
            let mut keyed: Vec<(usize, f64)> = idx
                .iter()
                .map(|&k| {
                    let tag = &tenants[active[k].tenant].tag;
                    let (_, items, weight) = tags
                        .iter()
                        .find(|(t, _, _)| *t == *tag)
                        .expect("every pool job's tag was aggregated");
                    (k, *items as f64 / (*weight).max(1) as f64)
                })
                .collect();
            keyed.sort_by(|a, b| {
                a.1.total_cmp(&b.1)
                    .then_with(|| active[a.0].seq.cmp(&active[b.0].seq))
            });
            idx = keyed.into_iter().map(|(k, _)| k).collect();
        }
    }
    idx
}

/// Sort node indices by descending finish time — the refinement order
/// graph autotuning sweeps (latest finishers first). Stable, so ties
/// keep shape order.
pub(crate) fn by_finish_desc(outcome: &GraphSimOutcome) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..outcome.nodes.len()).collect();
    idx.sort_by(|&a, &b| {
        outcome.nodes[b].finish.total_cmp(&outcome.nodes[a].finish)
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::partitioner::Scheme;
    use crate::sim::simulate;

    fn costs() -> CostModel {
        CostModel::recorded()
    }

    fn cfg() -> SchedConfig {
        SchedConfig::default()
    }

    #[test]
    fn builder_mirrors_nodespec_api() {
        let shape = GraphShape::new("g")
            .node(NodeModel::uniform("a", 100, 1e-6))
            .node(
                NodeModel::uniform("b", 50, 1e-6)
                    .after("a")
                    .with_config(cfg().with_scheme(Scheme::Gss)),
            )
            .node(NodeModel::uniform("c", 10, 1e-6).after_all(["a", "b"]));
        assert_eq!(shape.len(), 3);
        assert_eq!(
            shape.node_names().collect::<Vec<_>>(),
            vec!["a", "b", "c"]
        );
        assert!((shape.total_cost() - 160e-6).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_shapes_with_executor_errors() {
        let topo = Topology::broadwell20();
        let cycle = GraphShape::new("cycle")
            .node(NodeModel::uniform("a", 10, 1e-6).after("b"))
            .node(NodeModel::uniform("b", 10, 1e-6).after("a"));
        assert!(matches!(
            replay(&cycle, &topo, &cfg(), &costs(), GraphMode::Dag),
            Err(GraphError::Cycle(_))
        ));
        // validate() agrees with replay without running anything
        assert!(matches!(cycle.validate(), Err(GraphError::Cycle(_))));
        assert!(GraphShape::unbalanced_diamond(4).validate().is_ok());

        let unknown = GraphShape::new("unknown")
            .node(NodeModel::uniform("a", 10, 1e-6).after("ghost"));
        assert_eq!(
            replay(&unknown, &topo, &cfg(), &costs(), GraphMode::Barrier)
                .err(),
            Some(GraphError::UnknownDependency {
                node: "a".into(),
                dep: "ghost".into()
            })
        );

        let dup = GraphShape::new("dup")
            .node(NodeModel::uniform("a", 10, 1e-6))
            .node(NodeModel::uniform("a", 10, 1e-6));
        assert_eq!(
            replay(&dup, &topo, &cfg(), &costs(), GraphMode::Dag).err(),
            Some(GraphError::DuplicateNode("a".into()))
        );
    }

    #[test]
    fn barrier_replay_is_sum_of_single_job_sims() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::new("chain")
            .node(NodeModel::uniform("a", 20_000, 1e-7))
            .node(NodeModel::uniform("b", 5_000, 3e-7).after("a"))
            .node(NodeModel::uniform("c", 1_000, 1e-6).after("b"));
        let out =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Barrier)
                .unwrap();
        let expect: f64 = shape
            .nodes()
            .iter()
            .map(|n| simulate(&topo, &cfg(), &n.workload, &costs()).makespan())
            .sum();
        assert!((out.makespan() - expect).abs() < 1e-12);
        assert_eq!(out.critical_path, vec!["a", "b", "c"]);
        // node starts stack end-to-end
        assert_eq!(out.node("b").unwrap().start, out.node("a").unwrap().finish);
    }

    #[test]
    fn dag_chain_agrees_with_summed_sims_within_tolerance() {
        // A linear chain has no overlap to exploit: dag replay must
        // agree with the summed single-job makespans up to the tiny
        // worker-availability skew at node boundaries.
        let topo = Topology::cascadelake56();
        let shape = GraphShape::new("chain")
            .node(NodeModel::uniform("a", 30_000, 1e-7))
            .node(NodeModel::uniform("b", 30_000, 2e-7).after("a"))
            .node(NodeModel::uniform("c", 10_000, 1e-7).after("b"));
        let dag =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        let expect: f64 = shape
            .nodes()
            .iter()
            .map(|n| simulate(&topo, &cfg(), &n.workload, &costs()).makespan())
            .sum();
        let rel = (dag.makespan() - expect).abs() / expect;
        assert!(
            rel < 0.05,
            "dag chain {} vs summed sims {expect} (rel {rel})",
            dag.makespan()
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let topo = Topology::cascadelake56();
        let shape = GraphShape::unbalanced_diamond(28);
        let config = cfg()
            .with_scheme(Scheme::Gss)
            .with_seed(42);
        let a = replay(&shape, &topo, &config, &costs(), GraphMode::Dag)
            .unwrap();
        let b = replay(&shape, &topo, &config, &costs(), GraphMode::Dag)
            .unwrap();
        assert_eq!(a.makespan(), b.makespan());
        assert_eq!(a.total_steals(), b.total_steals());
        assert_eq!(a.critical_path, b.critical_path);
    }

    #[test]
    fn dag_overlaps_unbalanced_diamond_barrier_does_not() {
        // The acceptance shape: on the modelled 56-core machine the
        // branches are each 28 wide, so barrier mode strands half the
        // pool per branch while dag mode fills it.
        let topo = Topology::cascadelake56();
        let shape = GraphShape::unbalanced_diamond(28);
        let dag =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        let barrier =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Barrier)
                .unwrap();
        assert!(
            dag.makespan() < barrier.makespan(),
            "dag {} must beat barrier {}",
            dag.makespan(),
            barrier.makespan()
        );
        // the light branch rides entirely inside the heavy branch's span
        let light = dag.node("light").unwrap();
        let heavy = dag.node("heavy").unwrap();
        assert!(light.finish <= heavy.finish);
        assert!(light.start < heavy.finish, "branches overlapped");
        // and the critical path goes through the heavy branch
        assert!(dag
            .critical_path
            .contains(&"heavy".to_string()));
        assert!(!dag.critical_path.contains(&"light".to_string()));
    }

    #[test]
    fn every_item_executes_exactly_once_in_dag_mode() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::new("counts")
            .node(NodeModel::uniform("a", 7_001, 1e-7))
            .node(NodeModel::uniform("b", 3_003, 1e-7).after("a"))
            .node(NodeModel::uniform("c", 2_002, 1e-7).after("a"))
            .node(
                NodeModel::uniform("d", 555, 1e-7).after("b").after("c"),
            );
        let out =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        for node in &out.nodes {
            let want = shape
                .nodes()
                .iter()
                .find(|n| n.name == node.name)
                .unwrap()
                .workload
                .items();
            assert_eq!(node.outcome.report.total_items(), want, "{}", node.name);
        }
        assert!(out.serial_time() >= out.makespan());
    }

    #[test]
    fn zero_item_nodes_chain_through() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::new("zeros")
            .node(NodeModel::uniform("a", 0, 0.0))
            .node(NodeModel::uniform("b", 0, 0.0).after("a"))
            .node(NodeModel::uniform("c", 1_000, 1e-7).after("b"));
        let out =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        assert_eq!(out.node("a").unwrap().finish, 0.0);
        assert_eq!(out.node("c").unwrap().outcome.report.total_items(), 1_000);
        assert!(out.makespan() > 0.0);
        // both modes cost an empty node identically (zero span), so
        // empty synchronization-only nodes can't fake a dag-overlap win
        let barrier =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Barrier)
                .unwrap();
        assert_eq!(barrier.node("a").unwrap().outcome.report.makespan, 0.0);
        assert_eq!(barrier.node("b").unwrap().outcome.report.makespan, 0.0);
        assert_eq!(out.node("b").unwrap().outcome.report.makespan, 0.0);
    }

    #[test]
    fn empty_shape_replays_to_zero() {
        let topo = Topology::broadwell20();
        let out = replay(
            &GraphShape::new("empty"),
            &topo,
            &cfg(),
            &costs(),
            GraphMode::Dag,
        )
        .unwrap();
        assert!(out.nodes.is_empty());
        assert_eq!(out.makespan(), 0.0);
        assert!(out.critical_path.is_empty());
    }

    #[test]
    fn placed_nodes_replay_on_their_pools() {
        // Two independent equal-cost nodes: pinned to different pools
        // they overlap on disjoint modelled workers, and the GPU pool's
        // 4x speed factor shows up in the finish times.
        let topo = Topology::heterogeneous(
            "h",
            1,
            8,
            1.0,
            1.0,
            &[(DeviceClass::Gpu, 8, 4.0)],
        );
        let shape = GraphShape::new("pools")
            .node(
                NodeModel::uniform("cpu", 8_000, 1e-6)
                    .on(DeviceClass::Cpu),
            )
            .node(
                NodeModel::uniform("gpu", 8_000, 1e-6)
                    .on(DeviceClass::Gpu),
            );
        let out =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        let cpu = out.node("cpu").unwrap();
        let gpu = out.node("gpu").unwrap();
        assert_eq!(cpu.device, DeviceClass::Cpu);
        assert_eq!(gpu.device, DeviceClass::Gpu, "model honours the gpu pool");
        assert_eq!(cpu.start, 0.0);
        assert_eq!(gpu.start, 0.0, "pools overlap: both roots start at 0");
        // same item count, same per-item cost, same worker count — the
        // only difference is the pool speed factor
        let ratio = cpu.finish / gpu.finish;
        assert!(
            (3.0..5.0).contains(&ratio),
            "gpu pool should be ~4x faster, got {ratio}"
        );
        // true cross-pool overlap: the dag makespan is the slower pool,
        // not the sum
        assert!(out.makespan() < cpu.finish + gpu.finish);
        assert!((out.makespan() - cpu.finish.max(gpu.finish)).abs() < 1e-12);
    }

    #[test]
    fn unplaced_nodes_use_the_cpu_pool_on_hetero_machines() {
        let out = replay(
            &GraphShape::new("any")
                .node(NodeModel::uniform("n", 1_000, 1e-6)),
            &Topology::hetero56(),
            &cfg(),
            &costs(),
            GraphMode::Dag,
        )
        .unwrap();
        assert_eq!(out.node("n").unwrap().device, DeviceClass::Cpu);
        // per-worker stats cover exactly the CPU pool
        assert_eq!(
            out.node("n").unwrap().outcome.report.per_worker.len(),
            56
        );
    }

    #[test]
    fn absent_class_placement_is_the_executor_error() {
        let shape = GraphShape::new("bad").node(
            NodeModel::uniform("n", 10, 1e-6).on(DeviceClass::Gpu),
        );
        // CPU-only machine: no gpu pool to honour
        let err = replay(
            &shape,
            &Topology::broadwell20(),
            &cfg(),
            &costs(),
            GraphMode::Dag,
        )
        .unwrap_err();
        assert_eq!(
            err,
            GraphError::NoSuchPool {
                node: "n".into(),
                wanted: "class:gpu".into()
            }
        );
        // barrier mode rejects identically
        assert!(replay(
            &shape,
            &Topology::broadwell20(),
            &cfg(),
            &costs(),
            GraphMode::Barrier
        )
        .is_err());
    }

    #[test]
    fn barrier_mode_serializes_pools_too() {
        let topo = Topology::hetero20();
        let shape = GraphShape::new("pools")
            .node(NodeModel::uniform("cpu", 2_000, 1e-6).on(DeviceClass::Cpu))
            .node(NodeModel::uniform("gpu", 2_000, 1e-6).on(DeviceClass::Gpu));
        let barrier =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Barrier)
                .unwrap();
        let dag =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        // barrier: spans stack end-to-end even across pools
        let sum: f64 = barrier
            .nodes
            .iter()
            .map(|n| n.outcome.report.makespan)
            .sum();
        assert!((barrier.makespan() - sum).abs() < 1e-12);
        assert!(
            dag.makespan() < barrier.makespan(),
            "cross-pool overlap must beat the barrier: {} vs {}",
            dag.makespan(),
            barrier.makespan()
        );
    }

    /// One heavy batch tenant at t=0 plus short interactive tenants
    /// arriving in a burst just behind it — the scenario where FIFO
    /// starves the shorts and Fair/Priority should not. Per-item SS
    /// chunks on the atomic central queue keep the preemption quantum
    /// fine enough for the policies to act within a node.
    fn bursty_tenants(cores: usize) -> Vec<TenantSpec> {
        let heavy = GraphShape::new("batch")
            .node(NodeModel::uniform("p1", cores * 64, 1e-4))
            .node(NodeModel::uniform("p2", cores * 64, 1e-4).after("p1"));
        let mut out =
            vec![TenantSpec::new("batch", heavy, 0.0).tag("batch")];
        for i in 0..4usize {
            let shape = GraphShape::new("interactive")
                .node(NodeModel::uniform("q", cores * 4, 1e-4));
            out.push(
                TenantSpec::new(&format!("short{i}"), shape, 1e-3 * (i + 1) as f64)
                    .tag("interactive")
                    .priority(2)
                    .weight(4),
            );
        }
        out
    }

    fn fine_cfg() -> SchedConfig {
        SchedConfig::fine_grained()
    }

    #[test]
    fn single_tenant_fifo_matches_dag_replay() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::unbalanced_diamond(10);
        let dag =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        let tenants = vec![TenantSpec::new("only", shape, 0.0)];
        let out = replay_tenants(
            &tenants,
            &topo,
            &cfg(),
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        let rel = (out.makespan - dag.makespan()).abs() / dag.makespan();
        assert!(
            rel < 1e-9,
            "lone FIFO tenant {} vs dag replay {}",
            out.makespan,
            dag.makespan()
        );
        assert_eq!(out.tenants.len(), 1);
        assert!((out.tenants[0].slowdown() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fair_and_priority_beat_fifo_on_tail_slowdown() {
        let topo = Topology::symmetric("t8", 1, 8, 1.0, 1.0);
        let tenants = bursty_tenants(8);
        let run = |policy| {
            replay_tenants(&tenants, &topo, &fine_cfg(), &costs(), policy)
                .unwrap()
        };
        let fifo = run(TenancyPolicy::Fifo);
        let fair = run(TenancyPolicy::Fair);
        let prio = run(TenancyPolicy::Priority);
        assert!(
            fair.p99_slowdown() < fifo.p99_slowdown() / 2.0,
            "fair p99 {} vs fifo p99 {}",
            fair.p99_slowdown(),
            fifo.p99_slowdown()
        );
        assert!(
            prio.p99_slowdown() < fifo.p99_slowdown() / 2.0,
            "priority p99 {} vs fifo p99 {}",
            prio.p99_slowdown(),
            fifo.p99_slowdown()
        );
        // the interactive tenants are the ones FIFO starves
        let short_latency = |o: &TenancySimOutcome| {
            o.tenant("short0").unwrap().latency()
        };
        assert!(short_latency(&prio) < short_latency(&fifo));
        assert!(short_latency(&fair) < short_latency(&fifo));
        // fair's whole point: slowdowns spread more evenly
        assert!(
            fair.fairness() > fifo.fairness(),
            "fair index {} vs fifo index {}",
            fair.fairness(),
            fifo.fairness()
        );
        // every policy is work-conserving: same total work, so the
        // batch tenant still finishes (makespans in the same ballpark)
        assert!(fair.makespan < fifo.makespan * 1.5);
        assert!(prio.makespan < fifo.makespan * 1.5);
    }

    #[test]
    fn tenant_replay_deterministic_per_seed() {
        let topo = Topology::symmetric("t8", 1, 8, 1.0, 1.0);
        let tenants = bursty_tenants(8);
        for policy in TenancyPolicy::ALL {
            let a = replay_tenants(
                &tenants,
                &topo,
                &fine_cfg(),
                &costs(),
                policy,
            )
            .unwrap();
            let b = replay_tenants(
                &tenants,
                &topo,
                &fine_cfg(),
                &costs(),
                policy,
            )
            .unwrap();
            assert_eq!(a.makespan, b.makespan, "{policy:?}");
            for (x, y) in a.tenants.iter().zip(&b.tenants) {
                assert_eq!(x.finish, y.finish, "{policy:?}: {}", x.name);
            }
        }
    }

    #[test]
    fn arrivals_bound_start_and_zero_cost_tenants_are_instant() {
        let topo = Topology::broadwell20();
        let tenants = vec![
            TenantSpec::new(
                "first",
                GraphShape::new("a")
                    .node(NodeModel::uniform("n", 1_000, 1e-6)),
                0.0,
            ),
            TenantSpec::new(
                "late-empty",
                GraphShape::new("b").node(NodeModel::uniform("n", 0, 0.0)),
                0.5,
            ),
        ];
        let out = replay_tenants(
            &tenants,
            &topo,
            &cfg(),
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        let late = out.tenant("late-empty").unwrap();
        assert_eq!(late.finish, 0.5, "zero-item graph completes on arrival");
        assert_eq!(late.latency(), 0.0);
        assert_eq!(late.slowdown(), 1.0);
        assert!(out.makespan >= 0.5);
        assert!(out.tenant("first").unwrap().finish < 0.5);
        // zero-item graphs are never dispatched: started = finish, so
        // the whole (zero) latency is service-free
        assert_eq!(late.queueing_delay(), 0.0);
        assert_eq!(late.service_time(), 0.0);
    }

    #[test]
    fn fifo_queueing_delay_separates_from_service_time() {
        // Single-core machine, FIFO: a short tenant arriving behind a
        // long batch waits for the batch to drain — its latency must
        // decompose into a queueing delay ~ the batch remainder plus a
        // service time ~ its isolated makespan.
        let topo = Topology::symmetric("t1", 1, 1, 1.0, 1.0);
        let tenants = vec![
            TenantSpec::new(
                "batch",
                GraphShape::new("a")
                    .node(NodeModel::uniform("n", 1_000, 1e-4)),
                0.0,
            )
            .tag("batch"),
            TenantSpec::new(
                "short",
                GraphShape::new("b").node(NodeModel::uniform("n", 10, 1e-4)),
                1e-3,
            )
            .tag("short"),
        ];
        let out = replay_tenants(
            &tenants,
            &topo,
            &cfg(),
            &costs(),
            TenancyPolicy::Fifo,
        )
        .unwrap();
        let short = out.tenant("short").unwrap();
        assert!(
            (short.queueing_delay() + short.service_time() - short.latency())
                .abs()
                < 1e-12,
            "latency must decompose exactly"
        );
        // the batch holds the single core for ~0.1s; the short tenant's
        // wait dominates its ~1ms of own work
        assert!(
            short.queueing_delay() > 10.0 * short.service_time(),
            "qdelay {} vs service {}",
            short.queueing_delay(),
            short.service_time()
        );
        let batch = out.tenant("batch").unwrap();
        assert!(batch.queueing_delay() < 1e-3, "first tenant served at once");
    }

    #[test]
    fn tenant_replay_rejects_invalid_shapes_like_the_executor() {
        let topo = Topology::broadwell20();
        let bad = GraphShape::new("cycle")
            .node(NodeModel::uniform("a", 10, 1e-6).after("b"))
            .node(NodeModel::uniform("b", 10, 1e-6).after("a"));
        let tenants = vec![
            TenantSpec::new("ok", GraphShape::unbalanced_diamond(4), 0.0),
            TenantSpec::new("bad", bad, 0.1),
        ];
        assert!(matches!(
            replay_tenants(
                &tenants,
                &topo,
                &cfg(),
                &costs(),
                TenancyPolicy::Fair
            ),
            Err(GraphError::Cycle(_))
        ));
    }

    #[test]
    fn per_node_config_overrides_apply_in_replay() {
        let topo = Topology::broadwell20();
        let shape = GraphShape::new("cfg")
            .node(NodeModel::uniform("default", 1_000, 1e-7))
            .node(
                NodeModel::uniform("gss", 1_000, 1e-7)
                    .after("default")
                    .with_config(cfg().with_scheme(Scheme::Gss)),
            );
        let out =
            replay(&shape, &topo, &cfg(), &costs(), GraphMode::Dag).unwrap();
        assert_eq!(out.node("default").unwrap().outcome.report.scheme, "STATIC");
        assert_eq!(out.node("gss").unwrap().outcome.report.scheme, "GSS");
    }
}
