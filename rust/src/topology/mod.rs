//! Machine-topology model: sockets, NUMA domains, cores, device classes.
//!
//! The paper evaluates on a 2×10-core Intel Broadwell and a 2×28-core
//! Intel Cascade Lake. Neither is available here, so the topology is an
//! explicit model consumed by two executors that share all scheduler
//! code:
//!
//! - the real-thread worker pool ([`crate::sched::worker`]), which uses
//!   the topology for NUMA-aware victim selection and queue grouping;
//! - the discrete-event simulator ([`crate::sim`]), which additionally
//!   uses the per-domain latency factors to model remote-steal and
//!   remote-queue access costs.

/// Kind of compute device a worker fronts. The DAPHNE worker manager
/// also creates threads that launch kernels on accelerators; the
/// evaluation is CPU-only but the dimension is kept first-class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    Cpu,
    Gpu,
    Fpga,
}

/// One hardware thread (one DaphneSched worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorePlace {
    /// Global worker/core id, dense in `0..n_cores`.
    pub core: usize,
    /// Socket == NUMA domain on both evaluated machines.
    pub socket: usize,
    pub device: DeviceClass,
}

/// A machine: cores grouped into sockets/NUMA domains plus the latency
/// factors the simulator uses.
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub places: Vec<CorePlace>,
    pub sockets: usize,
    /// Relative cost multiplier for touching memory/queues on a remote
    /// NUMA domain (≈2x on the evaluated Xeons).
    pub remote_numa_factor: f64,
    /// Single-core relative speed vs the Broadwell baseline.
    pub core_speed: f64,
}

impl Topology {
    /// Build a symmetric multi-socket CPU topology.
    pub fn symmetric(
        name: &str,
        sockets: usize,
        cores_per_socket: usize,
        remote_numa_factor: f64,
        core_speed: f64,
    ) -> Self {
        let places = (0..sockets * cores_per_socket)
            .map(|core| CorePlace {
                core,
                socket: core / cores_per_socket,
                device: DeviceClass::Cpu,
            })
            .collect();
        Topology {
            name: name.to_string(),
            places,
            sockets,
            remote_numa_factor,
            core_speed,
        }
    }

    /// The paper's 2×10-core Intel E5-2640 v4 (Broadwell), 64 GB.
    pub fn broadwell20() -> Self {
        Topology::symmetric("broadwell20", 2, 10, 1.9, 1.0)
    }

    /// The paper's 2×28-core Intel Xeon Gold 6258R (Cascade Lake), 1.5 TB.
    pub fn cascadelake56() -> Self {
        Topology::symmetric("cascadelake56", 2, 28, 2.1, 1.15)
    }

    /// A topology matching the current host (single NUMA domain assumed;
    /// used by the real-thread executor for tests/examples). Detection
    /// runs once per process; see [`Topology::host_shared`] for the
    /// allocation-free handle.
    pub fn host() -> Self {
        (*Self::host_shared()).clone()
    }

    /// Shared handle to the host topology: detected once, then shared
    /// via `Arc` (the persistent executor and `Vee::host_default` clone
    /// the `Arc`, not the topology).
    pub fn host_shared() -> std::sync::Arc<Self> {
        static HOST: std::sync::OnceLock<std::sync::Arc<Topology>> =
            std::sync::OnceLock::new();
        std::sync::Arc::clone(HOST.get_or_init(|| {
            let n = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            std::sync::Arc::new(Topology::symmetric("host", 1, n, 1.0, 1.0))
        }))
    }

    /// Resolve a preset by name (CLI / config).
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "broadwell20" | "broadwell" => Some(Self::broadwell20()),
            "cascadelake56" | "cascadelake" => Some(Self::cascadelake56()),
            "host" => Some(Self::host()),
            _ => None,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.places.len()
    }

    pub fn cores_per_socket(&self) -> usize {
        self.places.len() / self.sockets.max(1)
    }

    /// NUMA domain of a core.
    pub fn socket_of(&self, core: usize) -> usize {
        self.places[core].socket
    }

    /// Whether two cores share a NUMA domain.
    pub fn same_domain(&self, a: usize, b: usize) -> bool {
        self.socket_of(a) == self.socket_of(b)
    }

    /// Cores in the given NUMA domain.
    pub fn domain_cores(&self, socket: usize) -> Vec<usize> {
        self.places
            .iter()
            .filter(|p| p.socket == socket)
            .map(|p| p.core)
            .collect()
    }

    /// Relative cost factor for core `from` accessing memory homed on
    /// `to`'s domain.
    pub fn access_factor(&self, from: usize, to: usize) -> f64 {
        if self.same_domain(from, to) {
            1.0
        } else {
            self.remote_numa_factor
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadwell_shape() {
        let t = Topology::broadwell20();
        assert_eq!(t.n_cores(), 20);
        assert_eq!(t.sockets, 2);
        assert_eq!(t.cores_per_socket(), 10);
        assert_eq!(t.socket_of(0), 0);
        assert_eq!(t.socket_of(9), 0);
        assert_eq!(t.socket_of(10), 1);
        assert_eq!(t.socket_of(19), 1);
    }

    #[test]
    fn cascadelake_shape() {
        let t = Topology::cascadelake56();
        assert_eq!(t.n_cores(), 56);
        assert_eq!(t.cores_per_socket(), 28);
        assert_eq!(t.domain_cores(1).len(), 28);
        assert!(t.domain_cores(1).iter().all(|&c| c >= 28));
    }

    #[test]
    fn access_factors() {
        let t = Topology::broadwell20();
        assert_eq!(t.access_factor(0, 5), 1.0);
        assert_eq!(t.access_factor(0, 15), 1.9);
        assert!(t.same_domain(3, 7));
        assert!(!t.same_domain(3, 17));
    }

    #[test]
    fn presets_resolve() {
        assert!(Topology::preset("broadwell20").is_some());
        assert!(Topology::preset("cascadelake").is_some());
        assert!(Topology::preset("host").is_some());
        assert!(Topology::preset("riscv").is_none());
    }

    #[test]
    fn host_has_at_least_one_core() {
        assert!(Topology::host().n_cores() >= 1);
    }

    #[test]
    fn host_shared_detects_once() {
        let a = Topology::host_shared();
        let b = Topology::host_shared();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "host topology must be cached");
        assert_eq!(Topology::host().n_cores(), a.n_cores());
    }
}
