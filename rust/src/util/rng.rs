//! Deterministic PRNGs: SplitMix64 (seeding) + xoshiro256** (streams).
//!
//! Every stochastic component in the crate (graph generation, PSS
//! chunking, RND/RNDPRI victim selection, the DES cost models, the
//! property-test harness) draws from this generator so whole experiments
//! replay bit-identically from a seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per worker) from this rng.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.index(i + 1));
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.index(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert!((0..10).any(|_| a.next_u64() != b.next_u64()));
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert!((0..10).any(|_| a.next_u64() != b.next_u64()));
    }
}
