//! Cross-layer tests of the virtual-time graph-replay subsystem
//! (`sim::graph`) and graph-level autotuning (`sched::autotune`):
//! replay semantics vs the single-job DES, error parity with the real
//! executor's graph validation, the dag-vs-barrier acceptance shape on
//! the modelled 56-core machine, and the apps' exported shapes agreeing
//! with the pipelines they actually run.

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::sync::Arc;

use daphne_sched::apps::{cc, linreg};
use daphne_sched::bench::AppCosts;
use daphne_sched::config::{GraphMode, SchedConfig};
use daphne_sched::graph::{amazon_like, SnapGraph};
use daphne_sched::sched::autotune::{self, SearchSpace};
use daphne_sched::sched::graph::{GraphError, GraphSpec};
use daphne_sched::sched::{Executor, QueueLayout, Scheme, VictimStrategy};
use daphne_sched::sim::{self, CostModel, GraphShape, NodeModel};
use daphne_sched::topology::Topology;

fn costs() -> CostModel {
    CostModel::recorded()
}

fn default_cfg() -> SchedConfig {
    SchedConfig::default()
}

#[test]
fn replay_is_deterministic_per_seed() {
    let topo = Topology::cascadelake56();
    let shape = GraphShape::unbalanced_diamond(28);
    for mode in [GraphMode::Dag, GraphMode::Barrier] {
        let config = default_cfg().with_scheme(Scheme::Fac2).with_seed(77);
        let a = sim::replay(&shape, &topo, &config, &costs(), mode).unwrap();
        let b = sim::replay(&shape, &topo, &config, &costs(), mode).unwrap();
        assert_eq!(a.makespan(), b.makespan(), "{mode:?}");
        assert_eq!(a.total_steals(), b.total_steals(), "{mode:?}");
        for (x, y) in a.nodes.iter().zip(&b.nodes) {
            assert_eq!(x.start, y.start);
            assert_eq!(x.finish, y.finish);
        }
    }
}

#[test]
fn linear_chain_replay_matches_summed_single_job_sims() {
    // A chain offers no overlap: dag replay must agree with the sum of
    // independent single-job simulations up to the worker-availability
    // skew at node boundaries (tiny vs the chunk work).
    let topo = Topology::broadwell20();
    let shape = GraphShape::new("chain")
        .node(NodeModel::uniform("s1", 40_000, 1e-7))
        .node(NodeModel::uniform("s2", 20_000, 3e-7).after("s1"))
        .node(NodeModel::uniform("s3", 10_000, 5e-7).after("s2"));
    let summed: f64 = shape
        .nodes()
        .iter()
        .map(|n| {
            sim::simulate(&topo, &default_cfg(), &n.workload, &costs())
                .makespan()
        })
        .sum();
    let barrier =
        sim::replay(&shape, &topo, &default_cfg(), &costs(), GraphMode::Barrier)
            .unwrap();
    assert!(
        (barrier.makespan() - summed).abs() < 1e-12,
        "barrier replay is exactly the summed sims"
    );
    let dag =
        sim::replay(&shape, &topo, &default_cfg(), &costs(), GraphMode::Dag)
            .unwrap();
    let rel = (dag.makespan() - summed).abs() / summed;
    assert!(
        rel < 0.05,
        "dag chain {} vs summed {} (rel {rel})",
        dag.makespan(),
        summed
    );
}

#[test]
fn dag_beats_barrier_on_unbalanced_diamond_on_56_cores() {
    // Acceptance criterion: on the modelled 56-core machine the
    // unbalanced diamond's dag-mode makespan is below barrier mode.
    let topo = Topology::cascadelake56();
    let shape = GraphShape::unbalanced_diamond(28);
    let dag =
        sim::replay(&shape, &topo, &default_cfg(), &costs(), GraphMode::Dag)
            .unwrap();
    let barrier = sim::replay(
        &shape,
        &topo,
        &default_cfg(),
        &costs(),
        GraphMode::Barrier,
    )
    .unwrap();
    assert!(
        dag.makespan() < barrier.makespan(),
        "dag {} must beat barrier {}",
        dag.makespan(),
        barrier.makespan()
    );
    // the win is the light branch hiding inside the heavy one: roughly
    // the light branch's span, not a rounding artifact
    let light_span = barrier.node("light").unwrap().outcome.report.makespan;
    assert!(
        barrier.makespan() - dag.makespan() > 0.5 * light_span,
        "overlap win {} vs light span {light_span}",
        barrier.makespan() - dag.makespan()
    );
}

#[test]
fn replay_rejects_what_the_executor_rejects() {
    // The same invalid graph structures produce the same GraphError
    // from the virtual-time replay and the real executor submission.
    let topo = Topology::symmetric("t", 1, 2, 1.0, 1.0);
    let exec = Executor::new(
        Arc::new(topo.clone()),
        Arc::new(SchedConfig::default()),
    );

    // cycle
    let shape = GraphShape::new("cycle")
        .node(NodeModel::uniform("a", 10, 1e-7).after("b"))
        .node(NodeModel::uniform("b", 10, 1e-7).after("a"));
    let sim_err =
        sim::replay(&shape, &topo, &default_cfg(), &costs(), GraphMode::Dag)
            .unwrap_err();
    let spec = GraphSpec::new("cycle")
        .node(
            daphne_sched::sched::NodeSpec::new("a", 10).after("b"),
            |_w, _r| {},
        )
        .node(
            daphne_sched::sched::NodeSpec::new("b", 10).after("a"),
            |_w, _r| {},
        );
    let exec_err = exec.submit_graph(spec).err().unwrap();
    match (&sim_err, &exec_err) {
        (GraphError::Cycle(a), GraphError::Cycle(b)) => assert_eq!(a, b),
        other => panic!("expected matching cycle errors, got {other:?}"),
    }

    // unknown dependency
    let shape = GraphShape::new("unknown")
        .node(NodeModel::uniform("a", 10, 1e-7).after("ghost"));
    let sim_err =
        sim::replay(&shape, &topo, &default_cfg(), &costs(), GraphMode::Dag)
            .unwrap_err();
    let spec = GraphSpec::new("unknown").node(
        daphne_sched::sched::NodeSpec::new("a", 10).after("ghost"),
        |_w, _r| {},
    );
    assert_eq!(sim_err, exec.submit_graph(spec).err().unwrap());

    // duplicate node name
    let shape = GraphShape::new("dup")
        .node(NodeModel::uniform("a", 10, 1e-7))
        .node(NodeModel::uniform("a", 10, 1e-7));
    let sim_err = sim::replay(
        &shape,
        &topo,
        &default_cfg(),
        &costs(),
        GraphMode::Barrier,
    )
    .unwrap_err();
    let spec = GraphSpec::new("dup")
        .node(daphne_sched::sched::NodeSpec::new("a", 10), |_w, _r| {})
        .node(daphne_sched::sched::NodeSpec::new("a", 10), |_w, _r| {});
    assert_eq!(sim_err, exec.submit_graph(spec).err().unwrap());
}

#[test]
fn graph_autotune_beats_or_matches_best_uniform_on_56_cores() {
    // Acceptance criterion: graph-level autotune's per-node configs
    // replay at a makespan <= the best single uniform config from the
    // sweep on the modelled 56-core machine.
    let topo = Topology::cascadelake56();
    let shape = GraphShape::unbalanced_diamond(28);
    let space = SearchSpace {
        schemes: vec![Scheme::Static, Scheme::Gss, Scheme::Mfsc, Scheme::Fac2],
        layouts: vec![
            QueueLayout::Centralized { atomic: false },
            QueueLayout::Centralized { atomic: true },
            QueueLayout::PerCore,
        ],
        victims: vec![VictimStrategy::Seq, VictimStrategy::SeqPri],
        placements: Vec::new(),
    };
    let tuning =
        autotune::tune_graph(&shape, &topo, &costs(), &space, 3, 1).unwrap();
    assert!(
        tuning.predicted <= tuning.uniform.predicted + 1e-12,
        "per-node {} vs best uniform {}",
        tuning.predicted,
        tuning.uniform.predicted
    );
    // and the assignment's replayed makespan truly is the prediction
    let configs: Vec<SchedConfig> = tuning
        .per_node
        .iter()
        .map(|c| c.config.clone())
        .collect();
    let replayed = daphne_sched::sim::graph::replay_with_configs(
        &shape,
        &topo,
        &configs,
        &costs(),
        GraphMode::Dag,
    )
    .unwrap()
    .makespan();
    assert!((replayed - tuning.predicted).abs() / tuning.predicted < 1e-9);
}

#[test]
fn app_shapes_mirror_their_executed_pipelines() {
    // linreg: the exported shape has exactly the stage names the real
    // pipeline reports, and its replay overlaps the two reductions.
    let app = AppCosts::recorded();
    let shape = linreg::graph_shape(50_000, app.lr_per_row);
    let spec = linreg::LinregSpec {
        rows: 500,
        cols: 5,
        lambda: 1e-3,
        seed: 3,
    };
    let (x, y) = linreg::generate(&spec);
    let topo = Topology::symmetric("t", 1, 2, 1.0, 1.0);
    let result =
        linreg::run_native(&x, &y, 1e-3, &topo, &SchedConfig::default())
            .unwrap();
    let ran: Vec<&str> =
        result.report.stages.iter().map(|(n, _)| n.as_str()).collect();
    let modelled: Vec<&str> = shape.node_names().collect();
    assert_eq!(ran, modelled, "shape models the executed pipeline");

    // cc: the iteration shape replays on the big modelled machine with
    // the dag mode no slower than barrier (chain: equal up to skew)
    let g = amazon_like(&SnapGraph::small(5_000, 7)).symmetrize();
    let cc_shape = cc::iteration_shape(&g, app.cc_per_row, app.cc_per_nnz);
    let machine = Topology::cascadelake56();
    let dag = sim::replay(
        &cc_shape,
        &machine,
        &default_cfg(),
        &costs(),
        GraphMode::Dag,
    )
    .unwrap();
    let barrier = sim::replay(
        &cc_shape,
        &machine,
        &default_cfg(),
        &costs(),
        GraphMode::Barrier,
    )
    .unwrap();
    assert!(dag.makespan() <= barrier.makespan() * 1.05);
    assert_eq!(
        dag.node("propagate").unwrap().outcome.report.total_items(),
        g.rows
    );
}
