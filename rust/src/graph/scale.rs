//! The paper's scale-up scheme: replicating the source graph K times
//! ("A scale-up factor of 50 was applied to the source data set,
//! resulting in an input matrix with 20,169,700 nodes and 244,340,800
//! two-directional edges").
//!
//! 403,394 × 50 = 20,169,700 nodes and 2 × 50 × ~2.44M... the paper's
//! two-directional count implies block replication of the symmetrized
//! pattern along the diagonal: K disjoint copies. Disjoint copies keep
//! the per-row nnz distribution identical — exactly what matters for
//! task-cost skew — while multiplying the row count (task count) by K.

use crate::matrix::CsrMatrix;

/// Replicate `g` as `k` diagonal blocks (disjoint copies).
pub fn scale_up(g: &CsrMatrix, k: usize) -> CsrMatrix {
    assert!(k >= 1);
    let n = g.rows;
    let mut indptr = Vec::with_capacity(n * k + 1);
    let mut indices = Vec::with_capacity(g.nnz() * k);
    indptr.push(0usize);
    for copy in 0..k {
        let off = (copy * n) as u32;
        for r in 0..n {
            for &c in g.row(r) {
                indices.push(c + off);
            }
            indptr.push(indices.len());
        }
    }
    CsrMatrix { rows: n * k, cols: g.cols * k, indptr, indices, vals: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{amazon_like, SnapGraph};

    #[test]
    fn scale_one_is_identity() {
        let g = amazon_like(&SnapGraph::small(200, 1));
        let s = scale_up(&g, 1);
        assert_eq!(g.rows, s.rows);
        assert_eq!(g.indices, s.indices);
    }

    #[test]
    fn scale_multiplies_counts() {
        let g = amazon_like(&SnapGraph::small(300, 2));
        let s = scale_up(&g, 5);
        assert_eq!(s.rows, 1500);
        assert_eq!(s.nnz(), 5 * g.nnz());
    }

    #[test]
    fn copies_are_disjoint_blocks() {
        let g = amazon_like(&SnapGraph::small(100, 3));
        let s = scale_up(&g, 3);
        for copy in 0..3u32 {
            for r in 0..100usize {
                let sr = s.row(copy as usize * 100 + r);
                let gr = g.row(r);
                assert_eq!(sr.len(), gr.len());
                for (a, b) in sr.iter().zip(gr) {
                    assert_eq!(*a, b + copy * 100);
                }
            }
        }
    }

    #[test]
    fn row_cost_distribution_preserved() {
        let g = amazon_like(&SnapGraph::small(400, 4));
        let s = scale_up(&g, 4);
        let gc = g.row_costs();
        let sc = s.row_costs();
        assert_eq!(&sc[..400], &gc[..]);
        assert_eq!(&sc[1200..], &gc[..]);
    }
}
