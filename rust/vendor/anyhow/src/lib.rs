//! Vendored std-only subset of the `anyhow` API.
//!
//! Provides exactly what this repository uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics mirror the real crate where it matters:
//!
//! - `Display` shows the outermost message; the alternate form (`{:#}`)
//!   appends the source chain as `outer: cause: root`.
//! - `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`] (and `Error` itself deliberately does **not** implement
//!   `std::error::Error`, exactly like upstream, so the blanket `From`
//!   does not conflict).

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    Msg(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
    Context { msg: String, inner: Box<Error> },
}

/// A dynamic error with an optional chain of causes.
pub struct Error(Repr);

impl Error {
    /// Error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Repr::Msg(message.to_string()))
    }

    /// Wrap a concrete error (kept as the chain's root cause).
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error(Repr::Boxed(Box::new(error)))
    }

    /// Attach a higher-level message in front of this error.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error(Repr::Context { msg: context.to_string(), inner: Box::new(self) })
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<String> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            match &e.0 {
                Repr::Msg(m) => {
                    out.push(m.clone());
                    cur = None;
                }
                Repr::Boxed(b) => {
                    out.push(b.to_string());
                    let mut src = b.source();
                    while let Some(s) = src {
                        out.push(s.to_string());
                        src = s.source();
                    }
                    cur = None;
                }
                Repr::Context { msg, inner } => {
                    out.push(msg.clone());
                    cur = Some(inner);
                }
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain();
        write!(f, "{}", chain.first().map(String::as_str).unwrap_or(""))?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e:#}"), "gone");
    }

    #[test]
    fn macros_work() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "x too small: 0");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let from_string = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }

    #[test]
    fn debug_shows_causes() {
        let e = Error::new(io_err()).context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by"));
    }
}
