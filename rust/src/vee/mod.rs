//! Vectorized execution engine (VEE): the DAPHNE runtime component that
//! turns (data, operator) into tasks and executes pipelines under a
//! scheduling configuration (Fig. 2).
//!
//! A `Vee` fronts a **persistent** [`Executor`]: worker threads are
//! spawned once when the engine is created and parked between operators
//! — every [`Vee::execute`] call submits a job to the resident pool
//! instead of respawning OS threads per stage (the seed behaviour). A
//! pipeline is a set of [`Stage`]s connected by dependency edges; in
//! the default `graph=dag` mode it is submitted as one task graph
//! ([`Executor::run_graph`]) so independent stages overlap, while
//! `graph=barrier` ([`GraphMode::Barrier`]) serializes the stages with
//! a full barrier between them for A/B comparison. Per-stage
//! [`SchedReport`]s feed the evaluation harness either way.
//!
//! Cloning a `Vee` is cheap and **shares** the pool (`Arc`), and
//! [`Vee::with_config`] derives an engine with different scheduling on
//! the *same* workers — which is how one resident pool serves STATIC and
//! GSS pipelines back-to-back or concurrently.

pub mod pipeline;

pub use pipeline::{report_from_graph, Pipeline, PipelineReport, Stage};

use std::sync::{Arc, OnceLock};

use crate::config::{ExecutorMode, GraphMode, SchedConfig};
use crate::sched::executor::{Executor, JobSpec};
use crate::sched::{SchedReport, Session, TaskRange, TenancyPolicy};
use crate::topology::Topology;

/// The engine: topology + default scheduling configuration + resident
/// executor.
#[derive(Debug, Clone)]
pub struct Vee {
    pub topo: Arc<Topology>,
    pub sched: Arc<SchedConfig>,
    /// `None` in [`ExecutorMode::Oneshot`] — threads spawn per operator
    /// (the legacy behaviour, kept for A/B comparison).
    executor: Option<Arc<Executor>>,
    /// How pipelines are dispatched (`graph=barrier|dag`).
    graph_mode: GraphMode,
}

impl Vee {
    /// Engine with a persistent worker pool (spawned here, once).
    pub fn new(topo: Topology, sched: SchedConfig) -> Self {
        Vee::with_mode(Arc::new(topo), Arc::new(sched), ExecutorMode::Persistent)
    }

    /// Engine with an explicit executor mode; `Arc` inputs are shared,
    /// not cloned.
    pub fn with_mode(
        topo: Arc<Topology>,
        sched: Arc<SchedConfig>,
        mode: ExecutorMode,
    ) -> Self {
        let executor = match mode {
            ExecutorMode::Persistent => Some(Arc::new(Executor::new(
                Arc::clone(&topo),
                Arc::clone(&sched),
            ))),
            ExecutorMode::Oneshot => None,
        };
        Vee { topo, sched, executor, graph_mode: GraphMode::default() }
    }

    /// Derive an engine with a different pipeline dispatch mode (shares
    /// the pool; `graph=barrier` is the A/B baseline for figures).
    pub fn with_graph_mode(mut self, mode: GraphMode) -> Self {
        self.graph_mode = mode;
        self
    }

    /// How this engine *actually* dispatches pipelines: dag dispatch
    /// needs the resident executor, so a one-shot engine always reports
    /// (and uses) barrier mode regardless of what was configured.
    pub fn graph_mode(&self) -> GraphMode {
        if self.executor.is_some() {
            self.graph_mode
        } else {
            GraphMode::Barrier
        }
    }

    /// Engine on the host topology with default (STATIC) scheduling.
    ///
    /// The host topology is detected once and the engine (including its
    /// worker pool) is created once per process and shared — repeated
    /// calls clone `Arc`s instead of re-detecting the topology,
    /// re-cloning the config, or spawning further threads.
    pub fn host_default() -> Self {
        static HOST: OnceLock<Vee> = OnceLock::new();
        HOST.get_or_init(|| {
            Vee::with_mode(
                Topology::host_shared(),
                Arc::new(SchedConfig::default()),
                ExecutorMode::Persistent,
            )
        })
        .clone()
    }

    /// Derive an engine with a different scheduling configuration that
    /// **shares this engine's worker pool** (per-job config override).
    pub fn with_config(&self, sched: SchedConfig) -> Self {
        Vee {
            topo: Arc::clone(&self.topo),
            sched: Arc::new(sched),
            executor: self.executor.clone(),
            graph_mode: self.graph_mode,
        }
    }

    /// Set the resident pool's cross-job pick policy
    /// (`policy=fifo|fair|priority`) — how concurrent tenants share the
    /// workers. A no-op on a one-shot engine (each operator gets a
    /// fresh single-job pool, so there is nothing to arbitrate).
    ///
    /// Unlike [`Vee::with_graph_mode`] this is **not** a per-engine
    /// setting: the policy lives on the executor's run queue, so it
    /// applies to every engine sharing this pool (e.g.
    /// [`Vee::with_config`] clones — and, if called on
    /// [`Vee::host_default`], the process-wide shared engine) and to
    /// jobs those engines already have queued. Engines that want a
    /// private policy should own a private pool
    /// ([`Vee::new`]/[`Vee::with_mode`]), as the CLI does per run.
    pub fn with_tenancy_policy(self, policy: TenancyPolicy) -> Self {
        if let Some(exec) = &self.executor {
            exec.set_policy(policy);
        }
        self
    }

    /// A multi-tenant submission session on the resident pool (`None`
    /// in oneshot mode). This is how `jobs=<n>` submits its concurrent
    /// pipelines from one thread — see [`crate::apps::cc::run_concurrent`].
    pub fn session(&self) -> Option<Session<'_>> {
        self.executor.as_ref().map(|e| e.session())
    }

    /// The resident executor (`None` in oneshot mode). Useful for
    /// submitting jobs directly via the [`JobSpec`] API.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        self.executor.as_ref()
    }

    /// Execute one vectorized operator over `items` work items: submits
    /// a job tagged with this engine's config to the resident pool and
    /// waits for it.
    pub fn execute<F>(&self, items: usize, body: F) -> SchedReport
    where
        F: Fn(usize, TaskRange) + Send + Sync,
    {
        match &self.executor {
            Some(exec) => exec.run(
                JobSpec::new(items)
                    .with_shared_config(Arc::clone(&self.sched)),
                body,
            ),
            // Oneshot mode: spawn a throwaway executor for this one job
            // (construct pool → run → join, the seed's spawn-per-stage
            // semantics).
            None => Executor::new(
                Arc::clone(&self.topo),
                Arc::clone(&self.sched),
            )
            .run(JobSpec::new(items), body),
        }
    }

    /// Execute a pipeline under this engine's [`GraphMode`]: one task
    /// graph in `dag` mode (independent stages overlap), serial stages
    /// with full barriers in `barrier` mode. Stages reuse the resident
    /// pool — no threads are spawned per stage.
    pub fn run_pipeline(&self, pipeline: &Pipeline<'_>) -> PipelineReport {
        pipeline.run(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Scheme;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn execute_covers_items() {
        let vee = Vee::host_default();
        let count = AtomicUsize::new(0);
        let report = vee.execute(1234, |_w, r| {
            count.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1234);
        assert_eq!(report.total_items(), 1234);
    }

    #[test]
    fn host_default_shares_one_engine() {
        let a = Vee::host_default();
        let b = Vee::host_default();
        assert!(Arc::ptr_eq(&a.topo, &b.topo), "topology detected once");
        assert!(Arc::ptr_eq(&a.sched, &b.sched), "config shared, not recloned");
        let (ea, eb) = (a.executor().unwrap(), b.executor().unwrap());
        assert!(Arc::ptr_eq(ea, eb), "one resident pool shared");
    }

    #[test]
    fn with_config_shares_the_pool() {
        let base = Vee::new(
            Topology::symmetric("t", 1, 2, 1.0, 1.0),
            SchedConfig::default(),
        );
        let gss = base.with_config(SchedConfig::default().with_scheme(Scheme::Gss));
        assert!(Arc::ptr_eq(
            base.executor().unwrap(),
            gss.executor().unwrap()
        ));
        let r1 = base.execute(500, |_w, _r| {});
        let r2 = gss.execute(500, |_w, _r| {});
        assert_eq!(r1.scheme, "STATIC");
        assert_eq!(r2.scheme, "GSS");
        assert_eq!(base.executor().unwrap().jobs_completed(), 2);
    }

    #[test]
    fn with_config_preserves_graph_mode() {
        let base = Vee::new(
            Topology::symmetric("t", 1, 2, 1.0, 1.0),
            SchedConfig::default(),
        )
        .with_graph_mode(GraphMode::Barrier);
        assert_eq!(base.graph_mode(), GraphMode::Barrier);
        let derived = base.with_config(SchedConfig::default());
        assert_eq!(derived.graph_mode(), GraphMode::Barrier);
        assert_eq!(
            Vee::host_default().graph_mode(),
            GraphMode::Dag,
            "dag dispatch is the default"
        );
    }

    #[test]
    fn oneshot_mode_still_covers_items() {
        let vee = Vee::with_mode(
            Arc::new(Topology::symmetric("t", 1, 2, 1.0, 1.0)),
            Arc::new(SchedConfig::default()),
            ExecutorMode::Oneshot,
        );
        assert!(vee.executor().is_none());
        assert_eq!(
            vee.graph_mode(),
            GraphMode::Barrier,
            "a one-shot engine reports the mode it actually uses"
        );
        let count = AtomicUsize::new(0);
        let report = vee.execute(999, |_w, r| {
            count.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 999);
        assert_eq!(report.total_items(), 999);
    }
}
