//! Integration: elastic device pools end-to-end — lend/resize/reclaim
//! churn on the real executor never loses or double-executes work,
//! pool-pinned jobs never cross onto borrowed or foreign workers
//! mid-resize, and a scripted resize schedule produces the same
//! `Resize` event stream on a real `Session` and the DES mirror
//! (`sim::replay_steps`).

// Real-thread integration suites are too heavy (and too
// timing-dependent) for the interpreter; Miri covers the unit suites.
#![cfg(not(miri))]

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use daphne_sched::config::{SchedConfig, TraceMode};
use daphne_sched::obs::trace;
use daphne_sched::obs::TraceKind;
use daphne_sched::sched::{Executor, JobSpec, Placement, PoolId, SubmitOpts};
use daphne_sched::sim::{self, ElasticStep};
use daphne_sched::topology::{DeviceClass, Topology};

/// The suite touches process-global state (the trace gate, the metrics
/// gauges) and hammers the same small machine — serialize the tests.
static SEQ: Mutex<()> = Mutex::new(());

/// 2 CPU cores (pool 0: workers 0,1) + 2 GPU devices (pool 1: workers
/// 2,3) — the smallest topology where lending, parking and pinning are
/// all observable with real threads.
fn hetero4() -> Arc<Topology> {
    Arc::new(Topology::heterogeneous(
        "t-elastic",
        1,
        2,
        1.0,
        1.0,
        &[(DeviceClass::Gpu, 2, 2.0)],
    ))
}

fn executor() -> Executor {
    Executor::new(hetero4(), Arc::new(SchedConfig::default()))
}

/// ACCEPTANCE: across 100 lend/resize/reclaim cycles racing moldable
/// submissions and concurrent cancellation, no task is lost and no
/// task executes twice — per-item hit counts agree exactly with each
/// job's report, cancelled or not.
#[test]
fn resize_churn_never_loses_or_duplicates_work() {
    let _guard = SEQ.lock().unwrap();
    const JOBS: usize = 48;
    const ITEMS: usize = 4_000;
    let exec = executor();
    let hits: Arc<Vec<AtomicUsize>> = Arc::new(
        (0..JOBS * ITEMS).map(|_| AtomicUsize::new(0)).collect(),
    );
    std::thread::scope(|s| {
        let churn = s.spawn(|| {
            let session = exec.session();
            for cycle in 0..100 {
                session.lend(1, 0, 2);
                session.resize_pool(0, 1 + cycle % 2);
                session.reclaim(1);
                session.resize_pool(0, 2);
                std::thread::yield_now();
            }
        });
        let session = exec.session();
        let mut handles = Vec::new();
        for j in 0..JOBS {
            let hits = Arc::clone(&hits);
            let h = session.submit(
                JobSpec::new(ITEMS).named(&format!("mold{j}")),
                SubmitOpts::new().moldable(1, 4),
                move |_w, r| {
                    for i in r.start..r.end {
                        hits[j * ITEMS + i].fetch_add(1, Ordering::Relaxed);
                    }
                },
            );
            handles.push(h);
            if j % 4 == 3 {
                // stagger submissions so cancels land mid-flight
                std::thread::yield_now();
            }
        }
        for (j, h) in handles.iter().enumerate() {
            if j % 3 == 0 {
                h.cancel();
            }
        }
        for (j, h) in handles.into_iter().enumerate() {
            let report = h.wait();
            let row = &hits[j * ITEMS..(j + 1) * ITEMS];
            let executed: usize =
                row.iter().map(|c| c.load(Ordering::Relaxed)).sum();
            assert!(
                row.iter().all(|c| c.load(Ordering::Relaxed) <= 1),
                "job {j}: an item executed twice"
            );
            assert_eq!(
                executed,
                report.total_items(),
                "job {j}: counted items disagree with the report"
            );
            if j % 3 != 0 {
                assert_eq!(report.total_items(), ITEMS, "job {j} lost work");
            }
        }
        churn.join().unwrap();
    });
    // the final cycle reclaimed and re-widened: base assignment restored
    assert_eq!(exec.elastic().widths(), vec![2, 2]);
    assert_eq!(exec.elastic().lent_out(1), 0);
}

/// ACCEPTANCE: a pool-pinned job is never observed on a foreign pool's
/// worker mid-resize, and a pinned arrival on a lending pool snaps the
/// lease back before the job needs its workers.
#[test]
fn pinned_pool_jobs_never_run_on_borrowed_or_foreign_workers() {
    let _guard = SEQ.lock().unwrap();
    let exec = executor();
    let violation = Arc::new(AtomicBool::new(false));
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        let churn = s.spawn(|| {
            let session = exec.session();
            while !stop.load(Ordering::Acquire) {
                // lend is refused while the pinned jobs are live; the
                // resizes park/unpark the GPU pool under them
                session.lend(1, 0, 2);
                session.resize_pool(1, 1);
                session.resize_pool(1, 2);
                session.reclaim(1);
                std::thread::yield_now();
            }
        });
        let session = exec.session();
        for g in 0..40 {
            let violation = Arc::clone(&violation);
            let h = session.submit(
                JobSpec::new(800)
                    .named(&format!("gpu{g}"))
                    .with_placement(Placement::Pool(PoolId(1))),
                SubmitOpts::new(),
                move |w, _r| {
                    // pool 1 owns workers 2 and 3 on this topology
                    if w < 2 {
                        violation.store(true, Ordering::Release);
                    }
                },
            );
            h.wait();
        }
        stop.store(true, Ordering::Release);
        churn.join().unwrap();
    });
    assert!(
        !violation.load(Ordering::Acquire),
        "a pool-1-pinned task executed on a CPU worker"
    );

    // with the donor idle the lease goes through — and the next pinned
    // arrival on the lending pool snaps it back automatically
    let session = exec.session();
    assert_eq!(session.lend(1, 0, 2), 2);
    assert_eq!(exec.elastic().lent_out(1), 2);
    let vflag = Arc::clone(&violation);
    let h = session.submit(
        JobSpec::new(800)
            .named("gpu-snap")
            .with_placement(Placement::Pool(PoolId(1))),
        SubmitOpts::new(),
        move |w, _r| {
            if w < 2 {
                vflag.store(true, Ordering::Release);
            }
        },
    );
    let report = h.wait();
    assert_eq!(report.total_items(), 800);
    assert!(!violation.load(Ordering::Acquire));
    assert_eq!(
        exec.elastic().lent_out(1),
        0,
        "the pinned arrival must have reclaimed the lease"
    );
    assert_eq!(exec.elastic().widths(), vec![2, 2]);
}

/// ACCEPTANCE: a scripted lend/resize/reclaim schedule applied through
/// a real `Session` and through the DES mirror produces the same width
/// trajectory AND the same ordered `Resize` trace-event stream.
#[test]
fn scripted_resize_schedule_matches_the_des_mirror() {
    let _guard = SEQ.lock().unwrap();
    trace::enable(TraceMode::On, 4, 4096);
    let _ = trace::drain();
    let steps = [
        ElasticStep::Lend { t: 0.01, from: 1, to: 0, n: 2 },
        ElasticStep::Resize { t: 0.02, pool: 0, width: 1 },
        ElasticStep::Resize { t: 0.03, pool: 0, width: 2 },
        ElasticStep::Reclaim { t: 0.04, pool: 1 },
    ];

    // real session applying the schedule
    let exec = executor();
    let session = exec.session();
    let mut real_widths = Vec::new();
    for s in &steps {
        match *s {
            ElasticStep::Lend { from, to, n, .. } => {
                session.lend(from, to, n);
            }
            ElasticStep::Resize { pool, width, .. } => {
                session.resize_pool(pool, width);
            }
            ElasticStep::Reclaim { pool, .. } => {
                session.reclaim(pool);
            }
        }
        real_widths.push(exec.elastic().widths());
    }
    // (pool, width) pairs; timestamps are engine-local and not compared
    let real: Vec<(u64, u64)> = trace::drain()
        .into_iter()
        .filter(|e| e.kind == TraceKind::Resize)
        .map(|e| (e.name_hash, e.tag_hash))
        .collect();

    // DES mirror applying the identical schedule
    let sim_widths = sim::replay_steps(&hetero4(), &steps);
    let des: Vec<(u64, u64)> = trace::drain()
        .into_iter()
        .filter(|e| e.kind == TraceKind::Resize)
        .map(|e| (e.name_hash, e.tag_hash))
        .collect();
    trace::enable(TraceMode::Off, 4, 4096);

    assert_eq!(real_widths, sim_widths, "width trajectories diverge");
    assert_eq!(
        real_widths.last(),
        Some(&vec![2, 2]),
        "the schedule ends at the base assignment"
    );
    assert!(
        !real.is_empty(),
        "every effective step must publish Resize events"
    );
    assert_eq!(real, des, "Resize event streams diverge");
}
