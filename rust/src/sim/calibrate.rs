//! Host calibration of the DES cost-model constants.
//!
//! `measure()` times the actual scheduler primitives on this machine —
//! the same code the real-thread executor runs — and returns a
//! [`CostModel`] in host-seconds. `CostModel::recorded()` holds the
//! values measured on the reference host so figure benches are
//! reproducible without re-measuring; EXPERIMENTS.md §Calibration logs
//! both.

use std::time::Instant;

use super::model::CostModel;
use crate::sched::partitioner::{PartitionerOptions, Scheme};
use crate::sched::queue::{CentralAtomic, CentralLocked, TaskSource};

/// Median-of-means timing of `f` per call, in seconds.
fn time_per_call<F: FnMut()>(calls: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..calls / 10 + 1 {
        f();
    }
    let reps = 5;
    let mut means = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..calls {
            f();
        }
        means.push(t0.elapsed().as_secs_f64() / calls as f64);
    }
    means.sort_by(|a, b| a.total_cmp(b));
    means[reps / 2]
}

/// Measure the lock-protected queue/partitioner access cost: one
/// `pull_local` on the locked central queue (lock + getNextChunk +
/// unlock), single-threaded — the DES adds contention by serialization.
pub fn measure_queue_access() -> f64 {
    let n = 2_000_000;
    let src = CentralLocked::new(
        Scheme::Ss,
        n,
        16,
        &PartitionerOptions::default(),
    );
    time_per_call(n / 2, || {
        std::hint::black_box(src.pull_local(0));
    })
}

/// Measure the atomic central-queue access (`fetch_add` + chunk read).
pub fn measure_atomic_access() -> f64 {
    let n = 2_000_000;
    let src = CentralAtomic::new(
        Scheme::Ss,
        n,
        16,
        &PartitionerOptions::default(),
    );
    time_per_call(n / 2, || {
        std::hint::black_box(src.pull_local(0));
    })
}

/// Full calibration; falls back to recorded values for constants that
/// cannot be measured in isolation (steal probe, dispatch).
pub fn measure() -> CostModel {
    let recorded = CostModel::recorded();
    CostModel {
        queue_access: measure_queue_access(),
        atomic_access: measure_atomic_access(),
        ..recorded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_costs_are_plausible() {
        let m = measure();
        // between 2ns and 50us per access on any sane machine
        assert!(
            (2e-9..5e-5).contains(&m.queue_access),
            "queue_access={}",
            m.queue_access
        );
        assert!(
            (5e-10..5e-5).contains(&m.atomic_access),
            "atomic_access={}",
            m.atomic_access
        );
        // the atomic path must be no slower than the locked path
        assert!(m.atomic_access <= m.queue_access * 1.5);
    }
}
